//! END-TO-END DRIVER (DESIGN.md §4): the full three-layer system on a real
//! workload. The reservoir state computation runs through the **compiled
//! HLO artifact** (Pallas kernel → JAX graph → PJRT executable) — the
//! production request path with Python nowhere in sight — cross-checked
//! against the native Rust engine, trained with ridge regression, and
//! evaluated on held-out MSO5 data. Also reports the throughput contrast
//! against the O(N²) dense baseline.
//!
//! Prerequisite: `make artifacts`.
//! Run: `cargo run --release --example e2e_mso_pipeline`

use linear_reservoir::experiments::e2e;

fn main() -> anyhow::Result<()> {
    let report = e2e::run(5, 100, 0, 1e-8)?;
    e2e::print_report(&report);

    // hard assertions — this example doubles as the release gate
    anyhow::ensure!(
        report.hlo_native_max_diff < 1e-2,
        "HLO/native disagreement"
    );
    anyhow::ensure!(report.test_rmse_hlo < 1e-3, "HLO-path model quality");
    anyhow::ensure!(report.test_rmse_native < 1e-3, "native-path model quality");
    println!("\ne2e pipeline OK — all layers compose.");
    Ok(())
}
