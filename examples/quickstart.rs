//! Quickstart: build a diagonal linear reservoir with DPG (no `W` matrix
//! ever materialized), train a ridge readout on a sine-forecasting task,
//! and evaluate — the 60-second tour of the public API.
//!
//! Run: `cargo run --release --example quickstart`

use linear_reservoir::linalg::Mat;
use linear_reservoir::metrics::rmse;
use linear_reservoir::readout::{fit, Regularizer};
use linear_reservoir::reservoir::{DiagonalEsn, EsnConfig};
use linear_reservoir::rng::Pcg64;
use linear_reservoir::spectral::golden::{golden_spectrum, GoldenParams};

fn main() -> anyhow::Result<()> {
    // 1. Hyper-parameters (paper Table 1 vocabulary).
    let config = EsnConfig::default()
        .with_n(100) // reservoir size N
        .with_sr(0.9) // spectral radius ρ
        .with_leak(1.0) // no leak
        .with_seed(42);

    // 2. DPG: sample the eigenvalue spectrum directly (Noisy Golden — the
    //    paper's best-performing initialization) and the eigenvectors per
    //    Algorithm 2. Cost: O(N²) instead of the O(N³) diagonalization.
    let mut rng = Pcg64::new(config.seed, 1);
    let spectrum = golden_spectrum(
        config.n,
        GoldenParams { sr: config.spectral_radius, sigma: 0.2 },
        &mut rng,
    );
    let esn = DiagonalEsn::from_dpg(spectrum, &config, &mut rng);
    println!(
        "reservoir: N={}, {} real eigenvalues + {} conjugate pairs, ρ={:.3}",
        esn.n(),
        esn.spec.n_real,
        esn.spec.n_cpx(),
        esn.spec.radius()
    );

    // 3. A workload: one-step-ahead prediction of sin(0.2·t)+sin(0.311·t).
    let t_total = 1200;
    let series: Vec<f64> = (0..=t_total)
        .map(|t| (0.2 * t as f64).sin() + (0.311 * t as f64).sin())
        .collect();
    let u = Mat::from_rows(t_total, 1, &series[..t_total]);
    let target = &series[1..=t_total];

    // 4. Run the O(N)-per-step reservoir (Corollary 2) → Q-basis features.
    let feats = esn.run(&u);

    // 5. Train the readout by ridge regression (Eq. 9) on steps 100..800
    //    (first 100 are washout).
    let train = 100..800;
    let x_train = linear_reservoir::tasks::mso::slice_rows(&feats, train.clone());
    let y_train = Mat::from_rows(train.len(), 1, &target[train]);
    let readout = fit(&x_train, &y_train, 1e-8, true, Regularizer::Identity)?;

    // 6. Evaluate on the held-out tail.
    let test = 800..t_total;
    let x_test = linear_reservoir::tasks::mso::slice_rows(&feats, test.clone());
    let y_test = Mat::from_rows(test.len(), 1, &target[test]);
    let pred = readout.predict(&x_test);
    println!("test RMSE: {:.3e}", rmse(&pred, &y_test));
    println!("first 5 predictions vs targets:");
    for t in 0..5 {
        println!("  ŷ={:+.6}  y={:+.6}", pred[(t, 0)], y_test[(t, 0)]);
    }

    // 7. The serving hot path: the same predictions via the fused
    //    streaming readout (Appendix-A engine), which folds y = f·W+b
    //    into each O(N) step — no [T×N] trajectory is ever materialized.
    let engine = linear_reservoir::reservoir::QBasisEsn::from_diagonal(&esn);
    let y_stream = engine.run_readout(&u, &readout);
    let mut max_diff = 0.0f64;
    for (i, t) in (800..t_total).enumerate() {
        max_diff = max_diff.max((y_stream[(t, 0)] - pred[(i, 0)]).abs());
    }
    println!("fused streaming readout matches batch predictions to {max_diff:.1e}");

    // 8. Precision selection for serving: the same model at f32 — half the
    //    state traffic, 2× SIMD lanes (the compiled kernels' precision
    //    point); the wire stays f64 and the error budget is enforced in
    //    rust/tests/precision.rs. Pass this Model to server::serve.
    use linear_reservoir::server::{Model, Precision};
    let serving = Model::with_precision(esn, readout, Precision::F32);
    let y32 = serving.predict(&series[..t_total]);
    let mut f32_diff = 0.0f64;
    for t in 800..t_total {
        f32_diff = f32_diff.max((y32[t] - y_stream[(t, 0)]).abs());
    }
    println!("f32 serving engine within {f32_diff:.1e} of the f64 oracle");

    // 9. Deploying: `server::serve(Arc::new(serving), addr, None)` shards
    //    the front one sweeper per core automatically (each with its own
    //    64-lane streaming hub and pooled predict engines); the CLI twin
    //    is `repro serve --shards N` (`0`/omitted = one per core, `1` =
    //    the single-front behavior, bit-identical responses either way).
    //    On Linux, connections are served by an epoll readiness loop —
    //    S sweepers + 1 poll thread regardless of connection count, so
    //    idle streaming clients cost a file descriptor, not a thread
    //    (and `--idle-timeout-s N` reaps connections silent for N
    //    seconds). `repro serve --threaded` (or `serve_on(…, threaded =
    //    true)` with an already-bound listener — bind port 0 for a
    //    race-free ephemeral port) forces the legacy
    //    thread-per-connection transport for A/B: responses are
    //    bit-identical between the two.

    // 10. ONLINE training over TCP: the O(N) step makes training as
    //     cheap as serving, so the server trains where it serves. On a
    //     live connection, `train` advances your streaming state AND
    //     accumulates (features, target) rows into a per-lane ridge
    //     accumulator; `commit` solves it and hot-swaps YOUR
    //     connection's readout (predict and other connections keep the
    //     deployed model); further `train`+`commit` rounds refine it
    //     online, and `reset` (or disconnecting) drops the training.
    //     Wire script against a running `repro serve`:
    //
    //       {"op":"train","input":[u0,u1,…],"target":[y0,y1,…]}
    //         ← {"ok":true,"rows":N}       (lane's total training rows)
    //       {"op":"commit","alpha":1e-6}   ← {"ok":true,"version":1}
    //       {"op":"stream","input":[u…]}   ← predictions from YOUR
    //                                        freshly committed readout
    //
    //     In-process the same cycle is `Client::train` / `commit` /
    //     `stream` (see server::wire), and the batch-scale twin is
    //     `reservoir::parallel::run_parallel_batch_train` — the batched
    //     scan streaming rows into `readout::GramAcc` without ever
    //     materializing the [T×N] training block.

    // 11. FAULT TOLERANCE: a connection's full lane value — streaming
    //     state, trainer accumulator, committed readout + version ring —
    //     round-trips through `checkpoint`/`restore` bit-exactly, on
    //     either transport, across reconnects, and across servers built
    //     from the same model (warm failover / lane migration):
    //
    //       {"op":"checkpoint"}               ← {"ok":true,"checkpoint":{…}}
    //       …connection dies / sweeper panics / lane migrates…
    //       {"op":"restore","checkpoint":{…}} ← {"ok":true,"version":v}
    //       {"op":"stream","input":[u…]}      ← bit-identical continuation
    //
    //     `commit` returns a monotonic version id and the sweeper keeps a
    //     bounded per-lane ring of committed readouts, so
    //     `{"op":"rollback","version":1}` atomically reinstates an
    //     earlier readout (0 = the deployed model's) WITHOUT dropping the
    //     accumulated training rows. Every degradation is a typed error
    //     code (`lane_poisoned`, `trainer_budget`, `unavailable`, …) —
    //     DESIGN.md §10 has the full contract, `--trainer-budget-mb`
    //     caps sweeper training memory, and the `fault-inject` cargo
    //     feature arms the deterministic chaos harness
    //     (`rust/tests/chaos.rs`). In-process: `Client::checkpoint` /
    //     `restore` / `rollback`.

    // 12. SELF-HEALING: two-process failover demo. Terminal A is the
    //     primary, streaming per-lane checkpoint deltas to a warm
    //     standby; terminal B is the replica — the same binary, the same
    //     model, no special mode:
    //
    //       B$ repro serve --addr 127.0.0.1:7879
    //       A$ repro serve --addr 127.0.0.1:7878 \
    //            --standby 127.0.0.1:7879 --standby-interval-ms 100
    //
    //     Stream against A, then hard-kill it (`kill -9`) and promote
    //     your lane on B — the continuation is bit-identical to the
    //     uninterrupted run (`lane_id` comes from `{"op":"info"}` on A;
    //     `standby_lag_lanes: 0` there means B holds every mutation):
    //
    //       A: {"op":"stream","input":[u…]}   ← predictions…   (A dies)
    //       B: {"op":"migrate_in","lane_id":7} ← {"ok":true,"version":v}
    //       B: {"op":"stream","input":[u…]}   ← …continue bit-identically
    //
    //     The same snapshot primitive powers live migration: `{"op":
    //     "migrate"}` moves your lane to another shard mid-stream
    //     (`--rebalance` does this automatically off hot shards), and
    //     `{"op":"migrate_in","checkpoint":{…}}` re-homes it onto
    //     another server. Overload degrades on YOUR terms: pass
    //     `"deadline_ms"` on any request and expired/shed jobs answer
    //     typed `deadline_exceeded`/`overloaded` (never a hang; state
    //     untouched; `Client::with_retry` backs off on exactly the
    //     transient codes). `kill -TERM` (or `{"op":"shutdown_drain"}`)
    //     drains gracefully — in-flight replies flush, and
    //     `--drain-checkpoint DIR` spills live lanes as `lane-<id>.json`
    //     for a successor to adopt. DESIGN.md §11 has the protocol.

    // 13. CLUSTER: three-node kill-one-node demo. Every node gets the
    //     full peer list (`--peers`) and its own address as the others
    //     spell it (`--advertise`); connection keys are consistent-
    //     hashed across the live members, each node answers `moved
    //     {addr}` for keys it does not own, and a gossiped ping
    //     detector (5 missed probes) reassigns a dead node's ring range
    //     automatically. `--standby` fans deltas out to BOTH peers so
    //     either survivor can promote:
    //
    //       A$ repro serve --addr 127.0.0.1:7878 --advertise 127.0.0.1:7878 \
    //            --peers 127.0.0.1:7879,127.0.0.1:7880 \
    //            --standby 127.0.0.1:7879,127.0.0.1:7880
    //       B$ repro serve --addr 127.0.0.1:7879 --advertise 127.0.0.1:7879 \
    //            --peers 127.0.0.1:7878,127.0.0.1:7880 \
    //            --standby 127.0.0.1:7878,127.0.0.1:7880
    //       C$ repro serve --addr 127.0.0.1:7880 --advertise 127.0.0.1:7880 \
    //            --peers 127.0.0.1:7878,127.0.0.1:7879 \
    //            --standby 127.0.0.1:7878,127.0.0.1:7879
    //
    //     Stream against your key's owner (any node's `{"op":"info"}`
    //     names it in `cluster_owner`), then `kill -9` that node. Within
    //     ~250 ms the survivors' `info` shows `cluster_live` drop and a
    //     new `cluster_owner`; reconnect to ANY survivor and adopt:
    //
    //       {"op":"migrate_in","lane_id":7}
    //         ← {"ok":false,"code":"moved","addr":"127.0.0.1:7880"}
    //       (reconnect there — `Client::request` follows automatically,
    //        bounded at 4 hops, then types out as `redirect_loop`)
    //         ← {"ok":true,"version":v}
    //       {"op":"stream","input":[u…]}  ← bit-identical continuation
    //
    //     DESIGN.md §12 has the ring, the detector thresholds, and the
    //     failover sequence.

    // 14. MULTI-TENANT: mint private reservoirs over the wire. Because
    //     DPG samples the spectrum directly, a model IS its recipe —
    //     `create_model` re-mints bit-identical planes from four numbers
    //     on any node (same seed ⇒ same model; the returned id is the
    //     content hash of the recipe, so re-creating is idempotent).
    //     Against a running `repro serve [--max-models K] [--pin-cores]`:
    //
    //       T1: {"op":"create_model","seed":7,"n":200}
    //             ← {"ok":true,"model":A,"created":true}
    //       T2: {"op":"create_model","seed":8,"n":200,
    //            "lambda_prior":"ring"}
    //             ← {"ok":true,"model":B,"created":true}
    //       T1: {"op":"stream","model":A,"input":[u…]} ← tenant-A lanes
    //       T2: {"op":"stream","model":B,"input":[u…]} ← tenant-B lanes
    //         (first model-bearing op binds the connection — sticky;
    //          untrained tenants answer exact zeros until you
    //          `train`+`commit` them online, §10-style, against their
    //          OWN planes)
    //       {"op":"info"} ← …,"model":A,"models":2,
    //                       "model_lanes":{"A":1,"B":1},…
    //
    //     Both tenants (and the base model) ride ONE masked diagonal
    //     sweep per shard — the sweeper groups lanes by model, so 128
    //     tenants cost one pass, not 128 (bench row
    //     `tenant128_batch64_N1000`). `delete_model` expires the lease:
    //     bound lanes finish, new binds answer typed `unknown_model`;
    //     over-budget creates answer `model_budget` with nothing
    //     allocated. In-process: `Client::create_model`/`delete_model`.
    //     DESIGN.md §13 has the recipe/identity/grouping contract.

    // 15. WIRE-PATH SCALE-OUT: when request RATE (not connection count)
    //     is the ceiling, shard the event loop and drop the text codec:
    //
    //       $ repro serve --poll-threads 4
    //
    //     Accepted connections are dealt round-robin across 4 epoll
    //     loops, each owning its conns' buffers, idle wheel, and
    //     completions — sweepers/shards/cluster/registry unchanged, and
    //     `--poll-threads 1` (the default) is bit-identical to before.
    //     Any client can then upgrade its OWN connection to length-
    //     prefixed binary frames — raw little-endian float bits, no
    //     float formatting on either side, same typed error codes —
    //     by sending the 8-byte hello as its first bytes
    //     (`Client::upgrade_binary()`; the demo client is
    //     `cargo run --release --example serve_demo -- --binary`).
    //     JSON connections on the same port are untouched: the server
    //     sniffs the first bytes, and '{' is not 'L'. Responses are
    //     bit-identical across codecs (A/B-enforced); `{"op":"info"}`
    //     shows `poll_threads`, your `poll_thread`, `binary_conns`,
    //     and per-thread `poll_rounds`. Bench rows
    //     `wirepath_rps_p{1,2,4}_N1000_{json,binary}` gate the win in
    //     requests/sec. DESIGN.md §14 has the frame layout and the
    //     negotiation state machine.
    Ok(())
}
