//! Serving scenario: spin up the TCP prediction service with a trained
//! MSO model, fire a batch of client requests at it, and report quality +
//! latency — the "deploy it" story for the diagonal reservoir.
//!
//! Run: `cargo run --release --example serve_demo`

use std::sync::Arc;

use linear_reservoir::readout::{fit, Regularizer};
use linear_reservoir::reservoir::{DiagonalEsn, EsnConfig};
use linear_reservoir::rng::Pcg64;
use linear_reservoir::server::{serve_on, Client, Model};
use linear_reservoir::spectral::golden::{golden_spectrum, GoldenParams};
use linear_reservoir::tasks::mso::{slice_rows, MsoTask};
use linear_reservoir::util::Timer;

fn main() -> anyhow::Result<()> {
    // train
    let n = 100;
    let config = EsnConfig::default().with_n(n).with_sr(0.9).with_seed(0);
    let mut rng = Pcg64::new(0, 140);
    let spec = golden_spectrum(n, GoldenParams { sr: 0.9, sigma: 0.2 }, &mut rng);
    let esn = DiagonalEsn::from_dpg(spec, &config, &mut rng);
    let task = MsoTask::new(5);
    let splits = MsoTask::splits();
    let feats = esn.run(&task.input_mat());
    let x = slice_rows(&feats, splits.train.clone());
    let y = task.target_mat(splits.train.clone());
    let readout = fit(&x, &y, 1e-8, true, Regularizer::Identity)?;
    // Model::new derives the fused serving engine; predict requests run
    // through the server's micro-batching front with zero [T×N] traffic
    let model = Arc::new(Model::new(esn, readout));

    // serve in the background on an ephemeral port (bind before the
    // thread starts — no startup race, no sleep; on Linux the default
    // transport is the epoll event loop)
    let listener = std::net::TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?.to_string();
    let server_model = Arc::clone(&model);
    let handle =
        std::thread::spawn(move || serve_on(listener, server_model, Some(1), 0, None, false));
    // client: batch of requests; --binary upgrades the connection to
    // the length-prefixed frame protocol (raw LE floats, no float
    // formatting either side) — responses are bit-identical to JSON
    let binary = std::env::args().any(|a| a == "--binary");
    let mut client = Client::connect(&addr)?;
    if binary {
        client.upgrade_binary()?;
        println!("client upgraded to binary frames");
    }
    let requests = 50;
    let t = Timer::start();
    let mut last = Vec::new();
    for _ in 0..requests {
        last = client.predict(&task.input)?;
    }
    let total = t.elapsed_s();
    println!("served {requests} predict requests of {} steps each", task.input.len());
    println!("  mean latency : {:.2} ms/request", total / requests as f64 * 1e3);
    println!(
        "  throughput   : {:.0} reservoir steps/s through the service",
        requests as f64 * task.input.len() as f64 / total
    );

    // quality check on the test span
    let test = MsoTask::splits().test;
    let y_test = task.target_mat(test.clone());
    let mut sse = 0.0;
    for (i, t_idx) in test.enumerate() {
        let d = last[t_idx] - y_test[(i, 0)];
        sse += d * d;
    }
    println!("  test RMSE    : {:.3e}", (sse / y_test.rows() as f64).sqrt());
    drop(client);
    handle.join().unwrap()?;
    Ok(())
}
