//! MSO forecasting scenario — the paper's §5.1 workload end-to-end with
//! model selection: run the grid search for a chosen task and method,
//! report the winning configuration and the test RMSE, and contrast the
//! diagonal methods against the Normal baseline.
//!
//! Run: `cargo run --release --example mso_forecast -- [K]`

use linear_reservoir::coordinator::{GridSearch, GridSpec, MethodKind};

fn main() -> anyhow::Result<()> {
    let k: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(5);
    println!("MSO{k} with validation-selected hyper-parameters (reduced grid)\n");

    let gs = GridSearch {
        spec: GridSpec::quick(),
        n: 100,
        connectivity: 1.0,
    };
    let methods = [
        MethodKind::Normal,
        MethodKind::Diagonalized,
        MethodKind::DpgUniform,
        MethodKind::DpgGolden { sigma: 0.0 },
        MethodKind::DpgGolden { sigma: 0.2 },
        MethodKind::DpgSim,
    ];
    println!(
        "{:<16} {:>12} {:>12} {:>6} {:>6} {:>8} {:>9}",
        "method", "valid RMSE", "test RMSE", "ρ", "lr", "scale", "α"
    );
    for method in methods {
        let r = gs.run_mso(k, method, 0)?;
        println!(
            "{:<16} {:>12.3e} {:>12.3e} {:>6.2} {:>6.2} {:>8.2} {:>9.0e}",
            method.label(),
            r.valid_rmse,
            r.test_rmse,
            r.spectral_radius,
            r.leak_rate,
            r.input_scaling,
            r.alpha
        );
    }
    println!("\n(use `repro table2` for the full Table-1 grid over 10 seeds)");
    Ok(())
}
