//! Closed-loop (free-running) forecasting with the Appendix-A memory-view
//! engine: train on MSO3 with teacher forcing, then let the network drive
//! itself — each prediction becomes the next input. Reports how far the
//! free-running trajectory tracks the ground truth.
//!
//! Run: `cargo run --release --example generative_forecast`

use linear_reservoir::linalg::Mat;
use linear_reservoir::readout::{fit, Regularizer};
use linear_reservoir::reservoir::{DiagonalEsn, EsnConfig, QBasisEsn};
use linear_reservoir::rng::Pcg64;
use linear_reservoir::spectral::golden::{golden_spectrum, GoldenParams};
use linear_reservoir::tasks::mso::{mso_series, slice_rows};

fn main() -> anyhow::Result<()> {
    let k = 3;
    let n = 300;
    let t_train = 2500;
    let horizon = 300;

    // closed-loop stability is delicate: with sr = 1.0 the trained
    // feedback loop puts poles slightly OUTSIDE the unit circle and the
    // rollout explodes; sr = 0.95 keeps the open-loop modes inside and
    // lets the readout synthesise the sustained oscillation (measured:
    // max |err| ≈ 1e-11 over 300 free-running steps at this setting).
    let config = EsnConfig::default().with_n(n).with_sr(0.95).with_seed(1);
    let mut rng = Pcg64::new(1, 170);
    let spec = golden_spectrum(n, GoldenParams { sr: 0.95, sigma: 0.0 }, &mut rng);
    let diag = DiagonalEsn::from_dpg(spec, &config, &mut rng);
    let esn = QBasisEsn::from_diagonal(&diag); // interleaved hot-path engine

    let series = mso_series(k, t_train + horizon + 1);
    let u = Mat::from_rows(t_train, 1, &series[..t_train]);
    let feats = esn.run(&u);
    let train = 400..t_train;
    let x = slice_rows(&feats, train.clone());
    let y = Mat::from_rows(train.len(), 1, &series[401..=t_train]);
    let readout = fit(&x, &y, 1e-10, true, Regularizer::Identity)?;

    // free-running rollout
    let rollout = esn.generate(&series[..t_train], horizon, &readout.w, readout.b[0]);

    println!("free-running MSO{k} forecast, horizon {horizon}:");
    let mut worst: f64 = 0.0;
    for (h, checkpoints) in [(10, ()), (50, ()), (100, ()), (200, ()), (299, ())] {
        let _ = checkpoints;
        let pred = rollout[h];
        let want = series[t_train + h];
        println!("  t+{h:<4} ŷ={pred:+.4}  y={want:+.4}  |err|={:.2e}", (pred - want).abs());
    }
    for (h, pred) in rollout.iter().enumerate() {
        worst = worst.max((pred - series[t_train + h]).abs());
    }
    println!("max |error| over the whole horizon: {worst:.3e}");
    println!("(signal range is ±{k}; the linear reservoir sustains the oscillators)");
    Ok(())
}
