//! Memory-capacity scenario (paper §5.2): compare the short-term memory of
//! the Normal baseline against the DPG distributions at spectral radius 1,
//! printing the MC-vs-delay curve and the total capacity.
//!
//! Run: `cargo run --release --example memory_capacity -- [N]`

use linear_reservoir::experiments::fig6;

fn main() -> anyhow::Result<()> {
    let n: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(100);
    println!("memory capacity at N={n}, sr=1, no leak (2 seeds)\n");
    let rows = fig6::run(&[n], 2, 1e-7, false)?;

    // print a compact curve: every ~N/10 delays
    let step = (n / 10).max(1);
    println!("{:>7} {:>10} {:>10} {:>10} {:>10}", "delay", "normal", "uniform", "golden", "sim");
    let mc = |method: &str, k: usize| {
        rows.iter()
            .find(|r| r.method == method && r.delay == k)
            .map(|r| r.mc_mean)
            .unwrap_or(f64::NAN)
    };
    let mut k = 1;
    while k <= fig6::k_max_for(n) {
        println!(
            "{:>7} {:>10.3} {:>10.3} {:>10.3} {:>10.3}",
            k,
            mc("normal", k),
            mc("uniform", k),
            mc("golden", k),
            mc("sim", k)
        );
        k += step;
    }
    println!("\ntotal capacity (Σ MC_k):");
    for method in fig6::METHODS {
        let total: f64 = rows
            .iter()
            .filter(|r| r.method == method)
            .map(|r| r.mc_mean)
            .sum();
        println!("  {method:<8} {total:.1}  (bound: N = {n})");
    }
    Ok(())
}
