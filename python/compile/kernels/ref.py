"""Pure-jnp reference oracles for the diagonal-reservoir kernels.

These are the correctness ground truth for the Pallas kernels in
``diag_scan.py``. Everything here is written in the most direct possible
style (``jax.lax.scan`` over time) so that it is obviously equivalent to the
paper's equations:

    Corollary 2 (pointwise reservoir step, P-basis):
        s(t) = s(t-1) ⊙ Λ + uproj(t)

with complex Λ and complex projected inputs ``uproj(t) = u(t) [W_in]_P``.

Complex numbers are represented as split (re, im) float arrays throughout —
the same layout the Pallas kernels and the Rust runtime use (Appendix A's
"memory view" expressed as explicit planes rather than pointer casts).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def complex_mul(ar, ai, br, bi):
    """Split-complex product: (ar + i·ai) · (br + i·bi) → (re, im)."""
    return ar * br - ai * bi, ar * bi + ai * br


def diag_scan_ref(lam_re, lam_im, u_re, u_im, s0_re=None, s0_im=None):
    """Sequential reference for the diagonal recurrence.

    Args:
      lam_re, lam_im: ``[N]`` eigenvalue planes.
      u_re, u_im:     ``[T, N]`` projected-input planes (``u(t) [W_in]_P``).
      s0_re, s0_im:   optional ``[N]`` initial state (defaults to zero, as in
                      the paper: ``r(0) = 0``).

    Returns:
      (s_re, s_im): ``[T, N]`` state trajectory planes, where row ``t`` is
      the state *after* consuming input ``t`` (i.e. ``r(t+1)`` in paper
      1-based indexing).
    """
    n = lam_re.shape[-1]
    dtype = u_re.dtype
    if s0_re is None:
        s0_re = jnp.zeros((n,), dtype)
    if s0_im is None:
        s0_im = jnp.zeros((n,), dtype)

    def step(carry, u_t):
        sr, si = carry
        ur, ui = u_t
        pr, pi = complex_mul(sr, si, lam_re, lam_im)
        sr, si = pr + ur, pi + ui
        return (sr, si), (sr, si)

    (_, _), (s_re, s_im) = jax.lax.scan(step, (s0_re, s0_im), (u_re, u_im))
    return s_re, s_im


def diag_scan_closed_form(lam_re, lam_im, u_re, u_im):
    """Lemma 3 closed form: ``r(t) = Σ_{i≤t} uproj(i) ⊙ Λ^{t-i}``.

    O(T²) — only used in tests as an independent derivation (it exercises a
    different summation order than the scan, catching order-of-operations
    bugs that a scan-vs-scan comparison would miss).
    """
    lam = (lam_re + 1j * lam_im).astype(jnp.complex64)
    u = (u_re + 1j * u_im).astype(jnp.complex64)
    T = u.shape[0]
    ts = jnp.arange(T)
    # powers[k] = Λ^k  for k in 0..T-1
    powers = lam[None, :] ** ts[:, None].astype(jnp.complex64)

    def state_at(t):
        # Σ_{i=0..t} u[i] * Λ^(t-i)
        w = jnp.where(ts[:, None] <= t, powers[(t - ts) % T], 0.0)
        return jnp.sum(u * w, axis=0)

    s = jax.vmap(state_at)(ts)
    return jnp.real(s), jnp.imag(s)


def assoc_scan_ref(lam_re, lam_im, u_re, u_im):
    """Appendix-B reference: parallel prefix over the affine maps.

    The recurrence ``s ← λ⊙s + u(t)`` composes as elementwise affine maps
    ``(a, b): s ↦ a⊙s + b`` with combine ``(a2,b2)∘(a1,b1) = (a2a1, a2b1+b2)``
    — associative, hence ``jax.lax.associative_scan`` applies. Returns the
    same trajectory as :func:`diag_scan_ref`.
    """
    a_re = jnp.broadcast_to(lam_re, u_re.shape)
    a_im = jnp.broadcast_to(lam_im, u_im.shape)

    def combine(x, y):
        xar, xai, xbr, xbi = x
        yar, yai, ybr, ybi = y
        ar, ai = complex_mul(yar, yai, xar, xai)
        tr, ti = complex_mul(yar, yai, xbr, xbi)
        return ar, ai, tr + ybr, ti + ybi

    _, _, s_re, s_im = jax.lax.associative_scan(
        combine, (a_re, a_im, u_re, u_im), axis=0
    )
    return s_re, s_im


def project_input_ref(u, win_re, win_im):
    """``uproj(t) = u(t) [W_in]_P`` as two real matmuls. u: [T, D_in]."""
    return u @ win_re, u @ win_im


def qbasis_features_ref(s_re, s_im, n_real):
    """Map split-complex P-basis states to the real Q-basis feature layout.

    Slot convention (shared with spectral generators and the Rust side):
      * slots ``0..n_real``            — real eigenvalues (imag plane ≡ 0),
      * slots ``n_real..n_real+n_cpx`` — one member of each conjugate pair.

    Q-basis features (Appendix A): ``[s_re(real slots) | re,im interleaved
    per complex slot]`` — exactly N real numbers for an N-dim reservoir,
    where ``N = n_real + 2·n_cpx`` and the slot count is ``n_real + n_cpx``.
    """
    T = s_re.shape[0]
    real_part = s_re[:, :n_real]
    cr = s_re[:, n_real:]
    ci = s_im[:, n_real:]
    inter = jnp.stack([cr, ci], axis=-1).reshape(T, -1)
    return jnp.concatenate([real_part, inter], axis=1)


def esn_forward_ref(u, lam_re, lam_im, win_re, win_im, n_real, w_out, bias):
    """Full L2 reference: project → scan → Q-features → readout.

    ``w_out``: [N, D_out] real (Q-basis readout), ``bias``: [D_out].
    Returns (y [T, D_out], feats [T, N]).
    """
    ur, ui = project_input_ref(u, win_re, win_im)
    s_re, s_im = diag_scan_ref(lam_re, lam_im, ur, ui)
    feats = qbasis_features_ref(s_re, s_im, n_real)
    return feats @ w_out + bias, feats


def dense_esn_ref(u, w, w_in):
    """Standard (un-diagonalized) linear ESN: r(t) = r(t-1)W + u(t)W_in.

    Used by tests to validate that the diagonal path reproduces the
    standard dynamics when (Λ, P) come from an actual eigendecomposition
    (the EWT equivalence, Theorem 1).
    """
    n = w.shape[0]

    def step(r, u_t):
        r = r @ w + u_t @ w_in
        return r, r

    _, rs = jax.lax.scan(step, jnp.zeros((n,), u.dtype), u)
    return rs
