"""Layer-1 Pallas kernels for the diagonal linear-reservoir recurrence.

The paper's compute hot-spot (Corollary 2) is

    s(t) = s(t-1) ⊙ Λ + uproj(t),      Λ ∈ ℂ^N,  uproj(t) ∈ ℂ^N

i.e. an elementwise complex affine recurrence — O(N) per step instead of the
standard reservoir's O(N²) matvec. Two kernels implement it:

``diag_scan_pallas``
    Grid-parallel over eigenvalue tiles, sequential over T *inside* the
    tile. Every eigencomponent evolves independently (the whole point of
    the diagonalization), so the natural TPU decomposition maps eigenvalue
    slots onto the 128-lane axis and keeps the carried state resident in
    VMEM while input-projection tiles stream HBM→VMEM.

``assoc_scan_pallas``
    Appendix-B parallelization across *time*: the affine maps
    ``(a,b) : s ↦ a⊙s + b`` form a monoid under composition
    ``(a2,b2)∘(a1,b1) = (a2·a1, a2·b1 + b2)``, so the trajectory is an
    inclusive prefix scan computed in ⌈log₂ T⌉ Hillis–Steele passes, each
    fully parallel over T·N.

Complex numbers are split (re, im) f32 planes — Appendix A's "memory view"
expressed as layout. Kernels MUST run with ``interpret=True``: real-TPU
lowering emits a Mosaic custom-call the CPU PJRT plugin cannot execute.

Hardware adaptation (see DESIGN.md §5): the original story is CPU/GPU
matvec-vs-elementwise; on TPU there is no MXU work left at all — the kernel
is VPU/bandwidth-bound, which *is* the paper's O(N) claim made physical.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile of eigenvalue slots handled by one program instance. 128 = TPU lane
# width; under interpret=True it just sets the grid decomposition.
LANE_TILE = 128


def _ceil_div(a: int, b: int) -> int:
    return (a + b - 1) // b


# ---------------------------------------------------------------------------
# Kernel 1: tile-parallel over N, sequential over T
# ---------------------------------------------------------------------------


def _diag_scan_kernel(lam_re_ref, lam_im_ref, u_re_ref, u_im_ref,
                      o_re_ref, o_im_ref):
    """One program scans T steps for a ``[LANE_TILE]`` block of slots.

    The carry lives in registers/VMEM for the whole loop; each step is two
    complex FMAs per slot. BlockSpec gives this program the full T extent of
    its slot tile, so the HBM→VMEM streaming of ``u`` is expressed by the
    index_map below, not inside the kernel body.
    """
    lam_re = lam_re_ref[...]
    lam_im = lam_im_ref[...]
    T = u_re_ref.shape[0]

    def body(t, carry):
        s_re, s_im = carry
        u_re = u_re_ref[t, :]
        u_im = u_im_ref[t, :]
        # (s·λ) + u, split-complex
        new_re = s_re * lam_re - s_im * lam_im + u_re
        new_im = s_re * lam_im + s_im * lam_re + u_im
        o_re_ref[t, :] = new_re
        o_im_ref[t, :] = new_im
        return new_re, new_im

    zero = jnp.zeros(lam_re.shape, lam_re.dtype)
    jax.lax.fori_loop(0, T, body, (zero, zero))


@functools.partial(jax.jit, static_argnames=("tile",))
def diag_scan_pallas(lam_re, lam_im, u_re, u_im, *, tile: int = LANE_TILE):
    """Pallas diagonal-recurrence scan. Shapes: λ [N], u [T, N] → s [T, N]².

    N is padded to a multiple of ``tile`` internally; padding slots carry
    λ=0, u=0 and are stripped before returning.
    """
    T, n = u_re.shape
    n_pad = _ceil_div(n, tile) * tile
    if n_pad != n:
        pad = [(0, n_pad - n)]
        lam_re = jnp.pad(lam_re, pad)
        lam_im = jnp.pad(lam_im, pad)
        u_re = jnp.pad(u_re, [(0, 0)] + pad)
        u_im = jnp.pad(u_im, [(0, 0)] + pad)

    grid = (n_pad // tile,)
    lam_spec = pl.BlockSpec((tile,), lambda i: (i,))
    seq_spec = pl.BlockSpec((T, tile), lambda i: (0, i))
    out_shape = [
        jax.ShapeDtypeStruct((T, n_pad), u_re.dtype),
        jax.ShapeDtypeStruct((T, n_pad), u_re.dtype),
    ]
    s_re, s_im = pl.pallas_call(
        _diag_scan_kernel,
        grid=grid,
        in_specs=[lam_spec, lam_spec, seq_spec, seq_spec],
        out_specs=[seq_spec, seq_spec],
        out_shape=out_shape,
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(lam_re, lam_im, u_re, u_im)
    return s_re[:, :n], s_im[:, :n]


# ---------------------------------------------------------------------------
# Kernel 2: Appendix-B parallel prefix over time (Hillis–Steele)
# ---------------------------------------------------------------------------


def _assoc_scan_kernel(lam_re_ref, lam_im_ref, u_re_ref, u_im_ref,
                       o_re_ref, o_im_ref, *, steps: int):
    """Inclusive scan over the affine-map monoid, log₂(T) doubling passes.

    Each pass combines element t with element t-2^k:
        (a, b)[t] ← (a[t]·a[t-d],  a[t]·b[t-d] + b[t])
    After all passes b[t] = s(t) (since s(0)=0 the 'a' product is never
    applied to a nonzero initial state) — the standard Hillis–Steele form
    of Appendix B's "each input's echo evaluated independently".
    """
    T = u_re_ref.shape[0]
    a_re = jnp.broadcast_to(lam_re_ref[...], u_re_ref.shape)
    a_im = jnp.broadcast_to(lam_im_ref[...], u_im_ref.shape)
    b_re = u_re_ref[...]
    b_im = u_im_ref[...]

    def pass_k(k, carry):
        a_re, a_im, b_re, b_im = carry
        d = 1 << k
        idx = jnp.arange(T)
        src = jnp.maximum(idx - d, 0)
        valid = (idx >= d)[:, None]
        pa_re, pa_im = a_re[src, :], a_im[src, :]
        pb_re, pb_im = b_re[src, :], b_im[src, :]
        # compose: new_a = a∘pa, new_b = a·pb + b   (elementwise complex)
        na_re = jnp.where(valid, a_re * pa_re - a_im * pa_im, a_re)
        na_im = jnp.where(valid, a_re * pa_im + a_im * pa_re, a_im)
        nb_re = jnp.where(valid, a_re * pb_re - a_im * pb_im + b_re, b_re)
        nb_im = jnp.where(valid, a_re * pb_im + a_im * pb_re + b_im, b_im)
        return na_re, na_im, nb_re, nb_im

    a_re, a_im, b_re, b_im = jax.lax.fori_loop(
        0, steps, pass_k, (a_re, a_im, b_re, b_im))
    o_re_ref[...] = b_re
    o_im_ref[...] = b_im


@functools.partial(jax.jit, static_argnames=("tile",))
def assoc_scan_pallas(lam_re, lam_im, u_re, u_im, *, tile: int = LANE_TILE):
    """Parallel-in-time diagonal scan (Appendix B). Same contract as
    :func:`diag_scan_pallas`; O(T·N·log T) work, O(log T) depth."""
    T, n = u_re.shape
    steps = max(1, (T - 1).bit_length())
    n_pad = _ceil_div(n, tile) * tile
    if n_pad != n:
        pad = [(0, n_pad - n)]
        lam_re = jnp.pad(lam_re, pad)
        lam_im = jnp.pad(lam_im, pad)
        u_re = jnp.pad(u_re, [(0, 0)] + pad)
        u_im = jnp.pad(u_im, [(0, 0)] + pad)

    grid = (n_pad // tile,)
    lam_spec = pl.BlockSpec((tile,), lambda i: (i,))
    seq_spec = pl.BlockSpec((T, tile), lambda i: (0, i))
    out_shape = [
        jax.ShapeDtypeStruct((T, n_pad), u_re.dtype),
        jax.ShapeDtypeStruct((T, n_pad), u_re.dtype),
    ]
    s_re, s_im = pl.pallas_call(
        functools.partial(_assoc_scan_kernel, steps=steps),
        grid=grid,
        in_specs=[lam_spec, lam_spec, seq_spec, seq_spec],
        out_specs=[seq_spec, seq_spec],
        out_shape=out_shape,
        interpret=True,
    )(lam_re, lam_im, u_re, u_im)
    return s_re[:, :n], s_im[:, :n]


# ---------------------------------------------------------------------------
# Kernel 3: single fused reservoir step (for the streaming/serving path)
# ---------------------------------------------------------------------------


def _diag_step_kernel(lam_re_ref, lam_im_ref, s_re_ref, s_im_ref,
                      u_re_ref, u_im_ref, o_re_ref, o_im_ref):
    """One O(N) reservoir step: o = s ⊙ λ + u (split-complex)."""
    s_re, s_im = s_re_ref[...], s_im_ref[...]
    l_re, l_im = lam_re_ref[...], lam_im_ref[...]
    o_re_ref[...] = s_re * l_re - s_im * l_im + u_re_ref[...]
    o_im_ref[...] = s_re * l_im + s_im * l_re + u_im_ref[...]


@functools.partial(jax.jit, static_argnames=("tile",))
def diag_step_pallas(lam_re, lam_im, s_re, s_im, u_re, u_im,
                     *, tile: int = LANE_TILE):
    """Single-step kernel used by the streaming engine (one token at a time,
    e.g. generative/feedback mode where the scan cannot be batched)."""
    n = lam_re.shape[0]
    n_pad = _ceil_div(n, tile) * tile
    args = [lam_re, lam_im, s_re, s_im, u_re, u_im]
    if n_pad != n:
        args = [jnp.pad(a, [(0, n_pad - n)]) for a in args]
    spec = pl.BlockSpec((tile,), lambda i: (i,))
    out_shape = [jax.ShapeDtypeStruct((n_pad,), lam_re.dtype)] * 2
    o_re, o_im = pl.pallas_call(
        _diag_step_kernel,
        grid=(n_pad // tile,),
        in_specs=[spec] * 6,
        out_specs=[spec, spec],
        out_shape=out_shape,
        interpret=True,
    )(*args)
    return o_re[:n], o_im[:n]
