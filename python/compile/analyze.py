"""L2 perf analysis: static inspection of the lowered HLO artifacts.

Reports, per artifact: instruction counts by opcode family, the number of
fusions, while-loops, transposes/copies (layout red flags), and an analytic
cost model — FLOPs and HBM bytes per reservoir step — used for the
DESIGN.md §Perf roofline discussion (interpret=True wall-clock is CPU-numpy
time, NOT a TPU proxy, so structure is what we optimize).

Usage:  python -m compile.analyze [--dir ../artifacts]
"""

from __future__ import annotations

import argparse
import json
import os
import re
from collections import Counter


ASSIGN_RE = re.compile(r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+)$")
OP_RE = re.compile(r"([\w\-]+)\(")


def count_ops(hlo_text: str) -> Counter:
    """Count HLO opcodes: for each `name = <type> op(args…)` line, the
    opcode is the first `word(` on the right-hand side (types/layout
    annotations contain parens but never directly after an identifier)."""
    counts: Counter = Counter()
    for line in hlo_text.splitlines():
        m = ASSIGN_RE.match(line)
        if not m:
            continue
        op = OP_RE.search(m.group(1))
        if op:
            counts[op.group(1)] += 1
    return counts


def step_cost_model(slots: int, d_in: int) -> dict:
    """Analytic per-step cost of the diagonal update (split-complex):

    FLOPs: complex multiply (4 mul + 2 add) + input add (2) per slot, plus
    the projection 2·d_in MACs per slot plane.
    Bytes (f32): read λ (8B/slot) + state (8B) + uproj (8B), write state
    (8B) — the memory-bound profile that makes this VPU work on TPU.
    """
    flops = slots * (6 + 2) + 2 * 2 * d_in * slots
    bytes_moved = slots * (8 + 8 + 8 + 8)
    return {
        "flops_per_step": flops,
        "bytes_per_step": bytes_moved,
        "arithmetic_intensity": flops / bytes_moved,
    }


def analyze_dir(art_dir: str) -> list[dict]:
    manifest = json.load(open(os.path.join(art_dir, "manifest.json")))
    reports = []
    for art in manifest["artifacts"]:
        text = open(os.path.join(art_dir, art["file"])).read()
        ops = count_ops(text)
        report = {
            "file": art["file"],
            "kind": art["kind"],
            "total_instructions": sum(ops.values()),
            "while_loops": ops.get("while", 0),
            "fusions": ops.get("fusion", 0),
            "transposes": ops.get("transpose", 0),
            "copies": ops.get("copy", 0),
            "dots": ops.get("dot", 0),
            "custom_calls": ops.get("custom-call", 0),
        }
        if art["kind"].startswith("diag_states"):
            report["cost_model"] = step_cost_model(
                art["dims"]["slots"], art["dims"]["d_in"]
            )
        reports.append(report)
    return reports


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dir", default="../artifacts")
    args = ap.parse_args()
    reports = analyze_dir(args.dir)
    for r in reports:
        print(f"{r['file']}")
        print(
            f"  instrs={r['total_instructions']} while={r['while_loops']} "
            f"fusion={r['fusions']} transpose={r['transposes']} "
            f"copy={r['copies']} dot={r['dots']} custom-call={r['custom_calls']}"
        )
        if "cost_model" in r:
            cm = r["cost_model"]
            print(
                f"  per-step: {cm['flops_per_step']} FLOPs, "
                f"{cm['bytes_per_step']} B, AI={cm['arithmetic_intensity']:.2f}"
            )
    # red-flag summary
    bad = [r for r in reports if r["custom_calls"] > 0]
    if bad:
        print("\nWARNING: custom-calls present (CPU PJRT cannot run Mosaic):")
        for r in bad:
            print(f"  {r['file']}")
    else:
        print("\nOK: no custom-calls — every artifact is plain HLO.")


if __name__ == "__main__":
    main()
