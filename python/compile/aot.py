"""AOT entry point: lower the L2 graphs to HLO *text* artifacts.

Run once at build time (``make artifacts``); the Rust runtime loads these
files via ``HloModuleProto::from_text_file`` and never touches Python.

Interchange format is HLO **text**, not a serialized ``HloModuleProto``:
jax ≥ 0.5 emits protos with 64-bit instruction ids which the image's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Shape strategy: every graph is lowered for the concrete shapes the Rust
side needs (one executable per variant, listed in ``manifest.json``). The
state graphs return raw split-complex state planes ``[T, S]`` with a fixed
slot count ``S`` (padded with λ=0 slots); the Q-basis feature gather — which
depends on the per-seed real/complex split — happens in Rust. This keeps a
single artifact valid for *every* DPG seed of a given reservoir size.

Usage:  python -m compile.aot --out-dir ../artifacts [--quick]
"""

from __future__ import annotations

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

F32 = jnp.float32


def to_hlo_text(lowered) -> str:
    """jax Lowered → XlaComputation → HLO text (return_tuple=True: the Rust
    side always unwraps a tuple, regardless of arity)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(*shape):
    return jax.ShapeDtypeStruct(shape, F32)


# --------------------------------------------------------------------------
# Graph catalogue. Each entry: name pattern, lower() given dims.
# --------------------------------------------------------------------------


def lower_diag_states(T, d_in, slots):
    return jax.jit(model.diag_esn_states_raw).lower(
        spec(T, d_in), spec(slots), spec(slots),
        spec(d_in, slots), spec(d_in, slots))


def lower_diag_states_assoc(T, d_in, slots):
    return jax.jit(model.diag_esn_states_raw_assoc).lower(
        spec(T, d_in), spec(slots), spec(slots),
        spec(d_in, slots), spec(d_in, slots))


def lower_diag_step(d_in, slots):
    return jax.jit(model.diag_esn_step).lower(
        spec(slots), spec(slots), spec(d_in), spec(slots), spec(slots),
        spec(d_in, slots), spec(d_in, slots))


def lower_readout_apply(T, n_feat, d_out):
    fn = lambda x, w: (x @ w,)
    return jax.jit(fn).lower(spec(T, n_feat), spec(n_feat, d_out))


def lower_ridge_stats(T, n_feat, d_out):
    return jax.jit(model.ridge_stats).lower(spec(T, n_feat), spec(T, d_out))


def lower_dense_states(T, d_in, n):
    return jax.jit(model.dense_esn_states).lower(
        spec(T, d_in), spec(n, n), spec(d_in, n))


CATALOGUE = {
    "diag_states": (lower_diag_states, ("T", "d_in", "slots")),
    "diag_states_assoc": (lower_diag_states_assoc, ("T", "d_in", "slots")),
    "diag_step": (lower_diag_step, ("d_in", "slots")),
    "readout_apply": (lower_readout_apply, ("T", "n_feat", "d_out")),
    "ridge_stats": (lower_ridge_stats, ("T", "n_feat", "d_out")),
    "dense_states": (lower_dense_states, ("T", "d_in", "n")),
}

# Default variant set: the e2e MSO pipeline (T=1000, N=100, D=1), the
# serving step, and small shapes for the Rust integration tests.
DEFAULT_VARIANTS = [
    ("diag_states", dict(T=1000, d_in=1, slots=100)),
    ("diag_states_assoc", dict(T=1000, d_in=1, slots=100)),
    ("diag_step", dict(d_in=1, slots=100)),
    ("readout_apply", dict(T=300, n_feat=101, d_out=1)),
    ("ridge_stats", dict(T=300, n_feat=101, d_out=1)),
    ("dense_states", dict(T=1000, d_in=1, n=100)),
    # small test shapes
    ("diag_states", dict(T=32, d_in=2, slots=16)),
    ("diag_states_assoc", dict(T=32, d_in=2, slots=16)),
    ("diag_step", dict(d_in=2, slots=16)),
    ("ridge_stats", dict(T=32, n_feat=17, d_out=2)),
    ("readout_apply", dict(T=32, n_feat=17, d_out=2)),
    ("dense_states", dict(T=32, d_in=2, n=16)),
]

QUICK_VARIANTS = DEFAULT_VARIANTS[6:]  # tests-only set


def artifact_name(kind: str, dims: dict) -> str:
    _, keys = CATALOGUE[kind]
    suffix = "_".join(f"{k}{dims[k]}" for k in keys)
    return f"{kind}__{suffix}"


def build(out_dir: str, variants) -> None:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"format": "hlo-text", "artifacts": []}
    for kind, dims in variants:
        lower_fn, keys = CATALOGUE[kind]
        name = artifact_name(kind, dims)
        path = os.path.join(out_dir, name + ".hlo.txt")
        lowered = lower_fn(**dims)
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"].append(
            {"kind": kind, "dims": {k: dims[k] for k in keys},
             "file": os.path.basename(path)})
        print(f"  wrote {path} ({len(text)} chars)")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"manifest: {len(manifest['artifacts'])} artifacts")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--quick", action="store_true",
                    help="only the small test-shape artifacts")
    args = ap.parse_args()
    build(args.out_dir, QUICK_VARIANTS if args.quick else DEFAULT_VARIANTS)


if __name__ == "__main__":
    main()
