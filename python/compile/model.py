"""Layer-2 JAX compute graphs for the diagonal linear reservoir.

Each public function here is a *whole* jit-able graph that ``aot.py`` lowers
once to HLO text; the Rust runtime (``rust/src/runtime``) loads, compiles
(PJRT CPU) and executes them on the request path. Python never runs at
inference time.

Graphs
------
``diag_esn_states``   u [T,D_in] → Q-basis features [T,N]
    input projection (2 real matmuls) → L1 Pallas scan → Q-feature gather.
``diag_esn_forward``  … plus readout application → (y [T,D_out], feats)
``diag_esn_states_assoc``  same as states but through the Appendix-B
    parallel-prefix kernel (ablation artifact).
``ridge_stats``       features X [T,N'], targets Y [T,D] → (XᵀX, XᵀY)
    the O(T·N'²) half of ridge training, so the heavy accumulation also
    runs through XLA; the Rust side does the (tiny) regularized solve.
``diag_esn_step``     streaming single step for the serving path.

Q-basis feature layout (shared contract with ``kernels/ref.py``, the
spectral generators in Rust, and the readout): ``n_real`` real-eigenvalue
components first, then (re, im) interleaved per complex-conjugate pair;
``N = n_real + 2·n_cpx`` and the kernel scans ``n_slots = n_real + n_cpx``
complex slots.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import diag_scan as k


def _qbasis_features(s_re, s_im, n_real: int):
    """[T, n_slots]² split-complex states → [T, N] real Q-basis features."""
    T = s_re.shape[0]
    real_part = s_re[:, :n_real]
    cr = s_re[:, n_real:]
    ci = s_im[:, n_real:]
    inter = jnp.stack([cr, ci], axis=-1).reshape(T, -1)
    return jnp.concatenate([real_part, inter], axis=1)


def diag_esn_states(u, lam_re, lam_im, win_re, win_im, *, n_real: int,
                    scan=k.diag_scan_pallas):
    """Project inputs into the eigenbasis, scan, return Q-basis features.

    Args:
      u:       [T, D_in] real input sequence.
      lam_*:   [n_slots] eigenvalue planes (one slot per real eigenvalue or
               conjugate pair; conjugates implicit).
      win_*:   [D_in, n_slots] transformed input weights ``[W_in]_P``.
      n_real:  number of real-eigenvalue slots (static).

    Returns: [T, N] real features, N = n_real + 2·(n_slots - n_real).
    """
    u_re = u @ win_re
    u_im = u @ win_im
    s_re, s_im = scan(lam_re, lam_im, u_re, u_im)
    return _qbasis_features(s_re, s_im, n_real)


def diag_esn_states_assoc(u, lam_re, lam_im, win_re, win_im, *, n_real: int):
    """Appendix-B variant: states through the parallel-prefix kernel."""
    return diag_esn_states(u, lam_re, lam_im, win_re, win_im,
                           n_real=n_real, scan=k.assoc_scan_pallas)


def diag_esn_states_raw(u, lam_re, lam_im, win_re, win_im,
                        scan=k.diag_scan_pallas):
    """AOT variant of :func:`diag_esn_states` that returns the raw
    split-complex planes ``(s_re, s_im)`` [T, S] *without* the Q-feature
    gather. The gather depends on the per-seed real/complex split
    (``n_real``); deferring it to Rust lets one HLO artifact serve every
    DPG seed of a given reservoir size (see aot.py)."""
    u_re = u @ win_re
    u_im = u @ win_im
    return scan(lam_re, lam_im, u_re, u_im)


def diag_esn_states_raw_assoc(u, lam_re, lam_im, win_re, win_im):
    """Appendix-B parallel-prefix version of :func:`diag_esn_states_raw`."""
    return diag_esn_states_raw(u, lam_re, lam_im, win_re, win_im,
                               scan=k.assoc_scan_pallas)


def diag_esn_forward(u, lam_re, lam_im, win_re, win_im, w_out, b_out,
                     *, n_real: int):
    """Full inference graph: states + readout ``y = X·W_out + b``.

    w_out: [N, D_out] real Q-basis readout weights, b_out: [D_out].
    Returns (y [T, D_out], feats [T, N]).
    """
    feats = diag_esn_states(u, lam_re, lam_im, win_re, win_im, n_real=n_real)
    return feats @ w_out + b_out, feats


def ridge_stats(x, y):
    """Gram accumulation for ridge training: (XᵀX [N',N'], XᵀY [N',D]).

    Accumulates in f32; the Rust side adds the generalized Tikhonov term
    ``α·diag(I, QᵀQ)`` (Theorem 1 (iv)) and Cholesky-solves.
    """
    return x.T @ x, x.T @ y


def diag_esn_step(s_re, s_im, u, lam_re, lam_im, win_re, win_im):
    """Streaming step for serving: one input vector u [D_in] → next state."""
    u_re = u @ win_re
    u_im = u @ win_im
    return k.diag_step_pallas(lam_re, lam_im, s_re, s_im, u_re, u_im)


# ---------------------------------------------------------------------------
# Baseline graph (standard dense linear ESN) — used by the equivalence tests
# and by the fig2 HLO-path timing comparison.
# ---------------------------------------------------------------------------


def dense_esn_states(u, w, w_in):
    """Standard linear reservoir r(t) = r(t-1)·W + u(t)·W_in, O(N²)/step."""
    n = w.shape[0]

    def step(r, u_t):
        r = r @ w + u_t @ w_in
        return r, r

    _, rs = jax.lax.scan(step, jnp.zeros((n,), u.dtype), u)
    return rs
