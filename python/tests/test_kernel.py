"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

This is the CORE correctness signal for the compiled hot path: every state
the Rust runtime ever computes flows through one of these kernels. The
hypothesis sweeps cover shapes (T, N, D_in), dtype edge magnitudes (|λ|→1),
degenerate sizes (T=1, N=1), pure-real and pure-imaginary spectra, and
nonzero initial states for the single-step kernel.
"""

from __future__ import annotations

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import diag_scan as ds
from compile.kernels import ref

RNG = np.random.default_rng


def make_case(seed, T, n, max_mod=0.99):
    """Random split-complex λ inside the disk of radius max_mod + inputs."""
    rng = RNG(seed)
    mod = rng.uniform(0.0, max_mod, n)
    ang = rng.uniform(0.0, 2 * np.pi, n)
    lam_re = (mod * np.cos(ang)).astype(np.float32)
    lam_im = (mod * np.sin(ang)).astype(np.float32)
    u_re = rng.normal(size=(T, n)).astype(np.float32)
    u_im = rng.normal(size=(T, n)).astype(np.float32)
    return lam_re, lam_im, u_re, u_im


def rel_err(a, b):
    a, b = np.asarray(a), np.asarray(b)
    scale = max(1.0, np.abs(b).max())
    return np.abs(a - b).max() / scale


# ---------------------------------------------------------------------------
# references agree with each other (sanity of the oracle itself)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("T,n", [(1, 1), (2, 3), (17, 5), (64, 33)])
def test_refs_mutually_consistent(T, n):
    case = make_case(0, T, n)
    a = ref.diag_scan_ref(*case)
    b = ref.assoc_scan_ref(*case)
    c = ref.diag_scan_closed_form(*case)
    assert rel_err(a[0], b[0]) < 1e-4 and rel_err(a[1], b[1]) < 1e-4
    assert rel_err(a[0], c[0]) < 1e-3 and rel_err(a[1], c[1]) < 1e-3


# ---------------------------------------------------------------------------
# Pallas sequential kernel vs oracle
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    T=st.integers(1, 96),
    n=st.integers(1, 200),
)
def test_diag_scan_pallas_matches_ref(seed, T, n):
    case = make_case(seed, T, n)
    want = ref.diag_scan_ref(*case)
    got = ds.diag_scan_pallas(*case)
    assert rel_err(got[0], want[0]) < 1e-5
    assert rel_err(got[1], want[1]) < 1e-5


@pytest.mark.parametrize("tile", [8, 32, 128, 256])
def test_diag_scan_tile_invariance(tile):
    case = make_case(7, 40, 130)
    want = ref.diag_scan_ref(*case)
    got = ds.diag_scan_pallas(*case, tile=tile)
    assert rel_err(got[0], want[0]) < 1e-5


def test_diag_scan_pure_real_spectrum_keeps_zero_imag():
    rng = RNG(3)
    n, T = 24, 50
    lam_re = rng.uniform(-0.9, 0.9, n).astype(np.float32)
    lam_im = np.zeros(n, np.float32)
    u_re = rng.normal(size=(T, n)).astype(np.float32)
    u_im = np.zeros((T, n), np.float32)
    s_re, s_im = ds.diag_scan_pallas(lam_re, lam_im, u_re, u_im)
    assert np.abs(np.asarray(s_im)).max() == 0.0
    # real slots must follow the scalar recurrence exactly
    want = ref.diag_scan_ref(lam_re, lam_im, u_re, u_im)
    assert rel_err(s_re, want[0]) < 1e-6


def test_diag_scan_unit_modulus_rotation():
    """|λ|=1 pure rotation: |s(t)| of an impulse response stays 1."""
    n = 8
    ang = np.linspace(0.1, 3.0, n)
    lam_re = np.cos(ang).astype(np.float32)
    lam_im = np.sin(ang).astype(np.float32)
    T = 200
    u_re = np.zeros((T, n), np.float32)
    u_im = np.zeros((T, n), np.float32)
    u_re[0] = 1.0
    s_re, s_im = ds.diag_scan_pallas(lam_re, lam_im, u_re, u_im)
    mod = np.sqrt(np.asarray(s_re) ** 2 + np.asarray(s_im) ** 2)
    np.testing.assert_allclose(mod[-1], 1.0, rtol=1e-4)


# ---------------------------------------------------------------------------
# Pallas associative-scan kernel (Appendix B) vs oracle
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    T=st.integers(1, 80),
    n=st.integers(1, 150),
)
def test_assoc_scan_pallas_matches_ref(seed, T, n):
    case = make_case(seed, T, n, max_mod=0.95)
    want = ref.diag_scan_ref(*case)
    got = ds.assoc_scan_pallas(*case)
    assert rel_err(got[0], want[0]) < 1e-4
    assert rel_err(got[1], want[1]) < 1e-4


@pytest.mark.parametrize("T", [1, 2, 3, 4, 7, 8, 9, 31, 32, 33])
def test_assoc_scan_power_of_two_boundaries(T):
    case = make_case(11, T, 20)
    want = ref.diag_scan_ref(*case)
    got = ds.assoc_scan_pallas(*case)
    assert rel_err(got[0], want[0]) < 1e-4


# ---------------------------------------------------------------------------
# single-step kernel
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(1, 300))
def test_diag_step_pallas(seed, n):
    rng = RNG(seed)
    lam_re, lam_im, u_re, u_im = make_case(seed, 1, n)
    s_re = rng.normal(size=n).astype(np.float32)
    s_im = rng.normal(size=n).astype(np.float32)
    o_re, o_im = ds.diag_step_pallas(lam_re, lam_im, s_re, s_im,
                                     u_re[0], u_im[0])
    want_re = s_re * lam_re - s_im * lam_im + u_re[0]
    want_im = s_re * lam_im + s_im * lam_re + u_im[0]
    assert rel_err(o_re, want_re) < 1e-6
    assert rel_err(o_im, want_im) < 1e-6


def test_step_iterated_equals_scan():
    """T applications of the step kernel == one scan kernel call."""
    T, n = 12, 40
    lam_re, lam_im, u_re, u_im = make_case(21, T, n)
    s_re = np.zeros(n, np.float32)
    s_im = np.zeros(n, np.float32)
    for t in range(T):
        s_re, s_im = ds.diag_step_pallas(lam_re, lam_im,
                                         np.asarray(s_re), np.asarray(s_im),
                                         u_re[t], u_im[t])
    want = ds.diag_scan_pallas(lam_re, lam_im, u_re, u_im)
    assert rel_err(s_re, np.asarray(want[0])[-1]) < 1e-5
    assert rel_err(s_im, np.asarray(want[1])[-1]) < 1e-5
