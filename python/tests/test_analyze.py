"""Tests for the HLO structure analyzer (compile.analyze)."""

import tempfile

from compile import analyze, aot


def test_count_ops_basic():
    hlo = """HloModule m
ENTRY main {
  %p0 = f32[4]{0} parameter(0)
  %c = f32[4]{0} constant({1, 2, 3, 4})
  %a = f32[4]{0} add(%p0, %c)
  ROOT %t = (f32[4]{0}) tuple(%a)
}
"""
    ops = analyze.count_ops(hlo)
    assert ops["parameter"] == 1
    assert ops["add"] == 1
    assert ops["tuple"] == 1


def test_cost_model_scaling():
    small = analyze.step_cost_model(10, 1)
    big = analyze.step_cost_model(100, 1)
    # O(N): flops and bytes scale linearly with slots
    assert big["flops_per_step"] == 10 * small["flops_per_step"]
    assert big["bytes_per_step"] == 10 * small["bytes_per_step"]
    # memory-bound: arithmetic intensity well under 1 FLOP/byte × 10
    assert big["arithmetic_intensity"] < 2.0


def test_analyze_dir_on_fresh_artifacts():
    with tempfile.TemporaryDirectory() as d:
        aot.build(d, [("diag_states", dict(T=8, d_in=1, slots=4)),
                      ("readout_apply", dict(T=8, n_feat=5, d_out=1))])
        reports = analyze.analyze_dir(d)
        assert len(reports) == 2
        states = next(r for r in reports if r["kind"] == "diag_states")
        # interpret-mode Pallas must lower to plain HLO
        assert states["custom_calls"] == 0
        assert states["total_instructions"] > 10
        assert "cost_model" in states
