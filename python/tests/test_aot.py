"""AOT pipeline tests: HLO-text emission, manifest integrity, numerics of
the lowered module executed through jax's own runtime (the Rust integration
tests then re-execute the same artifacts through PJRT-via-the-xla-crate)."""

from __future__ import annotations

import json
import os
import tempfile

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import aot, model

RNG = np.random.default_rng


def test_catalogue_names_are_stable():
    assert aot.artifact_name("diag_states", dict(T=32, d_in=2, slots=16)) == \
        "diag_states__T32_d_in2_slots16"
    assert aot.artifact_name("ridge_stats", dict(T=32, n_feat=17, d_out=2)) == \
        "ridge_stats__T32_n_feat17_d_out2"


def test_hlo_text_emission_and_manifest():
    with tempfile.TemporaryDirectory() as d:
        variants = [("readout_apply", dict(T=8, n_feat=5, d_out=1)),
                    ("ridge_stats", dict(T=8, n_feat=5, d_out=1))]
        aot.build(d, variants)
        manifest = json.load(open(os.path.join(d, "manifest.json")))
        assert manifest["format"] == "hlo-text"
        assert len(manifest["artifacts"]) == 2
        for a in manifest["artifacts"]:
            path = os.path.join(d, a["file"])
            text = open(path).read()
            assert text.startswith("HloModule"), text[:40]
            # tuple return convention (rust always unwraps a tuple)
            assert "ROOT" in text


def test_lowered_diag_states_runs_and_matches_model():
    """Execute the exact lowered computation jax-side and compare to the
    eager graph — guards against lowering-time shape or layout bugs."""
    T, d_in, slots = 16, 2, 8
    lowered = aot.lower_diag_states(T, d_in, slots)
    compiled = lowered.compile()
    rng = RNG(0)
    u = rng.normal(size=(T, d_in)).astype(np.float32)
    lam_re = rng.uniform(-0.9, 0.9, slots).astype(np.float32)
    lam_im = rng.uniform(-0.5, 0.5, slots).astype(np.float32)
    win_re = rng.normal(size=(d_in, slots)).astype(np.float32)
    win_im = rng.normal(size=(d_in, slots)).astype(np.float32)
    got = compiled(u, lam_re, lam_im, win_re, win_im)
    want = model.diag_esn_states_raw(u, lam_re, lam_im, win_re, win_im)
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(want[0]),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(got[1]), np.asarray(want[1]),
                               rtol=1e-5, atol=1e-5)


def test_hlo_text_has_no_mosaic_custom_call():
    """interpret=True must lower Pallas to plain HLO the CPU client can run."""
    lowered = aot.lower_diag_states(8, 1, 4)
    text = aot.to_hlo_text(lowered)
    assert "custom-call" not in text or "tpu" not in text.lower()
    lowered = aot.lower_diag_states_assoc(8, 1, 4)
    text = aot.to_hlo_text(lowered)
    assert "mosaic" not in text.lower()


@pytest.mark.parametrize("kind,dims", aot.DEFAULT_VARIANTS[6:])
def test_quick_variants_all_lower(kind, dims):
    lower_fn, _ = aot.CATALOGUE[kind]
    text = aot.to_hlo_text(lower_fn(**dims))
    assert text.startswith("HloModule")
    assert len(text) > 100
