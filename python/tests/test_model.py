"""L2 graph correctness: model.py composition vs reference + EWT equivalence.

The headline mathematical claim of the paper (Theorem 1) is that the
diagonalized dynamics EXACTLY reproduce the standard dense dynamics when
(Λ, P) come from a true eigendecomposition of W. We verify that here in
float64 through numpy's eig — this is the python-side twin of the Rust
integration test that uses our own from-scratch eigensolver.
"""

from __future__ import annotations

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from compile import model
from compile.kernels import ref

RNG = np.random.default_rng


def rel_err(a, b):
    a, b = np.asarray(a), np.asarray(b)
    scale = max(1.0, np.abs(b).max())
    return np.abs(a - b).max() / scale


def random_dpg_like(seed, n_real, n_cpx, d_in, sr=0.9):
    """Split-complex (λ, [W_in]_P) with the shared slot convention."""
    rng = RNG(seed)
    n_slots = n_real + n_cpx
    lam_re = np.zeros(n_slots, np.float32)
    lam_im = np.zeros(n_slots, np.float32)
    lam_re[:n_real] = rng.uniform(-sr, sr, n_real)
    mod = sr * np.sqrt(rng.uniform(0, 1, n_cpx))
    ang = rng.uniform(0, np.pi, n_cpx)
    lam_re[n_real:] = mod * np.cos(ang)
    lam_im[n_real:] = mod * np.sin(ang)
    win_re = rng.normal(size=(d_in, n_slots)).astype(np.float32)
    win_im = np.concatenate(
        [np.zeros((d_in, n_real)), rng.normal(size=(d_in, n_cpx))],
        axis=1).astype(np.float32)
    return lam_re, lam_im, win_re, win_im


# ---------------------------------------------------------------------------
# graph composition vs reference pieces
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    T=st.integers(1, 48),
    n_real=st.integers(0, 6),
    n_cpx=st.integers(1, 20),
    d_in=st.integers(1, 3),
)
def test_states_graph_matches_reference(seed, T, n_real, n_cpx, d_in):
    lam_re, lam_im, win_re, win_im = random_dpg_like(seed, n_real, n_cpx, d_in)
    rng = RNG(seed + 1)
    u = rng.normal(size=(T, d_in)).astype(np.float32)

    feats = model.diag_esn_states(u, lam_re, lam_im, win_re, win_im,
                                  n_real=n_real)
    ur, ui = ref.project_input_ref(u, win_re, win_im)
    s_re, s_im = ref.diag_scan_ref(lam_re, lam_im, ur, ui)
    want = ref.qbasis_features_ref(s_re, s_im, n_real)
    assert feats.shape == (T, n_real + 2 * n_cpx)
    assert rel_err(feats, want) < 1e-5


def test_states_raw_plus_rust_style_gather_equals_states():
    """The AOT contract: raw planes + external gather == fused graph."""
    lam_re, lam_im, win_re, win_im = random_dpg_like(5, 4, 10, 2)
    u = RNG(6).normal(size=(30, 2)).astype(np.float32)
    fused = model.diag_esn_states(u, lam_re, lam_im, win_re, win_im, n_real=4)
    s_re, s_im = model.diag_esn_states_raw(u, lam_re, lam_im, win_re, win_im)
    gathered = ref.qbasis_features_ref(s_re, s_im, 4)
    assert rel_err(gathered, fused) < 1e-6


def test_assoc_raw_matches_seq_raw():
    lam_re, lam_im, win_re, win_im = random_dpg_like(9, 3, 12, 1)
    u = RNG(10).normal(size=(40, 1)).astype(np.float32)
    a = model.diag_esn_states_raw(u, lam_re, lam_im, win_re, win_im)
    b = model.diag_esn_states_raw_assoc(u, lam_re, lam_im, win_re, win_im)
    assert rel_err(a[0], b[0]) < 1e-4
    assert rel_err(a[1], b[1]) < 1e-4


def test_forward_graph_readout():
    n_real, n_cpx, d_out = 2, 7, 3
    n_feat = n_real + 2 * n_cpx
    lam_re, lam_im, win_re, win_im = random_dpg_like(12, n_real, n_cpx, 1)
    rng = RNG(13)
    u = rng.normal(size=(25, 1)).astype(np.float32)
    w_out = rng.normal(size=(n_feat, d_out)).astype(np.float32)
    b_out = rng.normal(size=(d_out,)).astype(np.float32)
    y, feats = model.diag_esn_forward(u, lam_re, lam_im, win_re, win_im,
                                      w_out, b_out, n_real=n_real)
    assert rel_err(y, np.asarray(feats) @ w_out + b_out) < 1e-5


def test_ridge_stats_graph():
    rng = RNG(14)
    x = rng.normal(size=(50, 12)).astype(np.float32)
    y = rng.normal(size=(50, 2)).astype(np.float32)
    xtx, xty = model.ridge_stats(x, y)
    assert rel_err(xtx, x.T @ x) < 1e-4
    assert rel_err(xty, x.T @ y) < 1e-4


def test_step_graph_matches_scan_row():
    lam_re, lam_im, win_re, win_im = random_dpg_like(15, 2, 8, 2)
    rng = RNG(16)
    u = rng.normal(size=(1, 2)).astype(np.float32)
    s_re = rng.normal(size=10).astype(np.float32)
    s_im = rng.normal(size=10).astype(np.float32)
    o_re, o_im = model.diag_esn_step(s_re, s_im, u[0], lam_re, lam_im,
                                     win_re, win_im)
    ur, ui = u @ win_re, u @ win_im
    want_re = s_re * lam_re - s_im * lam_im + ur[0]
    want_im = s_re * lam_im + s_im * lam_re + ui[0]
    assert rel_err(o_re, want_re) < 1e-5
    assert rel_err(o_im, want_im) < 1e-5


# ---------------------------------------------------------------------------
# Theorem 1 / EWT: diagonal path ≡ dense path through a real eigendecomp
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed,n", [(0, 8), (1, 16), (2, 30)])
def test_ewt_equivalence_with_true_eigendecomposition(seed, n):
    """r(t) (dense, O(N²)) == 1ᵀ-recombined diagonal states (O(N))."""
    rng = RNG(seed)
    w = rng.normal(size=(n, n)) / np.sqrt(n)
    d_in = 2
    w_in = rng.normal(size=(d_in, n))
    T = 40
    u = rng.normal(size=(T, d_in))

    # dense reference in f64
    r = np.zeros(n)
    dense_states = np.zeros((T, n))
    for t in range(T):
        r = r @ w + u[t] @ w_in
        dense_states[t] = r

    # diagonalize (row-vector convention: r(t) = r(t-1) W means states
    # transform as [r]_P = r P with [W]_P = P^{-1} W P — we need right-
    # multiplication structure: r W = r P D P^{-1} requires W = P D P^{-1})
    lam, p = np.linalg.eig(w)
    # [W_in]_P = W_in P ; states s(t) = r(t) P
    win_p = w_in @ p
    s = np.zeros((T, n), complex)
    cur = np.zeros(n, complex)
    for t in range(T):
        cur = cur * lam + u[t] @ win_p
        s[t] = cur
    # back: r(t) = s(t) P^{-1}
    rec = (s @ np.linalg.inv(p)).real
    assert rel_err(rec, dense_states) < 1e-8

    # and the split-complex kernel reproduces the same complex states
    got_re, got_im = ref.diag_scan_ref(
        lam.real.astype(np.float32), lam.imag.astype(np.float32),
        (u @ win_p).real.astype(np.float32),
        (u @ win_p).imag.astype(np.float32))
    assert rel_err(got_re, s.real) < 1e-3
    assert rel_err(got_im, s.imag) < 1e-3


def test_dense_states_graph_matches_numpy():
    rng = RNG(30)
    n, d_in, T = 12, 2, 20
    w = (rng.normal(size=(n, n)) / np.sqrt(n)).astype(np.float32)
    w_in = rng.normal(size=(d_in, n)).astype(np.float32)
    u = rng.normal(size=(T, d_in)).astype(np.float32)
    got = model.dense_esn_states(u, w, w_in)
    r = np.zeros(n, np.float32)
    want = np.zeros((T, n), np.float32)
    for t in range(T):
        r = r @ w + u[t] @ w_in
        want[t] = r
    assert rel_err(got, want) < 1e-4
