#!/usr/bin/env bash
# Tier-1 verification + perf snapshot in one command:
#   scripts/verify.sh
# Runs the release build, the full test suite, the plain-kernel A/B of
# the batched lane engine (the scalar twin of the chunked/branchless
# kernels must stay bit-identical), the chaos suite under
# `--features fault-inject` (deterministic sweeper panics, forced short
# writes, budget exhaustion, EMFILE accept storms, live-migration
# panics, standby promotion after a primary SIGKILL, cluster failover
# with SIGKILLed group members and `moved` redirects, torn standby
# delta frames, and forced deadline/admission refusals — every
# degradation must be a typed error, never a hang), and the quick
# reservoir bench (precision-ladder, sharded-serving, event-loop wire,
# fused/online training, the PR6 checkpoint/restore + failover-storm
# rows, the PR7 lane-mobility rows, the PR8 cluster-failover storm:
# kill → detect → promote → redirect, the PR9 multi-tenant rows:
# registry mint throughput + 128 distinct models through one sweeper,
# and the PR10 wire-path rows: requests/sec at pipelined saturation
# for JSON vs binary frames at P ∈ {1, 2, 4} poll threads),
# persisting the machine-readable perf snapshot as BENCH_pr10.json at
# the repo root — the committed perf-trajectory artifact
# (BENCH_reservoir_run.json is kept as an uncommitted working copy for
# tooling that greps the legacy name).
# Fails if the precision, sharding, event-loop, training,
# fault-tolerance, lane-mobility, multi-tenant, or wire-path rows are
# missing, non-finite, or report zero throughput — or if the PR10
# acceptance gates fail: binary frames must beat JSON on requests/sec
# at P=1, and P=4 poll threads must add rps over P=1.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

echo "== cargo test -q --features plain-kernel --lib reservoir::batch (A/B twin) =="
cargo test -q --features plain-kernel --lib reservoir::batch

echo "== cargo test -q --features fault-inject --test chaos (chaos suite) =="
cargo test -q --features fault-inject --test chaos

echo "== cargo bench --bench reservoir_run --features fault-inject -- --quick --json BENCH_pr10.json =="
# fault-inject makes the failover-storm row use REAL contained sweeper
# panics (without it the row still exists via teardown/reconnect cycles)
cargo bench --bench reservoir_run --features fault-inject -- --quick --json BENCH_pr10.json
cp BENCH_pr10.json BENCH_reservoir_run.json

echo "== bench sanity: precision/sharded/evloop/training/failover rows present, finite, non-zero =="
if command -v python3 >/dev/null 2>&1; then
  python3 - <<'EOF'
import json, math, sys

doc = json.load(open("BENCH_pr10.json"))
rows = {r.get("name"): r for r in doc.get("results", [])}
required = [
    "f32_batch8_N1000", "f64_batch8_N1000",
    "f32_batch64_N1000", "f64_batch64_N1000",
    "derived_precision_batch8_N1000", "derived_precision_batch64_N1000",
    "sharded1_batch64_N1000", "sharded2_batch64_N1000",
    "sharded4_batch64_N1000", "derived_sharded_batch64_N1000",
    "evloop_idle128_predict16_N1000",
    "evloop_mixed_stream16_predict16_N1000",
    "derived_evloop_N1000",
    "train_fused_f64_N1000", "train_fused_f32_N1000",
    "train_online_wire_N1000", "derived_train_N1000",
    "checkpoint_restore_N1000", "derived_failover_N1000",
    "migrate_lane_N1000", "standby_delta_N1000", "derived_rebalance_N1000",
    "failover_cluster_N1000",
    "create_model_N1000", "tenant128_batch64_N1000",
    "derived_tenant128_batch64_N1000",
    "wirepath_rps_p1_N1000_json", "wirepath_rps_p1_N1000_binary",
    "wirepath_rps_p2_N1000_json", "wirepath_rps_p2_N1000_binary",
    "wirepath_rps_p4_N1000_json", "wirepath_rps_p4_N1000_binary",
    "derived_wirepath_N1000",
]
for name in required:
    if name not in rows:
        sys.exit(f"FAIL: missing bench row {name}")
for name, row in rows.items():
    for key, val in row.items():
        if isinstance(val, float):
            if not math.isfinite(val):
                sys.exit(f"FAIL: non-finite {key} in row {name}: {val}")
            if key.endswith(("steps_per_sec", "rows_per_sec")) and val <= 0:
                sys.exit(f"FAIL: zero throughput {key} in row {name}")
            if key in ("median_s", "restore_round_trip_sec") and val <= 0:
                sys.exit(f"FAIL: zero-time bench row {name}")
for b in (8, 64):
    d = rows[f"derived_precision_batch{b}_N1000"]
    print(f"  batch{b}: f32 {d['f32_steps_per_sec']:.3e} steps/s, "
          f"f64 {d['f64_steps_per_sec']:.3e} steps/s, "
          f"speedup {d['f32_speedup']:.2f}x")
d = rows["derived_sharded_batch64_N1000"]
print(f"  sharded: 1x {d['sharded1_steps_per_sec']:.3e} steps/s, "
      f"2 shards {d['speedup_2_shards']:.2f}x, "
      f"4 shards {d['speedup_4_shards']:.2f}x")
d = rows["derived_evloop_N1000"]
print(f"  evloop: idle-loaded predicts {d['idle_predict_steps_per_sec']:.3e} steps/s, "
      f"mixed {d['mixed_steps_per_sec']:.3e} steps/s "
      f"({int(d['idle_conns'])} idle conns)")
d = rows["derived_train_N1000"]
print(f"  training: fused f64 {d['f64_rows_per_sec']:.3e} rows/s, "
      f"f32 {d['f32_rows_per_sec']:.3e} rows/s ({d['f32_over_f64']:.2f}x), "
      f"online wire {d['online_wire_rows_per_sec']:.3e} rows/s")
d = rows["derived_failover_N1000"]
real = "real sweeper panics" if d.get("real_sweeper_panics") else "reconnect cycles"
print(f"  failover: restore round trip {d['restore_round_trip_sec']:.3e}s, "
      f"storm {d['storm_steps_per_sec']:.3e} steps/s "
      f"across {int(d['cycles'])} failovers ({real})")
mig = rows["migrate_lane_N1000"]
delta = rows["standby_delta_N1000"]
d = rows["derived_rebalance_N1000"]
print(f"  mobility: migrate {mig['median_s']:.3e}s, "
      f"standby delta {delta['median_s']:.3e}s, "
      f"rebalance storm {d['storm_steps_per_sec']:.3e} steps/s "
      f"({int(d['lanes_migrated'])} lane move(s))")
d = rows["failover_cluster_N1000"]
print(f"  cluster: failover storm {d['storm_steps_per_sec']:.3e} steps/s, "
      f"outage {d['outage_ms']:.1f}ms "
      f"({int(d['lanes_promoted'])} lane(s) promoted via redirects)")
d = rows["derived_tenant128_batch64_N1000"]
if d["create_models_per_sec"] <= 0:
    sys.exit("FAIL: zero create_model throughput in derived_tenant128_batch64_N1000")
print(f"  tenants: mint {d['create_models_per_sec']:.3e} models/s, "
      f"128-model sweep {d['tenant_steps_per_sec']:.3e} steps/s "
      f"({d['ratio_vs_single_model']:.2f}x of single-model)")
d = rows["derived_wirepath_N1000"]
print(f"  wirepath: json {d['json_rps_p1']:.3e} req/s, "
      f"binary {d['binary_rps_p1']:.3e} req/s at P=1 "
      f"({d['binary_over_json_p1']:.2f}x) | scaling P=4/P=1: "
      f"json {d['json_scaling_p4']:.2f}x, binary {d['binary_scaling_p4']:.2f}x")
for key in ("json_rps_p1", "json_rps_p2", "json_rps_p4",
            "binary_rps_p1", "binary_rps_p2", "binary_rps_p4"):
    if d[key] <= 0:
        sys.exit(f"FAIL: zero rps in derived_wirepath_N1000: {key}")
if d["binary_rps_p1"] <= d["json_rps_p1"]:
    sys.exit("FAIL: binary framing must beat JSON on requests/sec at P=1 "
             f"(binary {d['binary_rps_p1']:.3e} <= json {d['json_rps_p1']:.3e})")
if max(d["json_scaling_p4"], d["binary_scaling_p4"]) <= 1.0:
    sys.exit("FAIL: P=4 poll threads must add rps over P=1 "
             f"(json {d['json_scaling_p4']:.2f}x, "
             f"binary {d['binary_scaling_p4']:.2f}x)")
print("bench rows OK")
EOF
else
  # minimal fallback when python3 is absent: rows exist, nothing NaN/inf
  for row in f32_batch8_N1000 f64_batch8_N1000 f32_batch64_N1000 \
             f64_batch64_N1000 sharded1_batch64_N1000 \
             sharded2_batch64_N1000 sharded4_batch64_N1000 \
             derived_sharded_batch64_N1000 \
             evloop_idle128_predict16_N1000 \
             evloop_mixed_stream16_predict16_N1000 derived_evloop_N1000 \
             train_fused_f64_N1000 train_fused_f32_N1000 \
             train_online_wire_N1000 derived_train_N1000 \
             checkpoint_restore_N1000 derived_failover_N1000 \
             migrate_lane_N1000 standby_delta_N1000 \
             derived_rebalance_N1000 failover_cluster_N1000 \
             create_model_N1000 tenant128_batch64_N1000 \
             derived_tenant128_batch64_N1000 \
             wirepath_rps_p1_N1000_json wirepath_rps_p1_N1000_binary \
             wirepath_rps_p2_N1000_json wirepath_rps_p2_N1000_binary \
             wirepath_rps_p4_N1000_json wirepath_rps_p4_N1000_binary \
             derived_wirepath_N1000; do
    grep -q "\"$row\"" BENCH_pr10.json \
      || { echo "FAIL: missing bench row $row"; exit 1; }
  done
  if grep -qiE '(nan|inf)' BENCH_pr10.json; then
    echo "FAIL: non-finite value in BENCH_pr10.json"; exit 1
  fi
  # the JSON writer prints integral values without decimals, so a zero
  # throughput is exactly `0` before the comma/EOL (0.97 must NOT match)
  if grep -qE '(steps|rows)_per_sec": *(0(,|$)|-)' BENCH_pr10.json; then
    echo "FAIL: zero throughput row in BENCH_pr10.json"; exit 1
  fi
  echo "bench rows OK (grep fallback)"
fi

echo "verify OK"
