#!/usr/bin/env bash
# Tier-1 verification + perf snapshot in one command:
#   scripts/verify.sh
# Runs the release build, the full test suite, and the quick reservoir
# bench, leaving a machine-readable perf snapshot in
# BENCH_reservoir_run.json (the perf-trajectory artifact).
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

echo "== cargo bench --bench reservoir_run -- --quick --json BENCH_reservoir_run.json =="
cargo bench --bench reservoir_run -- --quick --json BENCH_reservoir_run.json

echo "verify OK"
