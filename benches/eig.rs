//! Bench: the diagonalization pre-processing costs — eigenvalues only
//! (spectral-radius scaling, Sim distribution) vs the full
//! eigendecomposition (EWT/EET) vs DPG generation which avoids both.
//! Run: `cargo bench --bench eig [-- --quick]`

use linear_reservoir::bench::{bench_oneshot, BenchConfig};
use linear_reservoir::linalg::{eig, eigenvalues, Mat};
use linear_reservoir::reservoir::{DiagonalEsn, EsnConfig};
use linear_reservoir::rng::Pcg64;
use linear_reservoir::spectral::golden::{golden_spectrum, GoldenParams};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let _ = BenchConfig::default();
    let sizes: Vec<usize> = if quick {
        vec![50, 100]
    } else {
        vec![50, 100, 200, 400]
    };
    let reps = if quick { 1 } else { 2 };

    for &n in &sizes {
        let mut rng = Pcg64::seeded(4);
        let mut a = Mat::randn(n, n, &mut rng);
        a.scale(1.0 / (n as f64).sqrt());

        let r1 = bench_oneshot(&format!("eigenvalues_N{n}"), reps, || {
            eigenvalues(&a)
        });
        let r2 = bench_oneshot(&format!("full_eig_N{n}"), reps, || eig(&a));
        let config = EsnConfig::default().with_n(n).with_seed(5);
        let r3 = bench_oneshot(&format!("dpg_golden_N{n}"), reps, || {
            let mut g = Pcg64::new(5, 120);
            let spec = golden_spectrum(n, GoldenParams { sr: 1.0, sigma: 0.2 }, &mut g);
            DiagonalEsn::from_dpg(spec, &config, &mut g)
        });
        println!("{}", r1.report());
        println!("{}", r2.report());
        println!("{}", r3.report());
        println!(
            "  DPG avoids the O(N³) eig: {:.0}x cheaper than full eig\n",
            r2.per_iter.median / r3.per_iter.median
        );
    }
}
