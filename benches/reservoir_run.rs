//! Bench: full-sequence reservoir runs (T×N trajectories) — standard
//! dense vs sparse vs diagonal engines (Table 2's compute budget), plus
//! the serving-path rows: fused streaming readout vs materialize-then-
//! matmul, the batched multi-sequence engine vs the one-sequence-at-
//! a-time loop (states/sec across the batch), the precision ladder:
//! f32 vs f64 SoA lane engines at the serving point (N=1000, B∈{8,64}),
//! the shard-per-core serving rows: aggregate predict throughput
//! through a ShardedFront at 1/2/4 shards (B=64 concurrent requests),
//! the event-loop wire rows: pipelined predict and mixed
//! stream/predict throughput over TCP through the epoll readiness loop
//! while 128 idle streaming connections sit parked on it (thread-free),
//! and the training-stack rows: fused streaming Gram accumulation
//! (scan → GramAcc) at f64 and f32, plus online `train` ops over the
//! wire onto a hub lane (rows/sec, with a commit→stream close-out).
//!
//! Run: `cargo bench --bench reservoir_run [-- --quick] [--json <path>]`
//! `--json` writes machine-readable results (bench rows + derived
//! throughputs), e.g. `--json BENCH_reservoir_run.json`.

use linear_reservoir::bench::{bench, BenchConfig, BenchResult};
use linear_reservoir::coordinator::WorkerPool;
use linear_reservoir::linalg::Mat;
use linear_reservoir::readout::Readout;
use linear_reservoir::reservoir::parallel::{
    run_parallel_batch_train_prec, TrainSpec,
};
use linear_reservoir::reservoir::{
    BatchEsn, DiagonalEsn, EsnConfig, QBasisEsn, StandardEsn,
};
use linear_reservoir::rng::Pcg64;
use linear_reservoir::server::{
    serve_on, serve_on_opts, Client, Model, ModelRecipe, ModelRegistry,
    ServeOpts, ShardedFront,
};
use linear_reservoir::spectral::uniform::uniform_spectrum;
use linear_reservoir::util::json::Json;

use std::sync::Arc;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let quick = args.iter().any(|a| a == "--quick");
    let json_path = args
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| args.get(i + 1))
        .cloned();
    let cfg = if quick {
        BenchConfig::quick()
    } else {
        BenchConfig::default()
    };
    let t_len = 1000;
    let batch_b = 8;
    let sizes: Vec<usize> = if quick {
        vec![100, 400]
    } else {
        vec![100, 200, 400, 800, 1600]
    };
    let mut rng = Pcg64::seeded(1);
    let u = Mat::randn(t_len, 1, &mut rng);
    let u_batch = Mat::randn(t_len, batch_b, &mut rng);

    let mut rows: Vec<Json> = Vec::new();
    let push = |rows: &mut Vec<Json>, r: &BenchResult| {
        println!("{}", r.report());
        rows.push(r.to_json());
    };

    println!("full-sequence runs, T = {t_len}");
    for &n in &sizes {
        let config = EsnConfig::default().with_n(n).with_seed(2);
        let dense = StandardEsn::generate(config.with_connectivity(1.0));
        let sparse = StandardEsn::generate(config.with_connectivity(0.05));
        let mut gen_rng = Pcg64::new(2, 110);
        let spec = uniform_spectrum(n, 0.9, &mut gen_rng);
        let diag = DiagonalEsn::from_dpg(spec, &config, &mut gen_rng);
        let qbasis = QBasisEsn::from_diagonal(&diag);
        let readout = Readout {
            w: Mat::randn(n, 1, &mut gen_rng),
            b: vec![0.1],
        };

        let r1 = bench(&format!("dense_N{n}"), cfg, || dense.run(&u));
        let r2 = bench(&format!("sparse05_N{n}"), cfg, || sparse.run(&u));
        let r3 = bench(&format!("diagonal_N{n}"), cfg, || diag.run(&u));
        let r4 = bench(&format!("qbasis_N{n}"), cfg, || qbasis.run(&u));
        push(&mut rows, &r1);
        push(&mut rows, &r2);
        push(&mut rows, &r3);
        push(&mut rows, &r4);
        println!(
            "  speedup qbasis vs dense: {:.1}x, vs sparse(5%): {:.1}x, vs split-plane diag: {:.2}x\n",
            r1.per_iter.median / r4.per_iter.median,
            r2.per_iter.median / r4.per_iter.median,
            r3.per_iter.median / r4.per_iter.median
        );

        // --- fused streaming readout vs materialize-then-matmul ---------
        let r5 = bench(&format!("fused_readout_N{n}"), cfg, || {
            qbasis.run_readout(&u, &readout)
        });
        let r6 = bench(&format!("materialized_readout_N{n}"), cfg, || {
            readout.predict(&qbasis.run(&u))
        });
        push(&mut rows, &r5);
        push(&mut rows, &r6);

        // --- batched engine vs one-sequence-at-a-time serving loop ------
        let singles: Vec<Mat> = (0..batch_b)
            .map(|lane| {
                let col: Vec<f64> =
                    (0..t_len).map(|t| u_batch[(t, lane)]).collect();
                Mat::from_rows(t_len, 1, &col)
            })
            .collect();
        let r7 = bench(&format!("seq_loop_B{batch_b}_N{n}"), cfg, || {
            for u1 in &singles {
                std::hint::black_box(qbasis.run_readout(u1, &readout));
            }
        });
        let mut engine = BatchEsn::new(qbasis.clone(), batch_b);
        let r8 = bench(&format!("batch{batch_b}_N{n}"), cfg, || {
            engine.reset();
            engine.run_readout(&u_batch, &readout)
        });
        push(&mut rows, &r7);
        push(&mut rows, &r8);

        let total_states = (batch_b * t_len) as f64;
        let seq_sps = total_states / r7.per_iter.median;
        let batch_sps = total_states / r8.per_iter.median;
        let speedup = r7.per_iter.median / r8.per_iter.median;
        println!(
            "  fused vs materialized: {:.2}x | batch{batch_b}: {:.3e} states/s \
             vs seq-loop {:.3e} states/s → {:.2}x\n",
            r6.per_iter.median / r5.per_iter.median,
            batch_sps,
            seq_sps,
            speedup
        );
        rows.push(Json::obj(vec![
            ("name", Json::Str(format!("derived_batch{batch_b}_N{n}"))),
            ("n_reservoir", Json::Num(n as f64)),
            ("batch", Json::Num(batch_b as f64)),
            ("t", Json::Num(t_len as f64)),
            ("seq_states_per_sec", Json::Num(seq_sps)),
            ("batched_states_per_sec", Json::Num(batch_sps)),
            ("batched_speedup", Json::Num(speedup)),
            (
                "fused_vs_materialized_speedup",
                Json::Num(r6.per_iter.median / r5.per_iter.median),
            ),
        ]));
    }

    // --- precision ladder: f32 SoA lanes vs the f64 oracle --------------
    // The step is memory-bound (Corollary 2): halving the element width
    // should roughly double steps/sec. Rows run in BOTH quick and full
    // mode — they are the acceptance artifact for the f32 lane engine.
    {
        let n = 1000;
        println!("precision ladder, N = {n}, T = {t_len}");
        let config = EsnConfig::default().with_n(n).with_seed(2);
        let mut gen_rng = Pcg64::new(7, 111);
        let spec = uniform_spectrum(n, 0.9, &mut gen_rng);
        let diag = DiagonalEsn::from_dpg(spec, &config, &mut gen_rng);
        let qbasis = QBasisEsn::from_diagonal(&diag);
        let readout = Readout {
            w: Mat::randn(n, 1, &mut gen_rng),
            b: vec![0.1],
        };
        for &bsz in &[8usize, 64] {
            let u_b = Mat::randn(t_len, bsz, &mut rng);
            let mut e64 = BatchEsn::new(qbasis.clone(), bsz);
            let r64 = bench(&format!("f64_batch{bsz}_N{n}"), cfg, || {
                e64.reset();
                e64.run_readout(&u_b, &readout)
            });
            let mut e32 = BatchEsn::<f32>::with_precision(qbasis.clone(), bsz);
            let r32 = bench(&format!("f32_batch{bsz}_N{n}"), cfg, || {
                e32.reset();
                e32.run_readout(&u_b, &readout)
            });
            push(&mut rows, &r64);
            push(&mut rows, &r32);
            let steps = (t_len * bsz) as f64;
            let f64_sps = steps / r64.per_iter.median;
            let f32_sps = steps / r32.per_iter.median;
            let speedup = r64.per_iter.median / r32.per_iter.median;
            println!(
                "  B={bsz}: f32 {:.3e} steps/s vs f64 {:.3e} steps/s → {:.2}x\n",
                f32_sps, f64_sps, speedup
            );
            rows.push(Json::obj(vec![
                (
                    "name",
                    Json::Str(format!("derived_precision_batch{bsz}_N{n}")),
                ),
                ("n_reservoir", Json::Num(n as f64)),
                ("batch", Json::Num(bsz as f64)),
                ("t", Json::Num(t_len as f64)),
                ("f64_steps_per_sec", Json::Num(f64_sps)),
                ("f32_steps_per_sec", Json::Num(f32_sps)),
                ("f32_speedup", Json::Num(speedup)),
            ]));
        }
    }

    // --- shard-per-core serving: aggregate predict throughput -----------
    // B = 64 concurrent stateless predicts dealt across S sweepers, each
    // coalescing its share into masked batch sweeps. One sweeper is
    // single-core by design, so aggregate steps/sec should scale with
    // shard count until the cores (or memory bandwidth) run out; on a
    // 1-vCPU container the rows still exist but the scaling is ≈1x.
    let mut sharded1_sps = f64::NAN;
    {
        let n = 1000;
        let bsz = 64usize;
        println!("sharded serving, N = {n}, B = {bsz}, T = {t_len}");
        let config = EsnConfig::default().with_n(n).with_seed(2);
        let mut gen_rng = Pcg64::new(9, 112);
        let spec = uniform_spectrum(n, 0.9, &mut gen_rng);
        let diag = DiagonalEsn::from_dpg(spec, &config, &mut gen_rng);
        let readout = Readout {
            w: Mat::randn(n, 1, &mut gen_rng),
            b: vec![0.1],
        };
        let model = Arc::new(Model::new(diag, readout));
        let inputs: Vec<Vec<f64>> = (0..bsz)
            .map(|_| Mat::randn(t_len, 1, &mut rng).data().to_vec())
            .collect();
        let mut sps = Vec::new();
        for &s in &[1usize, 2, 4] {
            let front = ShardedFront::start(Arc::clone(&model), s);
            let r = bench(&format!("sharded{s}_batch{bsz}_N{n}"), cfg, || {
                // submit the whole burst before collecting, so each
                // shard's sweeper coalesces its share into batch sweeps
                let replies: Vec<_> = inputs
                    .iter()
                    .map(|i| {
                        front.predict_async(i.clone()).expect("sweeper alive")
                    })
                    .collect();
                for rx in replies {
                    std::hint::black_box(rx.recv().unwrap());
                }
            });
            front.shutdown();
            let steps = (bsz * t_len) as f64;
            let shard_sps = steps / r.per_iter.median;
            println!("  shards={s}: {:.3e} aggregate steps/s", shard_sps);
            push(&mut rows, &r);
            sps.push(shard_sps);
        }
        let base = sps[0];
        sharded1_sps = base;
        println!(
            "  scaling: 2 shards {:.2}x, 4 shards {:.2}x (vs 1 shard)\n",
            sps[1] / base,
            sps[2] / base
        );
        rows.push(Json::obj(vec![
            (
                "name",
                Json::Str(format!("derived_sharded_batch{bsz}_N{n}")),
            ),
            ("n_reservoir", Json::Num(n as f64)),
            ("batch", Json::Num(bsz as f64)),
            ("t", Json::Num(t_len as f64)),
            ("sharded1_steps_per_sec", Json::Num(sps[0])),
            ("sharded2_steps_per_sec", Json::Num(sps[1])),
            ("sharded4_steps_per_sec", Json::Num(sps[2])),
            ("speedup_2_shards", Json::Num(sps[1] / base)),
            ("speedup_4_shards", Json::Num(sps[2] / base)),
        ]));
    }

    // --- multi-tenant registry serving ----------------------------------
    // `create_model_N1000`: registry mint throughput (models/sec). One
    // iteration mints a batch of DISTINCT N=1000 recipes through the
    // registry and deletes them again, so the table never grows across
    // iterations (a delete is a map remove; the DPG mint dominates).
    // `tenant128_batch64_N1000`: 128 distinct registered models served
    // by ONE sweeper — bursts of 64 concurrent `predict_async_model`
    // requests fan out over the whole tenant set, so every sweep is a
    // per-model-grouped masked sweep. The derived ratio against
    // `sharded1_batch64_N1000` (same B, same N, one model) prices model
    // diversity itself: lost coalescing, per-model engine checkout.
    {
        let n = 1000;
        println!("multi-tenant registry, N = {n}");
        let config = EsnConfig::default().with_n(n).with_seed(2);
        let mut gen_rng = Pcg64::new(11, 113);
        let spec = uniform_spectrum(n, 0.9, &mut gen_rng);
        let diag = DiagonalEsn::from_dpg(spec, &config, &mut gen_rng);
        let readout = Readout {
            w: Mat::randn(n, 1, &mut gen_rng),
            b: vec![0.1],
        };
        let base_model = Arc::new(Model::new(diag, readout));

        let registry = ModelRegistry::new(Arc::clone(&base_model), usize::MAX);
        let mint_batch = 32usize;
        let r = bench(&format!("create_model_N{n}"), cfg, || {
            let ids: Vec<_> = (0..mint_batch)
                .map(|i| {
                    let recipe =
                        ModelRecipe::new(1000 + i as u64, n, 0.9, "uniform")
                            .unwrap();
                    registry.create(&recipe).expect("unlimited budget").0
                })
                .collect();
            for id in ids {
                registry.delete(id).unwrap();
            }
        });
        push(&mut rows, &r);
        let models_per_sec = mint_batch as f64 / r.per_iter.median;
        println!("  create_model: {models_per_sec:.3e} models/s");

        let tenants = 128usize;
        let bsz = 64usize;
        let registry =
            Arc::new(ModelRegistry::new(Arc::clone(&base_model), tenants));
        let ids: Vec<_> = (0..tenants)
            .map(|i| {
                let recipe =
                    ModelRecipe::new(2000 + i as u64, n, 0.9, "uniform")
                        .unwrap();
                registry.create(&recipe).unwrap().0
            })
            .collect();
        let front = ShardedFront::start_registry(
            Arc::clone(&base_model),
            Some(registry),
            1,
            0,
            usize::MAX,
            false,
        );
        let inputs: Vec<Vec<f64>> = (0..bsz)
            .map(|_| Mat::randn(t_len, 1, &mut rng).data().to_vec())
            .collect();
        let r = bench(&format!("tenant{tenants}_batch{bsz}_N{n}"), cfg, || {
            // two waves of B=64 cover all 128 tenants per iteration;
            // every request names a different model, so each sweep is
            // maximally mixed
            for wave in 0..2 {
                let replies: Vec<_> = inputs
                    .iter()
                    .enumerate()
                    .map(|(j, i)| {
                        front
                            .shard(0)
                            .predict_async_model(
                                ids[wave * bsz + j],
                                i.clone(),
                            )
                            .expect("sweeper alive")
                    })
                    .collect();
                for rx in replies {
                    std::hint::black_box(rx.recv().unwrap());
                }
            }
        });
        front.shutdown();
        push(&mut rows, &r);
        let steps = (tenants * t_len) as f64;
        let tenant_sps = steps / r.per_iter.median;
        println!(
            "  tenant{tenants}: {:.3e} aggregate steps/s — {:.2}x of the \
             single-model shard\n",
            tenant_sps,
            tenant_sps / sharded1_sps
        );
        rows.push(Json::obj(vec![
            (
                "name",
                Json::Str(format!("derived_tenant{tenants}_batch{bsz}_N{n}")),
            ),
            ("n_reservoir", Json::Num(n as f64)),
            ("tenants", Json::Num(tenants as f64)),
            ("batch", Json::Num(bsz as f64)),
            ("t", Json::Num(t_len as f64)),
            ("create_models_per_sec", Json::Num(models_per_sec)),
            ("tenant_steps_per_sec", Json::Num(tenant_sps)),
            ("single_model_steps_per_sec", Json::Num(sharded1_sps)),
            ("ratio_vs_single_model", Json::Num(tenant_sps / sharded1_sps)),
        ]));
    }

    // --- event-loop wire serving: idle connections + mixed traffic ------
    // The epoll transport's claim is capacity, not arithmetic: with 128
    // idle streaming connections parked on the loop (zero threads — see
    // rust/tests/pipeline.rs for the thread-count assertion), a
    // pipelined burst of predicts must still flow at sweeper throughput,
    // and mixing stream chunks in must not stall either side. These are
    // full wire-path numbers (JSON + TCP + queue + sweep), so they sit
    // below the raw engine rows by construction. Rows run in quick mode
    // too — they are the acceptance artifact for the readiness loop.
    {
        let n = 1000;
        let idle = 128usize;
        let active = 16usize;
        println!("event-loop serving, N = {n}, idle = {idle}, active = {active}, T = {t_len}");
        let config = EsnConfig::default().with_n(n).with_seed(2);
        let mut gen_rng = Pcg64::new(11, 113);
        let spec = uniform_spectrum(n, 0.9, &mut gen_rng);
        let diag = DiagonalEsn::from_dpg(spec, &config, &mut gen_rng);
        let readout = Readout {
            w: Mat::randn(n, 1, &mut gen_rng),
            b: vec![0.1],
        };
        let model = Arc::new(Model::new(diag, readout));
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server_model = Arc::clone(&model);
        let max_conns = idle + active;
        let server = std::thread::spawn(move || {
            serve_on(listener, server_model, Some(max_conns), 0, Some(2), false)
                .unwrap();
        });
        // park the idle streaming connections on the loop (one stream
        // round-trip each proves registration, then they sit idle)
        let probe = [0.1f64, -0.2, 0.3];
        let mut idles: Vec<Client> = (0..idle)
            .map(|_| {
                let mut c = Client::connect(&addr).unwrap();
                let out = c.stream(&probe).unwrap();
                assert_eq!(out.len(), probe.len());
                c
            })
            .collect();
        let mut actives: Vec<Client> =
            (0..active).map(|_| Client::connect(&addr).unwrap()).collect();
        let input: Vec<f64> = Mat::randn(t_len, 1, &mut rng).data().to_vec();
        let predict_req = Json::obj(vec![
            ("op", Json::Str("predict".into())),
            (
                "input",
                Json::Arr(input.iter().map(|&x| Json::Num(x)).collect()),
            ),
        ]);
        // pipelined: write all requests, then collect all replies — the
        // event loop interleaves the sweeps and flushes on writability
        let r_idle = bench(
            &format!("evloop_idle{idle}_predict{active}_N{n}"),
            cfg,
            || {
                for c in actives.iter_mut() {
                    c.send(&predict_req).unwrap();
                }
                for c in actives.iter_mut() {
                    std::hint::black_box(c.recv().unwrap());
                }
            },
        );
        push(&mut rows, &r_idle);
        let predict_sps = (active * t_len) as f64 / r_idle.per_iter.median;

        // mixed traffic: stream chunks on hub lanes + the predict burst
        let mixers = 16usize.min(idle);
        let chunk_len = 100usize;
        let stream_req = Json::obj(vec![
            ("op", Json::Str("stream".into())),
            (
                "input",
                Json::Arr(input[..chunk_len].iter().map(|&x| Json::Num(x)).collect()),
            ),
        ]);
        let r_mixed = bench(
            &format!("evloop_mixed_stream{mixers}_predict{active}_N{n}"),
            cfg,
            || {
                for c in idles[..mixers].iter_mut() {
                    c.send(&stream_req).unwrap();
                }
                for c in actives.iter_mut() {
                    c.send(&predict_req).unwrap();
                }
                for c in idles[..mixers].iter_mut() {
                    std::hint::black_box(c.recv().unwrap());
                }
                for c in actives.iter_mut() {
                    std::hint::black_box(c.recv().unwrap());
                }
            },
        );
        push(&mut rows, &r_mixed);
        let mixed_steps = (mixers * chunk_len + active * t_len) as f64;
        let mixed_sps = mixed_steps / r_mixed.per_iter.median;
        println!(
            "  idle-loaded predicts: {:.3e} steps/s | mixed stream+predict: {:.3e} steps/s\n",
            predict_sps, mixed_sps
        );
        rows.push(Json::obj(vec![
            ("name", Json::Str(format!("derived_evloop_N{n}"))),
            ("n_reservoir", Json::Num(n as f64)),
            ("idle_conns", Json::Num(idle as f64)),
            ("active_conns", Json::Num(active as f64)),
            ("t", Json::Num(t_len as f64)),
            ("idle_predict_steps_per_sec", Json::Num(predict_sps)),
            ("mixed_steps_per_sec", Json::Num(mixed_sps)),
        ]));
        drop(actives);
        drop(idles);
        server.join().unwrap();
    }

    // --- streaming fused training: rows/sec through GramAcc -------------
    // Training cost is Gram-dominated (O(F²) per row vs the O(N) step),
    // so the rows here time the full fused pipeline: batched chunk scan +
    // streamed rank-2 accumulation, at both precisions. f32 halves the
    // accumulator traffic and doubles SIMD width — the ratio is the
    // training-side precision ladder. Rows run in quick mode too: they
    // are the acceptance artifact for the training stack.
    {
        let n = 1000;
        let t_train = 256usize;
        println!("fused streaming training, N = {n}, rows = {t_train}");
        let config = EsnConfig::default().with_n(n).with_seed(2);
        let mut gen_rng = Pcg64::new(13, 114);
        let spec = uniform_spectrum(n, 0.9, &mut gen_rng);
        let diag = DiagonalEsn::from_dpg(spec, &config, &mut gen_rng);
        let u_t = Mat::randn(t_train, 1, &mut rng);
        let y_t = Mat::randn(t_train, 1, &mut rng);
        let pool = WorkerPool::new(
            linear_reservoir::coordinator::pool::suggested_threads(),
        );
        let tspec = TrainSpec {
            train: 0..t_train,
            eval: vec![],
        };
        let r64 = bench(&format!("train_fused_f64_N{n}"), cfg, || {
            run_parallel_batch_train_prec::<f64>(
                &diag,
                std::slice::from_ref(&u_t),
                std::slice::from_ref(&y_t),
                std::slice::from_ref(&tspec),
                &pool,
                64,
            )
        });
        let r32 = bench(&format!("train_fused_f32_N{n}"), cfg, || {
            run_parallel_batch_train_prec::<f32>(
                &diag,
                std::slice::from_ref(&u_t),
                std::slice::from_ref(&y_t),
                std::slice::from_ref(&tspec),
                &pool,
                64,
            )
        });
        push(&mut rows, &r64);
        push(&mut rows, &r32);
        let f64_rps = t_train as f64 / r64.per_iter.median;
        let f32_rps = t_train as f64 / r32.per_iter.median;

        // --- online training over the wire: train ops on a hub lane ----
        let train_ops = 4usize;
        let chunk_len = 64usize;
        let readout = Readout {
            w: Mat::randn(n, 1, &mut gen_rng),
            b: vec![0.1],
        };
        let model = Arc::new(Model::new(diag, readout));
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server_model = Arc::clone(&model);
        let server = std::thread::spawn(move || {
            serve_on(listener, server_model, Some(1), 0, Some(1), false)
                .unwrap();
        });
        let mut client = Client::connect(&addr).unwrap();
        let train_reqs: Vec<Json> = (0..train_ops)
            .map(|_| {
                let input = Mat::randn(chunk_len, 1, &mut rng);
                let target = Mat::randn(chunk_len, 1, &mut rng);
                Json::obj(vec![
                    ("op", Json::Str("train".into())),
                    (
                        "input",
                        Json::Arr(
                            input.data().iter().map(|&x| Json::Num(x)).collect(),
                        ),
                    ),
                    (
                        "target",
                        Json::Arr(
                            target.data().iter().map(|&x| Json::Num(x)).collect(),
                        ),
                    ),
                ])
            })
            .collect();
        let r_wire = bench(&format!("train_online_wire_N{n}"), cfg, || {
            // pipelined: send every train op, then drain the replies —
            // the lane accumulates (features, target) rows server-side
            for req in &train_reqs {
                client.send(req).unwrap();
            }
            for _ in 0..train_ops {
                std::hint::black_box(client.recv().unwrap());
            }
        });
        push(&mut rows, &r_wire);
        let wire_rps =
            (train_ops * chunk_len) as f64 / r_wire.per_iter.median;
        // close the loop once (untimed): the accumulated lane commits and
        // the hot-swapped readout serves a stream
        client.commit(1e-2).expect("commit after online training");
        let probe = [0.1f64, -0.2, 0.3];
        let swapped = client.stream(&probe).expect("post-commit stream");
        assert_eq!(swapped.len(), probe.len());
        drop(client);
        server.join().unwrap();

        println!(
            "  fused train: f64 {:.3e} rows/s, f32 {:.3e} rows/s → {:.2}x | online wire {:.3e} rows/s\n",
            f64_rps,
            f32_rps,
            r64.per_iter.median / r32.per_iter.median,
            wire_rps
        );
        rows.push(Json::obj(vec![
            ("name", Json::Str(format!("derived_train_N{n}"))),
            ("n_reservoir", Json::Num(n as f64)),
            ("train_rows", Json::Num(t_train as f64)),
            ("f64_rows_per_sec", Json::Num(f64_rps)),
            ("f32_rows_per_sec", Json::Num(f32_rps)),
            (
                "f32_over_f64",
                Json::Num(r64.per_iter.median / r32.per_iter.median),
            ),
            ("online_wire_rows_per_sec", Json::Num(wire_rps)),
        ]));
    }

    // --- fault-tolerant lifecycle: checkpoint/restore + failover storm --
    // PR6's acceptance rows. `checkpoint_restore_N1000` times one full
    // wire round of checkpoint → restore on a warm N=1000 lane (the
    // warm-failover primitive's latency). `derived_failover_N1000` runs a
    // restart storm: repeated cycles of stream → checkpoint → failover →
    // reconnect → restore → continue, reporting sustained steps/sec
    // across the whole storm. With `--features fault-inject` each cycle's
    // failover is a REAL contained sweeper panic (the lane is poisoned
    // and recovered through restore); without the feature the cycle
    // exercises the same client-side failover path via teardown +
    // reconnect. Rows run in quick mode too — they are the acceptance
    // artifact for the fault-tolerance work.
    {
        let n = 1000;
        let cycles = if quick { 4usize } else { 8 };
        let chunk_len = 250usize;
        println!("fault-tolerant lifecycle, N = {n}, storm cycles = {cycles}");
        let config = EsnConfig::default().with_n(n).with_seed(2);
        let mut gen_rng = Pcg64::new(17, 115);
        let spec = uniform_spectrum(n, 0.9, &mut gen_rng);
        let diag = DiagonalEsn::from_dpg(spec, &config, &mut gen_rng);
        let readout = Readout {
            w: Mat::randn(n, 1, &mut gen_rng),
            b: vec![0.1],
        };
        let model = Arc::new(Model::new(diag, readout));
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server_model = Arc::clone(&model);
        // conn #1 warms and runs the latency row; the storm reconnects
        // once per cycle
        let max_conns = 1 + cycles;
        let server = std::thread::spawn(move || {
            serve_on(listener, server_model, Some(max_conns), 0, Some(1), false)
                .unwrap();
        });
        let input: Vec<f64> = Mat::randn(t_len, 1, &mut rng).data().to_vec();
        let mut client = Client::connect(&addr).unwrap();
        let warm = client.stream(&input[..chunk_len]).unwrap();
        assert_eq!(warm.len(), chunk_len);

        // restore latency: one checkpoint + one restore per iteration,
        // full wire path (snapshot encode + JSON + TCP + sweeper install)
        let r_cp = bench(&format!("checkpoint_restore_N{n}"), cfg, || {
            let cp = client.checkpoint().expect("checkpoint");
            std::hint::black_box(client.restore(&cp).expect("restore"));
        });
        push(&mut rows, &r_cp);

        // failover storm: every cycle checkpoints, suffers a failover,
        // reconnects, restores, and keeps streaming
        let storm_t0 = std::time::Instant::now();
        let mut streamed = 0usize;
        for cycle in 0..cycles {
            let off = (cycle * chunk_len) % (t_len - chunk_len);
            let out = client.stream(&input[off..off + chunk_len]).unwrap();
            assert_eq!(out.len(), chunk_len);
            streamed += chunk_len;
            let cp = client.checkpoint().expect("storm checkpoint");
            #[cfg(feature = "fault-inject")]
            {
                // a real contained sweeper panic: the in-flight stream
                // answers the typed error and the lane is quarantined
                linear_reservoir::server::fault::arm_sweeper_panic(1);
                assert!(
                    client.stream(&input[..1]).is_err(),
                    "armed panic must fail the in-flight stream"
                );
            }
            drop(client);
            client = Client::connect(&addr).unwrap();
            let v = client.restore(&cp).expect("storm restore");
            std::hint::black_box(v);
        }
        let storm_secs = storm_t0.elapsed().as_secs_f64();
        let storm_sps = streamed as f64 / storm_secs;
        #[cfg(feature = "fault-inject")]
        linear_reservoir::server::fault::disarm();
        drop(client);
        server.join().unwrap();
        println!(
            "  restore round trip: {:.3e}s | storm: {streamed} steps across \
             {cycles} failovers → {:.3e} steps/s\n",
            r_cp.per_iter.median, storm_sps
        );
        rows.push(Json::obj(vec![
            ("name", Json::Str(format!("derived_failover_N{n}"))),
            ("n_reservoir", Json::Num(n as f64)),
            ("cycles", Json::Num(cycles as f64)),
            ("chunk", Json::Num(chunk_len as f64)),
            (
                "real_sweeper_panics",
                Json::Bool(cfg!(feature = "fault-inject")),
            ),
            ("storm_steps_per_sec", Json::Num(storm_sps)),
            (
                "restore_round_trip_sec",
                Json::Num(r_cp.per_iter.median),
            ),
        ]));
    }

    // --- PR7: lane mobility — migration, standby deltas, rebalance ------
    // `migrate_lane_N1000` times one live shard→shard move of a warm
    // N=1000 lane over the wire (sync checkpoint + cross-shard restore +
    // binding re-home: the self-healing primitive's latency).
    // `standby_delta_N1000` times one round of the standby pusher's
    // primitive: checkpoint the warm lane, park it on a replica server
    // under a fixed lane id (`migrate_in` push form). `derived_
    // rebalance_N1000` runs a skewed-load storm: every lane is forced
    // onto shard 0, then clients keep streaming while the `--rebalance`
    // policy thread migrates the skew away mid-stream — sustained
    // steps/sec across the storm. Rows run in quick mode too — they are
    // the acceptance artifact for the lane-mobility work.
    {
        let n = 1000;
        println!("lane mobility, N = {n}, T = {t_len}");
        let config = EsnConfig::default().with_n(n).with_seed(2);
        let mut gen_rng = Pcg64::new(19, 116);
        let spec = uniform_spectrum(n, 0.9, &mut gen_rng);
        let diag = DiagonalEsn::from_dpg(spec, &config, &mut gen_rng);
        let readout = Readout {
            w: Mat::randn(n, 1, &mut gen_rng),
            b: vec![0.1],
        };
        let model = Arc::new(Model::new(diag, readout));
        let input: Vec<f64> = Mat::randn(t_len, 1, &mut rng).data().to_vec();

        // migration latency: one live move per iteration; `None` targets
        // the coldest OTHER shard, so the warm lane ping-pongs 0↔1
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server_model = Arc::clone(&model);
        let server = std::thread::spawn(move || {
            serve_on_opts(
                listener,
                server_model,
                Some(1),
                ServeOpts {
                    shards: Some(2),
                    ..Default::default()
                },
            )
            .map(|_| ())
            .unwrap();
        });
        let mut client = Client::connect(&addr).unwrap();
        let warm = client.stream(&input[..250]).unwrap();
        assert_eq!(warm.len(), 250);
        let r_mig = bench(&format!("migrate_lane_N{n}"), cfg, || {
            std::hint::black_box(client.migrate(None).expect("migrate"));
        });
        push(&mut rows, &r_mig);
        drop(client);
        server.join().unwrap();

        // standby delta round trip: primary checkpoint → replica park
        let p_listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let p_addr = p_listener.local_addr().unwrap().to_string();
        let p_model = Arc::clone(&model);
        let primary = std::thread::spawn(move || {
            serve_on_opts(
                p_listener,
                p_model,
                Some(1),
                ServeOpts {
                    shards: Some(1),
                    ..Default::default()
                },
            )
            .map(|_| ())
            .unwrap();
        });
        let s_listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let s_addr = s_listener.local_addr().unwrap().to_string();
        let s_model = Arc::clone(&model);
        let replica = std::thread::spawn(move || {
            serve_on_opts(
                s_listener,
                s_model,
                Some(1),
                ServeOpts {
                    shards: Some(1),
                    ..Default::default()
                },
            )
            .map(|_| ())
            .unwrap();
        });
        let mut pc = Client::connect(&p_addr).unwrap();
        let warm = pc.stream(&input[..250]).unwrap();
        assert_eq!(warm.len(), 250);
        let mut rc = Client::connect(&s_addr).unwrap();
        let r_delta = bench(&format!("standby_delta_N{n}"), cfg, || {
            let cp = pc.checkpoint().expect("delta checkpoint");
            let req = Json::obj(vec![
                ("op", Json::Str("migrate_in".into())),
                ("lane_id", Json::Num(7.0)),
                ("checkpoint", cp),
            ]);
            let resp = rc.request(&req).expect("push delta");
            assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
        });
        push(&mut rows, &r_delta);
        drop(pc);
        drop(rc);
        primary.join().unwrap();
        replica.join().unwrap();

        // skewed-load rebalance storm: pile every lane onto shard 0,
        // then stream while the policy thread (50 ms tick) migrates the
        // skew to shard 1 mid-stream
        let movers = 8usize;
        let rounds = if quick { 8usize } else { 16 };
        let chunk_len = 250usize;
        let b_listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let b_addr = b_listener.local_addr().unwrap().to_string();
        let b_model = Arc::clone(&model);
        let storm_server = std::thread::spawn(move || {
            serve_on_opts(
                b_listener,
                b_model,
                Some(movers),
                ServeOpts {
                    shards: Some(2),
                    rebalance: true,
                    ..Default::default()
                },
            )
            .map(|_| ())
            .unwrap();
        });
        let mut clients: Vec<Client> = (0..movers)
            .map(|_| {
                let mut c = Client::connect(&b_addr).unwrap();
                let out = c.stream(&input[..chunk_len]).unwrap();
                assert_eq!(out.len(), chunk_len);
                // force the skew: every lane starts on shard 0
                c.migrate(Some(0)).expect("skew setup");
                c
            })
            .collect();
        let storm_t0 = std::time::Instant::now();
        let mut streamed = 0usize;
        for round in 0..rounds {
            let off = (round * chunk_len) % (t_len - chunk_len);
            // pipelined: all movers stream concurrently, so both shards'
            // sweepers stay busy while lanes move under them
            let req = Json::obj(vec![
                ("op", Json::Str("stream".into())),
                (
                    "input",
                    Json::Arr(
                        input[off..off + chunk_len]
                            .iter()
                            .map(|&x| Json::Num(x))
                            .collect(),
                    ),
                ),
            ]);
            for c in clients.iter_mut() {
                c.send(&req).unwrap();
            }
            for c in clients.iter_mut() {
                std::hint::black_box(c.recv().unwrap());
            }
            streamed += movers * chunk_len;
        }
        let storm_secs = storm_t0.elapsed().as_secs_f64();
        let storm_sps = streamed as f64 / storm_secs;
        // the policy thread must have found and drained the skew
        let moved = clients[0]
            .request(&Json::obj(vec![("op", Json::Str("info".into()))]))
            .expect("info")
            .get("lanes_migrated")
            .and_then(Json::as_f64)
            .unwrap_or(0.0);
        drop(clients);
        storm_server.join().unwrap();
        println!(
            "  migrate: {:.3e}s | standby delta: {:.3e}s | rebalance storm: \
             {streamed} steps, {moved} migration(s) → {:.3e} steps/s\n",
            r_mig.per_iter.median, r_delta.per_iter.median, storm_sps
        );
        rows.push(Json::obj(vec![
            ("name", Json::Str(format!("derived_rebalance_N{n}"))),
            ("n_reservoir", Json::Num(n as f64)),
            ("movers", Json::Num(movers as f64)),
            ("rounds", Json::Num(rounds as f64)),
            ("chunk", Json::Num(chunk_len as f64)),
            ("lanes_migrated", Json::Num(moved)),
            ("storm_steps_per_sec", Json::Num(storm_sps)),
        ]));
    }

    // --- PR8: cluster failover — kill, detect, promote, redirect --------
    // `failover_cluster_N1000` drives the whole resilience pipeline as
    // one storm: movers stream warm N=1000 lanes on an unclustered
    // primary whose standby fan-out parks deltas on two peered
    // survivors; the primary then vanishes; the survivors' failure
    // detectors reassign the hash ring; every mover reconnects to the
    // WRONG survivor, follows the `moved` redirect, adopts its lane on
    // the promoted owner, and finishes its rounds there. The row
    // reports sustained steps/sec across the storm (detection gap
    // included) plus the measured outage window. Runs in quick mode —
    // it is the acceptance artifact for the cluster-failover work.
    {
        let n = 1000;
        println!("cluster failover, N = {n}, T = {t_len}");
        let config = EsnConfig::default().with_n(n).with_seed(3);
        let mut gen_rng = Pcg64::new(23, 142);
        let spec = uniform_spectrum(n, 0.9, &mut gen_rng);
        let diag = DiagonalEsn::from_dpg(spec, &config, &mut gen_rng);
        let readout = Readout {
            w: Mat::randn(n, 1, &mut gen_rng),
            b: vec![0.1],
        };
        let model = Arc::new(Model::new(diag, readout));
        let input: Vec<f64> = Mat::randn(t_len, 1, &mut rng).data().to_vec();

        let movers = 4usize;
        let chunk_len = 250usize;
        let rounds = if quick { 4usize } else { 8 };
        let pre_rounds = rounds / 2;

        let l1 = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let l2 = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let s1_addr = l1.local_addr().unwrap().to_string();
        let s2_addr = l2.local_addr().unwrap().to_string();
        let p_listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let p_addr = p_listener.local_addr().unwrap().to_string();
        let mut survivors = Vec::new();
        for (listener, advertise, peers) in [
            (l1, s1_addr.clone(), format!("{p_addr},{s2_addr}")),
            (l2, s2_addr.clone(), format!("{p_addr},{s1_addr}")),
        ] {
            let m = Arc::clone(&model);
            survivors.push(std::thread::spawn(move || {
                serve_on_opts(
                    listener,
                    m,
                    Some(movers + 16),
                    ServeOpts {
                        shards: Some(1),
                        peers: Some(peers),
                        advertise: Some(advertise),
                        ping_interval_ms: 25,
                        ..Default::default()
                    },
                )
                .map(|_| ())
                .unwrap();
            }));
        }
        let p_model = Arc::clone(&model);
        let standby = format!("{s1_addr},{s2_addr}");
        let primary = std::thread::spawn(move || {
            // budget: the movers plus the two survivors' gossip probes
            serve_on_opts(
                p_listener,
                p_model,
                Some(movers + 8),
                ServeOpts {
                    shards: Some(1),
                    standby: Some(standby),
                    standby_interval_ms: 20,
                    ..Default::default()
                },
            )
            .map(|_| ())
            .unwrap();
        });

        let stream_round = |clients: &mut [Client], round: usize| {
            let off = (round * chunk_len) % (t_len - chunk_len);
            let req = Json::obj(vec![
                ("op", Json::Str("stream".into())),
                (
                    "input",
                    Json::Arr(
                        input[off..off + chunk_len]
                            .iter()
                            .map(|&x| Json::Num(x))
                            .collect(),
                    ),
                ),
            ]);
            for c in clients.iter_mut() {
                c.send(&req).unwrap();
            }
            for c in clients.iter_mut() {
                std::hint::black_box(c.recv().unwrap());
            }
        };
        let info_req = Json::obj(vec![("op", Json::Str("info".into()))]);

        let storm_t0 = std::time::Instant::now();
        let mut streamed = 0usize;
        // phase 1: warm lanes on the primary, fan-out replicating
        let mut clients: Vec<Client> = (0..movers)
            .map(|_| Client::connect(&p_addr).unwrap())
            .collect();
        for round in 0..pre_rounds {
            stream_round(&mut clients, round);
            streamed += movers * chunk_len;
        }
        let lane_ids: Vec<u64> = clients
            .iter_mut()
            .map(|c| {
                c.request(&info_req)
                    .expect("info")
                    .get("lane_id")
                    .and_then(Json::as_f64)
                    .expect("lane_id") as u64
            })
            .collect();
        loop {
            let lag = clients[0]
                .request(&info_req)
                .expect("info")
                .get("standby_lag_lanes")
                .and_then(Json::as_f64)
                .expect("standby_lag_lanes");
            if lag == 0.0 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        // phase 2: the primary vanishes; survivors must detect and
        // reassign
        let outage_t0 = std::time::Instant::now();
        clients[0].shutdown_drain().expect("stop the primary");
        drop(clients);
        primary.join().unwrap();
        let mut probe = Client::connect(&s1_addr).unwrap();
        let owner = loop {
            let info = probe.request(&info_req).expect("info");
            if info.get("cluster_live").and_then(Json::as_f64) == Some(2.0) {
                break info
                    .get("cluster_owner")
                    .and_then(Json::as_str)
                    .expect("cluster_owner")
                    .to_string();
            }
            std::thread::sleep(std::time::Duration::from_millis(10));
        };
        drop(probe);
        let loser = if owner == s1_addr { &s2_addr } else { &s1_addr };
        // phase 3: every mover reconnects to the WRONG survivor and is
        // redirected to the promoted owner, adopts, and resumes
        let mut clients: Vec<Client> = lane_ids
            .iter()
            .map(|&lane| {
                let mut c = Client::connect(loser).unwrap();
                c.adopt(lane).expect("promotion adopt via redirect");
                c
            })
            .collect();
        let outage_ms = outage_t0.elapsed().as_secs_f64() * 1e3;
        for round in pre_rounds..rounds {
            stream_round(&mut clients, round);
            streamed += movers * chunk_len;
        }
        let storm_secs = storm_t0.elapsed().as_secs_f64();
        let storm_sps = streamed as f64 / storm_secs;
        drop(clients);
        for addr in [&s1_addr, &s2_addr] {
            let mut d = Client::connect(addr).unwrap();
            d.shutdown_drain().expect("drain survivor");
        }
        for h in survivors {
            h.join().unwrap();
        }
        println!(
            "  failover storm: {streamed} steps, {movers} lane(s) promoted, \
             outage {outage_ms:.1}ms → {storm_sps:.3e} steps/s\n"
        );
        rows.push(Json::obj(vec![
            ("name", Json::Str(format!("failover_cluster_N{n}"))),
            ("n_reservoir", Json::Num(n as f64)),
            ("movers", Json::Num(movers as f64)),
            ("rounds", Json::Num(rounds as f64)),
            ("chunk", Json::Num(chunk_len as f64)),
            ("lanes_promoted", Json::Num(movers as f64)),
            ("outage_ms", Json::Num(outage_ms)),
            ("storm_steps_per_sec", Json::Num(storm_sps)),
        ]));
    }

    // --- PR10: wire-path scale-out — poll threads × frame codec ---------
    // Requests/sec (NOT steps/sec) at pipelined saturation: C client
    // threads each keep `depth` predicts in flight over one connection,
    // against the event-loop transport at P ∈ {1, 2, 4} poll threads,
    // once over JSON lines and once over binary frames. The predict is
    // deliberately wire-heavy (256 floats each way): at P=1 the single
    // poll thread's parse/format work is the bottleneck, so the binary
    // codec (raw LE bits, no float formatting) must beat JSON on rps,
    // and spreading the codec work across P=4 poll threads must add rps
    // on top. Two shards keep the sweep itself off the critical path.
    // Rows run in quick mode too — they are the acceptance artifact for
    // the wire-path scale-out.
    {
        let n = 1000;
        let conns = 8usize;
        let depth = if quick { 8usize } else { 16 };
        let steps = 256usize;
        println!(
            "wire-path scale-out, N = {n}, conns = {conns}, depth = {depth}, \
             steps/predict = {steps}"
        );
        let config = EsnConfig::default().with_n(n).with_seed(2);
        let mut gen_rng = Pcg64::new(29, 117);
        let spec = uniform_spectrum(n, 0.9, &mut gen_rng);
        let diag = DiagonalEsn::from_dpg(spec, &config, &mut gen_rng);
        let readout = Readout {
            w: Mat::randn(n, 1, &mut gen_rng),
            b: vec![0.1],
        };
        let model = Arc::new(Model::new(diag, readout));
        let input: Vec<f64> = Mat::randn(steps, 1, &mut rng).data().to_vec();
        let predict_req = Json::obj(vec![
            ("op", Json::Str("predict".into())),
            (
                "input",
                Json::Arr(input.iter().map(|&x| Json::Num(x)).collect()),
            ),
        ]);
        let mut json_rps = Vec::new();
        let mut bin_rps = Vec::new();
        for &p in &[1usize, 2, 4] {
            for binary in [false, true] {
                let listener =
                    std::net::TcpListener::bind("127.0.0.1:0").unwrap();
                let addr = listener.local_addr().unwrap().to_string();
                let server_model = Arc::clone(&model);
                let server = std::thread::spawn(move || {
                    serve_on_opts(
                        listener,
                        server_model,
                        Some(conns),
                        ServeOpts {
                            shards: Some(2),
                            poll_threads: p,
                            ..Default::default()
                        },
                    )
                    .map(|_| ())
                    .unwrap();
                });
                let mut cs: Vec<Client> = (0..conns)
                    .map(|_| {
                        let mut c = Client::connect(&addr).unwrap();
                        if binary {
                            c.upgrade_binary().unwrap();
                        }
                        c
                    })
                    .collect();
                let codec = if binary { "binary" } else { "json" };
                let r = bench(
                    &format!("wirepath_rps_p{p}_N{n}_{codec}"),
                    cfg,
                    || {
                        // one saturation wave: every connection keeps
                        // `depth` requests pipelined, driven from its own
                        // client thread so the (single-threaded) bench
                        // client can't hide server-side scaling
                        std::thread::scope(|scope| {
                            for c in cs.iter_mut() {
                                let req = &predict_req;
                                scope.spawn(move || {
                                    for _ in 0..depth {
                                        c.send(req).unwrap();
                                    }
                                    for _ in 0..depth {
                                        std::hint::black_box(
                                            c.recv().unwrap(),
                                        );
                                    }
                                });
                            }
                        });
                    },
                );
                push(&mut rows, &r);
                let rps = (conns * depth) as f64 / r.per_iter.median;
                println!("  P={p} {codec}: {rps:.3e} req/s");
                if binary {
                    bin_rps.push(rps);
                } else {
                    json_rps.push(rps);
                }
                drop(cs);
                server.join().unwrap();
            }
        }
        println!(
            "  binary vs json @P=1: {:.2}x | scaling P=4/P=1: json {:.2}x, \
             binary {:.2}x\n",
            bin_rps[0] / json_rps[0],
            json_rps[2] / json_rps[0],
            bin_rps[2] / bin_rps[0]
        );
        rows.push(Json::obj(vec![
            ("name", Json::Str(format!("derived_wirepath_N{n}"))),
            ("n_reservoir", Json::Num(n as f64)),
            ("conns", Json::Num(conns as f64)),
            ("depth", Json::Num(depth as f64)),
            ("steps_per_predict", Json::Num(steps as f64)),
            ("json_rps_p1", Json::Num(json_rps[0])),
            ("json_rps_p2", Json::Num(json_rps[1])),
            ("json_rps_p4", Json::Num(json_rps[2])),
            ("binary_rps_p1", Json::Num(bin_rps[0])),
            ("binary_rps_p2", Json::Num(bin_rps[1])),
            ("binary_rps_p4", Json::Num(bin_rps[2])),
            (
                "binary_over_json_p1",
                Json::Num(bin_rps[0] / json_rps[0]),
            ),
            (
                "binary_over_json_p4",
                Json::Num(bin_rps[2] / json_rps[2]),
            ),
            ("json_scaling_p4", Json::Num(json_rps[2] / json_rps[0])),
            ("binary_scaling_p4", Json::Num(bin_rps[2] / bin_rps[0])),
        ]));
    }

    if let Some(path) = json_path {
        let doc = Json::obj(vec![
            ("bench", Json::Str("reservoir_run".into())),
            ("quick", Json::Bool(quick)),
            ("t", Json::Num(t_len as f64)),
            ("results", Json::Arr(rows)),
        ]);
        std::fs::write(&path, doc.to_string_pretty())
            .unwrap_or_else(|e| panic!("writing {path}: {e}"));
        println!("wrote {path}");
    }
}
