//! Bench: full-sequence reservoir runs (T×N trajectories) — standard
//! dense vs sparse vs diagonal engines, the end-to-end form of Table 2's
//! compute budget. Run: `cargo bench --bench reservoir_run [-- --quick]`

use linear_reservoir::bench::{bench, BenchConfig};
use linear_reservoir::linalg::Mat;
use linear_reservoir::reservoir::{DiagonalEsn, EsnConfig, QBasisEsn, StandardEsn};
use linear_reservoir::rng::Pcg64;
use linear_reservoir::spectral::uniform::uniform_spectrum;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let cfg = if quick {
        BenchConfig::quick()
    } else {
        BenchConfig::default()
    };
    let t_len = 1000;
    let sizes: Vec<usize> = if quick {
        vec![100, 400]
    } else {
        vec![100, 200, 400, 800, 1600]
    };
    let mut rng = Pcg64::seeded(1);
    let u = Mat::randn(t_len, 1, &mut rng);

    println!("full-sequence runs, T = {t_len}");
    for &n in &sizes {
        let config = EsnConfig::default().with_n(n).with_seed(2);
        let dense = StandardEsn::generate(config.with_connectivity(1.0));
        let sparse = StandardEsn::generate(config.with_connectivity(0.05));
        let mut gen_rng = Pcg64::new(2, 110);
        let spec = uniform_spectrum(n, 0.9, &mut gen_rng);
        let diag = DiagonalEsn::from_dpg(spec, &config, &mut gen_rng);

        let qbasis = QBasisEsn::from_diagonal(&diag);

        let r1 = bench(&format!("dense_N{n}"), cfg, || dense.run(&u));
        let r2 = bench(&format!("sparse05_N{n}"), cfg, || sparse.run(&u));
        let r3 = bench(&format!("diagonal_N{n}"), cfg, || diag.run(&u));
        let r4 = bench(&format!("qbasis_N{n}"), cfg, || qbasis.run(&u));
        println!("{}", r1.report());
        println!("{}", r2.report());
        println!("{}", r3.report());
        println!("{}", r4.report());
        println!(
            "  speedup qbasis vs dense: {:.1}x, vs sparse(5%): {:.1}x, vs split-plane diag: {:.2}x\n",
            r1.per_iter.median / r4.per_iter.median,
            r2.per_iter.median / r4.per_iter.median,
            r3.per_iter.median / r4.per_iter.median
        );
    }
}
