//! Bench: the compiled-HLO request path (PJRT execute) vs the native Rust
//! engine — the production serving comparison. Needs `make artifacts`.
//! Run: `cargo bench --bench runtime_exec [-- --quick]`

use linear_reservoir::bench::{bench, BenchConfig};
use linear_reservoir::linalg::Mat;
use linear_reservoir::reservoir::{DiagonalEsn, EsnConfig};
use linear_reservoir::rng::Pcg64;
use linear_reservoir::runtime::{DiagRuntime, Runtime};
use linear_reservoir::spectral::golden::{golden_spectrum, GoldenParams};

fn main() {
    if !Runtime::default_dir().join("manifest.json").exists() {
        println!("SKIP runtime_exec: artifacts not built (run `make artifacts`)");
        return;
    }
    let quick = std::env::args().any(|a| a == "--quick");
    let cfg = if quick {
        BenchConfig::quick()
    } else {
        BenchConfig::default()
    };

    let n = 100;
    let t_len = 1000;
    let config = EsnConfig::default().with_n(n).with_seed(6);
    let mut rng = Pcg64::new(6, 130);
    let spec = golden_spectrum(n, GoldenParams { sr: 0.9, sigma: 0.2 }, &mut rng);
    let esn = DiagonalEsn::from_dpg(spec, &config, &mut rng);
    let u = Mat::randn(t_len, 1, &mut rng);

    let mut drt = DiagRuntime::open_default().expect("open runtime");
    // compile warm-up
    let _ = drt.run(&esn, &u, false).expect("hlo run");

    let r_native = bench("native_diag_T1000_N100", cfg, || esn.run(&u));
    let r_hlo = bench("hlo_diag_T1000_N100", cfg, || {
        drt.run(&esn, &u, false).unwrap()
    });
    let r_hlo_assoc = bench("hlo_assoc_T1000_N100", cfg, || {
        drt.run(&esn, &u, true).unwrap()
    });
    println!("{}", r_native.report());
    println!("{}", r_hlo.report());
    println!("{}", r_hlo_assoc.report());
    println!(
        "\nthroughput: native {:.0} steps/s, hlo {:.0} steps/s, hlo-assoc {:.0} steps/s",
        t_len as f64 / r_native.per_iter.median,
        t_len as f64 / r_hlo.per_iter.median,
        t_len as f64 / r_hlo_assoc.per_iter.median,
    );
}
