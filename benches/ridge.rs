//! Bench: readout training — direct ridge fit vs Gram-stats reuse (the
//! grid-search fast path), and the generalized-Tikhonov (EET) variant.
//! Run: `cargo bench --bench ridge [-- --quick]`

use linear_reservoir::bench::{bench, BenchConfig};
use linear_reservoir::linalg::Mat;
use linear_reservoir::readout::{fit, GramStats, Regularizer};
use linear_reservoir::rng::Pcg64;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let cfg = if quick {
        BenchConfig::quick()
    } else {
        BenchConfig::default()
    };
    let t_len = 300;
    let sizes: Vec<usize> = if quick { vec![100] } else { vec![100, 200, 400] };
    let mut rng = Pcg64::seeded(3);

    for &f in &sizes {
        let x = Mat::randn(t_len, f, &mut rng);
        let y = Mat::randn(t_len, 1, &mut rng);
        let qtq = {
            let q = Mat::randn(f, f, &mut rng);
            q.transpose().matmul(&q)
        };

        let r1 = bench(&format!("fit_identity_F{f}"), cfg, || {
            fit(&x, &y, 1e-6, true, Regularizer::Identity).unwrap()
        });
        let r2 = bench(&format!("fit_generalized_F{f}"), cfg, || {
            fit(&x, &y, 1e-6, true, Regularizer::Generalized(&qtq)).unwrap()
        });
        let stats = GramStats::new(&x, &y);
        let r3 = bench(&format!("gram_build_F{f}"), cfg, || GramStats::new(&x, &y));
        let r4 = bench(&format!("gram_solve36_F{f}"), cfg, || {
            // the grid-search inner loop: 36 (scale, α) solves on one Gram
            let mut acc = 0.0;
            for si in 0..3 {
                for ai in 0..12 {
                    let s = [1.0, 0.1, 0.01][si];
                    let alpha = 10f64.powi(ai - 11);
                    let r = stats.solve_scaled(alpha, s).unwrap();
                    acc += r.w[(0, 0)];
                }
            }
            acc
        });
        println!("{}", r1.report());
        println!("{}", r2.report());
        println!("{}", r3.report());
        println!("{}", r4.report());
        println!(
            "  reuse speedup: 36 fits ≈ {:.2}ms direct vs {:.2}ms via Gram reuse\n",
            36.0 * r1.per_iter.median * 1e3,
            (r3.per_iter.median + r4.per_iter.median) * 1e3
        );
    }
}
