//! Bench: Figure 2's three stages across reservoir sizes (the paper's
//! headline O(N²)→O(N) claim as a measured crossover).
//! Run: `cargo bench --bench fig2_steps [-- --quick]`

use linear_reservoir::experiments::fig2;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let huge = std::env::args().any(|a| a == "--huge");
    let sizes: Vec<usize> = if quick {
        vec![100, 400]
    } else if huge {
        // the 1600/3000 points make the generation stage minutes-long
        // (O(N³) eigendecompositions) — opt-in
        vec![50, 100, 200, 400, 800, 1600, 3000]
    } else {
        vec![50, 100, 200, 400, 800]
    };
    let rows = fig2::run(&sizes, if quick { 1 } else { 3 }, quick).expect("fig2 run");
    println!("\n{:>6} {:>16} {:>18} {:>14} {:>10}", "N", "stage", "method", "seconds", "ratio");
    // ratio: normal/diagonal per size for the reservoir step
    for r in &rows {
        let ratio = if r.stage == "reservoir_step" && r.method == "diagonal" {
            rows.iter()
                .find(|x| x.n == r.n && x.stage == "reservoir_step" && x.method == "normal")
                .map(|x| format!("{:.1}x", x.seconds / r.seconds))
                .unwrap_or_default()
        } else {
            String::new()
        };
        println!(
            "{:>6} {:>16} {:>18} {:>14.3e} {:>10}",
            r.n, r.stage, r.method, r.seconds, ratio
        );
    }
}
