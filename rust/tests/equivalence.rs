//! Cross-module equivalence properties — the mathematical heart of the
//! paper, verified end-to-end over the *whole* library (randomized via the
//! property harness):
//!
//! * Theorem 1 / EWT: diagonalized trajectories + transformed readouts ≡
//!   the standard engine, for dense and sparse `W`, with and without leak.
//! * EET ≡ EWT: training in the eigenbasis with the generalized Tikhonov
//!   term produces the SAME predictions as training standard + transform.
//! * Theorem 5: `R(t)`-recovered features ≡ direct runs for every scaling.
//! * DPG spectra invariants: conjugate closure, radius bounds, layout.

use linear_reservoir::linalg::Mat;
use linear_reservoir::readout::{
    fit, predict_scaled, GramStats, Readout, Regularizer,
};
use linear_reservoir::reservoir::state_matrix::state_matrix_1d;
use linear_reservoir::reservoir::{
    BatchEsn, DiagonalEsn, EsnConfig, QBasisEsn, StandardEsn,
};
use linear_reservoir::rng::{Distributions, Pcg64};
use linear_reservoir::spectral::golden::{golden_spectrum, GoldenParams};
use linear_reservoir::spectral::uniform::uniform_spectrum;
use linear_reservoir::testing::check;

#[test]
fn prop_ewt_trajectory_equivalence() {
    check("EWT trajectory ≡ standard", 8, |rng| {
        let n = 8 + rng.next_below(20) as usize;
        let leak = rng.uniform(0.3, 1.0);
        let sr = rng.uniform(0.3, 1.0);
        let config = EsnConfig::default()
            .with_n(n)
            .with_sr(sr)
            .with_leak(leak)
            .with_seed(rng.next_u64());
        let standard = StandardEsn::generate(config);
        let diag = match DiagonalEsn::from_standard(&standard) {
            Ok(d) => d,
            Err(_) => return Ok(()), // non-diagonalizable draw: skip
        };
        let t_len = 30;
        let u = Mat::randn(t_len, 1, rng);
        let r = standard.run(&u);
        let feats = diag.run(&u);
        let q = diag.q.clone().unwrap();
        let mapped = r.matmul(&q);
        let scale = feats.data().iter().fold(1.0f64, |m, x| m.max(x.abs()));
        let err = mapped.max_abs_diff(&feats) / scale;
        if err < 1e-7 {
            Ok(())
        } else {
            Err(format!("n={n} leak={leak:.2} sr={sr:.2} err={err:.2e}"))
        }
    });
}

#[test]
fn prop_eet_equals_ewt_predictions() {
    check("EET ≡ EWT", 6, |rng| {
        let n = 10 + rng.next_below(15) as usize;
        let config = EsnConfig::default()
            .with_n(n)
            .with_sr(rng.uniform(0.4, 0.95))
            .with_seed(rng.next_u64());
        let standard = StandardEsn::generate(config);
        let diag = match DiagonalEsn::from_standard(&standard) {
            Ok(d) => d,
            Err(_) => return Ok(()),
        };
        let t_len = 120;
        let u = Mat::randn(t_len, 1, rng);
        let y = Mat::randn(t_len, 1, rng);
        let alpha = 10f64.powf(rng.uniform(-8.0, -2.0));

        // EWT: train on standard states, transform weights
        let x_std = standard.run(&u);
        let ro_std = fit(&x_std, &y, alpha, false, Regularizer::Identity).unwrap();
        let w_q = diag.transform_readout(&ro_std.w).unwrap();

        // EET: train directly in the eigenbasis with QᵀQ Tikhonov
        let x_q = diag.run(&u);
        let qtq = diag.tikhonov_matrix().unwrap();
        let ro_eet =
            fit(&x_q, &y, alpha, false, Regularizer::Generalized(&qtq)).unwrap();

        // both must predict identically
        let pred_ewt = x_q.matmul(&w_q);
        let pred_eet = x_q.matmul(&ro_eet.w);
        let scale = pred_ewt.data().iter().fold(1.0f64, |m, x| m.max(x.abs()));
        let err = pred_ewt.max_abs_diff(&pred_eet) / scale;
        if err < 1e-5 {
            Ok(())
        } else {
            Err(format!("n={n} α={alpha:.1e} err={err:.2e}"))
        }
    });
}

#[test]
fn prop_eet_equals_standard_training() {
    // the full Theorem 1 (iv) chain: EET predictions == predictions of a
    // readout trained on the STANDARD states with plain ridge
    check("EET ≡ standard ridge", 6, |rng| {
        let n = 10 + rng.next_below(12) as usize;
        let config = EsnConfig::default()
            .with_n(n)
            .with_sr(rng.uniform(0.4, 0.9))
            .with_seed(rng.next_u64());
        let standard = StandardEsn::generate(config);
        let diag = match DiagonalEsn::from_standard(&standard) {
            Ok(d) => d,
            Err(_) => return Ok(()),
        };
        let t_len = 100;
        let u = Mat::randn(t_len, 1, rng);
        let y = Mat::randn(t_len, 1, rng);
        let alpha = 1e-5;
        let x_std = standard.run(&u);
        let ro_std = fit(&x_std, &y, alpha, false, Regularizer::Identity).unwrap();
        let pred_std = x_std.matmul(&ro_std.w);

        let x_q = diag.run(&u);
        let qtq = diag.tikhonov_matrix().unwrap();
        let ro_eet =
            fit(&x_q, &y, alpha, false, Regularizer::Generalized(&qtq)).unwrap();
        let pred_eet = x_q.matmul(&ro_eet.w);

        let scale = pred_std.data().iter().fold(1.0f64, |m, x| m.max(x.abs()));
        let err = pred_std.max_abs_diff(&pred_eet) / scale;
        if err < 1e-5 {
            Ok(())
        } else {
            Err(format!("n={n} err={err:.2e}"))
        }
    });
}

#[test]
fn prop_theorem5_state_matrix_recovery() {
    check("Theorem 5 recovery", 10, |rng| {
        let n = 6 + rng.next_below(30) as usize;
        let config = EsnConfig::default().with_n(n).with_seed(rng.next_u64());
        let mut gen_rng = Pcg64::new(rng.next_u64(), 90);
        let spec = uniform_spectrum(n, rng.uniform(0.2, 1.0), &mut gen_rng);
        let esn = DiagonalEsn::from_dpg(spec, &config, &mut gen_rng);
        let t_len = 40;
        let u: Vec<f64> = rng.normal_vec(t_len);
        let direct = esn.run(&Mat::from_rows(t_len, 1, &u));
        let sm = state_matrix_1d(&esn.spec, &u);
        let rec = sm.features_for(esn.win_re.row(0), esn.win_im.row(0));
        let scale = direct.data().iter().fold(1.0f64, |m, x| m.max(x.abs()));
        let err = rec.max_abs_diff(&direct) / scale;
        if err < 1e-10 {
            Ok(())
        } else {
            Err(format!("n={n} err={err:.2e}"))
        }
    });
}

#[test]
fn prop_gram_scaling_consistency() {
    // the grid-search fast path: scaled Gram solve ≡ solve on
    // explicitly-scaled features, across random scales
    check("Gram scaling", 10, |rng| {
        let t_len = 80;
        let f = 5 + rng.next_below(10) as usize;
        let x = Mat::randn(t_len, f, rng);
        let y = Mat::randn(t_len, 1, rng);
        let s = 10f64.powf(rng.uniform(-2.0, 0.5));
        let alpha = 10f64.powf(rng.uniform(-8.0, 0.0));
        let stats = GramStats::new(&x, &y);
        let fast = stats.solve_scaled(alpha, s).unwrap();
        let mut xs = x.clone();
        xs.scale(s);
        let slow = fit(&xs, &y, alpha, true, Regularizer::Identity).unwrap();
        let pf = predict_scaled(&fast, &x, s);
        let ps = slow.predict(&xs);
        let err = pf.max_abs_diff(&ps);
        if err < 1e-7 {
            Ok(())
        } else {
            Err(format!("f={f} s={s:.2e} α={alpha:.1e} err={err:.2e}"))
        }
    });
}

#[test]
fn prop_batch_engine_matches_independent_runs() {
    // ISSUE-1 acceptance: BatchEsn states ≡ B independent QBasisEsn::run
    // calls (≤ 1e-10; the lane arithmetic is in fact bit-identical)
    check("BatchEsn ≡ B × QBasisEsn", 8, |rng| {
        let n = 6 + rng.next_below(40) as usize;
        let b = 1 + rng.next_below(12) as usize;
        let t_len = 25;
        let config = EsnConfig::default().with_n(n).with_seed(rng.next_u64());
        let mut gen_rng = Pcg64::new(rng.next_u64(), 91);
        let spec = uniform_spectrum(n, rng.uniform(0.3, 1.0), &mut gen_rng);
        let q = QBasisEsn::from_diagonal(&DiagonalEsn::from_dpg(
            spec, &config, &mut gen_rng,
        ));
        let u = Mat::randn(t_len, b, rng);
        let mut batch = BatchEsn::new(q.clone(), b);
        let lanes = batch.run(&u);
        for lane in 0..b {
            let col: Vec<f64> = (0..t_len).map(|t| u[(t, lane)]).collect();
            let single = q.run(&Mat::from_rows(t_len, 1, &col));
            let err = lanes[lane].max_abs_diff(&single);
            if err > 1e-10 {
                return Err(format!("n={n} B={b} lane={lane} err={err:.2e}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_fused_readout_matches_materialized() {
    // fused run_readout ≡ readout.predict(esn.run(u)) on both the plain
    // and the batched engine (≤ 1e-10)
    check("fused readout ≡ run-then-matmul", 8, |rng| {
        let n = 8 + rng.next_below(30) as usize;
        let b = 1 + rng.next_below(6) as usize;
        let d_out = 1 + rng.next_below(3) as usize;
        let t_len = 30;
        let config = EsnConfig::default().with_n(n).with_seed(rng.next_u64());
        let mut gen_rng = Pcg64::new(rng.next_u64(), 92);
        let spec = uniform_spectrum(n, rng.uniform(0.3, 0.95), &mut gen_rng);
        let q = QBasisEsn::from_diagonal(&DiagonalEsn::from_dpg(
            spec, &config, &mut gen_rng,
        ));
        let ro = Readout {
            w: Mat::randn(n, d_out, rng),
            b: (0..d_out).map(|_| rng.normal()).collect(),
        };
        let u = Mat::randn(t_len, b, rng);
        let mut batch = BatchEsn::new(q.clone(), b);
        let fused_batch = batch.run_readout(&u, &ro);
        for lane in 0..b {
            let col: Vec<f64> = (0..t_len).map(|t| u[(t, lane)]).collect();
            let u1 = Mat::from_rows(t_len, 1, &col);
            let fused = q.run_readout(&u1, &ro);
            let want = ro.predict(&q.run(&u1));
            let err = fused.max_abs_diff(&want);
            if err > 1e-10 {
                return Err(format!("qbasis n={n} lane={lane} err={err:.2e}"));
            }
            for t in 0..t_len {
                for k in 0..d_out {
                    let diff =
                        (fused_batch[(t, lane * d_out + k)] - want[(t, k)]).abs();
                    if diff > 1e-10 {
                        return Err(format!(
                            "batch n={n} lane={lane} t={t} k={k} err={diff:.2e}"
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_fused_readout_matches_materialized_on_feedback_path() {
    // the teacher-forced Eq.-1 path: fused run_readout_teacher_forced ≡
    // predict(run_teacher_forced) (≤ 1e-10)
    check("fused feedback readout ≡ materialized", 6, |rng| {
        let n = 8 + rng.next_below(14) as usize;
        let config = EsnConfig::default()
            .with_n(n)
            .with_sr(rng.uniform(0.4, 0.9))
            .with_seed(rng.next_u64());
        let w_fb = Mat::randn(1, n, rng);
        let standard = StandardEsn::generate(config).with_feedback(w_fb);
        let diag = match DiagonalEsn::from_standard(&standard) {
            Ok(d) => d,
            Err(_) => return Ok(()), // non-diagonalizable draw: skip
        };
        let t_len = 35;
        let u = Mat::randn(t_len, 1, rng);
        let y_teacher = Mat::randn(t_len, 1, rng);
        let ro = Readout {
            w: Mat::randn(n, 1, rng),
            b: vec![rng.normal()],
        };
        let fused = diag.run_readout_teacher_forced(&u, &y_teacher, &ro);
        let want = ro.predict(&diag.run_teacher_forced(&u, &y_teacher));
        let err = fused.max_abs_diff(&want);
        if err > 1e-10 {
            return Err(format!("n={n} err={err:.2e}"));
        }
        // and the no-feedback fused path agrees with run() + predict too
        let fused_plain = diag.run_readout(&u, &ro);
        let want_plain = ro.predict(&diag.run(&u));
        let err = fused_plain.max_abs_diff(&want_plain);
        if err > 1e-10 {
            return Err(format!("plain n={n} err={err:.2e}"));
        }
        Ok(())
    });
}

#[test]
fn prop_dpg_spectra_invariants() {
    check("DPG spectrum invariants", 15, |rng| {
        let n = 4 + rng.next_below(200) as usize;
        let sr = rng.uniform(0.1, 1.3);
        let sigma = if rng.bernoulli(0.5) { 0.0 } else { 0.2 };
        let spec = if rng.bernoulli(0.5) {
            uniform_spectrum(n, sr, rng)
        } else {
            golden_spectrum(n, GoldenParams { sr, sigma }, rng)
        };
        // layout invariants
        if spec.n != n {
            return Err(format!("n mismatch {} != {n}", spec.n));
        }
        if spec.full().len() != n {
            return Err("full() length".into());
        }
        for (i, z) in spec.lam.iter().enumerate() {
            if i < spec.n_real && z.im != 0.0 {
                return Err(format!("real slot {i} has im {}", z.im));
            }
            if i >= spec.n_real && z.im <= 0.0 {
                return Err(format!("cpx slot {i} not upper-half ({z:?})"));
            }
        }
        // conjugate closure of the full spectrum (trace is real)
        let im_sum: f64 = spec.full().iter().map(|z| z.im).sum();
        if im_sum.abs() > 1e-9 {
            return Err(format!("trace imaginary {im_sum}"));
        }
        Ok(())
    });
}

#[test]
fn prop_leak_commutes_with_diagonalization() {
    // diagonalize(leaked W) ≡ leak(diagonalized W) — Eq. 4's claim that
    // the same optimization applies to W^{(lr)}
    check("leak ∘ diag ≡ diag ∘ leak", 6, |rng| {
        let n = 8 + rng.next_below(10) as usize;
        let leak = rng.uniform(0.2, 0.9);
        let seed = rng.next_u64();
        let base_cfg = EsnConfig::default().with_n(n).with_sr(0.8).with_seed(seed);
        // path A: generate with leak folded into W
        let leaked = StandardEsn::generate(base_cfg.with_leak(leak));
        // path B: generate without leak, diagonalize, leak the spectrum
        let plain = StandardEsn::generate(base_cfg.with_leak(1.0));
        let diag = match DiagonalEsn::from_standard(&plain) {
            Ok(d) => d,
            Err(_) => return Ok(()),
        };
        let spec_leaked = diag.spec.apply_leak(leak);
        // compare spectra as multisets of |λ| (leaked W vs leaked Λ)
        let mut a: Vec<f64> =
            linear_reservoir::linalg::eigenvalues(&leaked.w_dense())
                .iter()
                .map(|z| z.abs())
                .collect();
        let mut b: Vec<f64> = spec_leaked.full().iter().map(|z| z.abs()).collect();
        a.sort_by(|x, y| x.partial_cmp(y).unwrap());
        b.sort_by(|x, y| x.partial_cmp(y).unwrap());
        for (x, y) in a.iter().zip(&b) {
            if (x - y).abs() > 1e-7 {
                return Err(format!("|λ| mismatch {x} vs {y}"));
            }
        }
        Ok(())
    });
}
