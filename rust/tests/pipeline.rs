//! Full-pipeline integration: the complete train-and-evaluate protocol on
//! real workloads (MSO, NARMA), the serving path over TCP, and the
//! coordinator's parallel map — each exercising several modules together.

use std::sync::Arc;

use linear_reservoir::coordinator::{GridSearch, GridSpec, MethodKind, WorkerPool};
use linear_reservoir::linalg::Mat;
use linear_reservoir::metrics::{nrmse, rmse};
use linear_reservoir::readout::{fit, Regularizer};
use linear_reservoir::reservoir::{DiagonalEsn, EsnConfig, StandardEsn};
use linear_reservoir::rng::Pcg64;
use linear_reservoir::server::{serve_on, Client, Model};
use linear_reservoir::spectral::golden::{golden_spectrum, GoldenParams};
use linear_reservoir::tasks::mso::{slice_rows, MsoTask};
use linear_reservoir::tasks::narma::NarmaTask;

#[test]
fn mso5_pipeline_beats_trivial_baseline() {
    // a trained DPG reservoir must beat the persistence forecast by a
    // large margin on MSO5
    let n = 100;
    let config = EsnConfig::default().with_n(n).with_sr(0.9).with_seed(0);
    let mut rng = Pcg64::new(0, 100);
    let spec = golden_spectrum(n, GoldenParams { sr: 0.9, sigma: 0.0 }, &mut rng);
    let esn = DiagonalEsn::from_dpg(spec, &config, &mut rng);

    let task = MsoTask::new(5);
    let splits = MsoTask::splits();
    let feats = esn.run(&task.input_mat());
    let x_train = slice_rows(&feats, splits.train.clone());
    let y_train = task.target_mat(splits.train.clone());
    let readout = fit(&x_train, &y_train, 1e-9, true, Regularizer::Identity).unwrap();

    let x_test = slice_rows(&feats, splits.test.clone());
    let y_test = task.target_mat(splits.test.clone());
    let model_rmse = rmse(&readout.predict(&x_test), &y_test);

    // persistence baseline: y(t) = u(t)
    let persistence = {
        let p = Mat::from_rows(
            splits.test.len(),
            1,
            &task.input[splits.test.clone()],
        );
        rmse(&p, &y_test)
    };
    assert!(
        model_rmse < persistence * 1e-3,
        "model {model_rmse:.3e} vs persistence {persistence:.3e}"
    );
}

#[test]
fn narma_pipeline_linear_reservoir_learns_partially() {
    // NARMA-10 is nonlinear: a linear ESN + linear readout can only track
    // it partially (NRMSE < 1 means better than predicting the mean —
    // that's the expected ceiling for linear models)
    let n = 120;
    let config = EsnConfig::default().with_n(n).with_sr(0.95).with_seed(1);
    let esn = StandardEsn::generate(config);
    let task = NarmaTask::new(2200, 1);
    let states = esn.run(&task.input_mat());
    let x_train = slice_rows(&states, 200..1400);
    let y_train = task.target_mat(200..1400);
    let readout = fit(&x_train, &y_train, 1e-6, true, Regularizer::Identity).unwrap();
    let x_test = slice_rows(&states, 1400..2200);
    let y_test = task.target_mat(1400..2200);
    let e = nrmse(&readout.predict(&x_test), &y_test);
    assert!(e < 0.9, "NARMA NRMSE {e}");
    assert!(e > 0.01, "linear model should NOT solve NARMA perfectly: {e}");
}

#[test]
fn grid_search_end_to_end_diag_vs_normal() {
    let gs = GridSearch {
        spec: GridSpec::quick(),
        n: 50,
        connectivity: 1.0,
    };
    let normal = gs.run_mso(3, MethodKind::Normal, 0).unwrap();
    let golden = gs
        .run_mso(3, MethodKind::DpgGolden { sigma: 0.2 }, 0)
        .unwrap();
    assert!(normal.test_rmse < 1e-2, "normal {}", normal.test_rmse);
    assert!(golden.test_rmse < 1e-2, "golden {}", golden.test_rmse);
}

#[test]
fn worker_pool_runs_grid_trials_in_parallel() {
    let pool = WorkerPool::new(2);
    let results = pool.map(vec![0u64, 1, 2, 3], |seed| {
        let gs = GridSearch {
            spec: GridSpec::quick(),
            n: 30,
            connectivity: 1.0,
        };
        gs.run_mso(1, MethodKind::DpgUniform, seed)
            .map(|r| r.test_rmse)
            .unwrap()
    });
    assert_eq!(results.len(), 4);
    for r in &results {
        assert!(r.is_finite() && *r < 0.1);
    }
    // determinism across pool executions
    let again = pool.map(vec![0u64, 1, 2, 3], |seed| {
        let gs = GridSearch {
            spec: GridSpec::quick(),
            n: 30,
            connectivity: 1.0,
        };
        gs.run_mso(1, MethodKind::DpgUniform, seed)
            .map(|r| r.test_rmse)
            .unwrap()
    });
    assert_eq!(results, again);
}

/// Bind port 0, spawn `serve_on`, hand back the discovered address —
/// race-free (the listener is bound before the thread starts) and safe
/// under parallel test runs (no hard-coded ports, no startup sleeps).
fn spawn_server_on(
    model: Arc<Model>,
    max_conns: usize,
    shards: Option<usize>,
    threaded: bool,
) -> (String, std::thread::JoinHandle<()>) {
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let handle = std::thread::spawn(move || {
        serve_on(listener, model, Some(max_conns), 0, shards, threaded).unwrap();
    });
    (addr, handle)
}

#[test]
fn tcp_serving_pipeline() {
    // train a small model, serve it, query it over TCP, check quality
    let n = 60;
    let config = EsnConfig::default().with_n(n).with_sr(0.9).with_seed(3);
    let mut rng = Pcg64::new(3, 101);
    let spec = golden_spectrum(n, GoldenParams { sr: 0.9, sigma: 0.0 }, &mut rng);
    let esn = DiagonalEsn::from_dpg(spec, &config, &mut rng);
    let task = MsoTask::new(2);
    let splits = MsoTask::splits();
    let feats = esn.run(&task.input_mat());
    let x = slice_rows(&feats, splits.train.clone());
    let y = task.target_mat(splits.train.clone());
    let readout = fit(&x, &y, 1e-9, true, Regularizer::Identity).unwrap();
    let model = Arc::new(Model::new(esn, readout));

    let (addr, handle) = spawn_server_on(Arc::clone(&model), 1, None, false);

    let mut client = Client::connect(&addr).unwrap();
    let pred = client.predict(&task.input).unwrap();
    assert_eq!(pred.len(), task.input.len());
    // quality on the test span
    let test = MsoTask::splits().test;
    let pred_test = Mat::from_rows(test.len(), 1, &pred[test.clone()]);
    let y_test = task.target_mat(test);
    assert!(rmse(&pred_test, &y_test) < 1e-4);
    drop(client);
    handle.join().unwrap();
}

fn serving_model(seed: u64) -> Model {
    let n = 50;
    let config = EsnConfig::default().with_n(n).with_sr(0.9).with_seed(seed);
    let mut rng = Pcg64::new(seed, 102);
    let spec = golden_spectrum(n, GoldenParams { sr: 0.9, sigma: 0.0 }, &mut rng);
    let esn = DiagonalEsn::from_dpg(spec, &config, &mut rng);
    let task = MsoTask::new(2);
    let splits = MsoTask::splits();
    let feats = esn.run(&task.input_mat());
    let x = slice_rows(&feats, splits.train.clone());
    let y = task.target_mat(splits.train.clone());
    let readout = fit(&x, &y, 1e-9, true, Regularizer::Identity).unwrap();
    Model::new(esn, readout)
}

#[test]
fn concurrent_batched_predicts_bit_identical_to_sequential() {
    // the micro-batching front must be invisible: whatever coalescing
    // happens server-side, every client gets bit-for-bit the output of a
    // sequential Model::predict
    let model = Arc::new(serving_model(11));
    let task = MsoTask::new(2);
    let clients = 6;
    let (addr, server) = spawn_server_on(Arc::clone(&model), clients, None, false);

    let mut workers = Vec::new();
    for i in 0..clients {
        let model = Arc::clone(&model);
        let addr = addr.clone();
        let input: Vec<f64> = task.input[i * 17..i * 17 + 60 + 3 * i].to_vec();
        workers.push(std::thread::spawn(move || {
            let mut client = Client::connect(&addr).unwrap();
            // several rounds per connection to overlap with the others
            for _ in 0..4 {
                let got = client.predict(&input).unwrap();
                let want = model.predict(&input);
                assert_eq!(got.len(), want.len());
                for (a, b) in got.iter().zip(&want) {
                    assert!(
                        (a - b).abs() == 0.0,
                        "batched predict not bit-identical: {a} vs {b}"
                    );
                }
            }
        }));
    }
    for w in workers {
        w.join().unwrap();
    }
    server.join().unwrap();
}

#[test]
fn concurrent_stream_connections_are_isolated() {
    // every connection owns a streaming state; interleaved stream requests
    // from concurrent connections must each reproduce their own sequential
    // trajectory (no cross-talk between hub lanes)
    let model = Arc::new(serving_model(12));
    let task = MsoTask::new(2);
    let clients = 4;
    let (addr, server) = spawn_server_on(Arc::clone(&model), clients, None, false);

    let mut workers = Vec::new();
    for i in 0..clients {
        let model = Arc::clone(&model);
        let addr = addr.clone();
        // distinct input per connection so cross-talk would be visible
        let input: Vec<f64> = task.input[i * 50..i * 50 + 48].to_vec();
        workers.push(std::thread::spawn(move || {
            let mut client = Client::connect(&addr).unwrap();
            // chunked streaming: state must persist across requests
            let mut got = Vec::new();
            for chunk in input.chunks(7 + i) {
                got.extend(client.stream(chunk).unwrap());
            }
            // sequential reference on this connection's input alone
            let want = {
                let u = Mat::from_rows(input.len(), 1, &input);
                let y = model.qesn.run_readout(&u, &model.readout);
                (0..y.rows()).map(|t| y[(t, 0)]).collect::<Vec<f64>>()
            };
            assert_eq!(got.len(), want.len());
            for (t, (a, b)) in got.iter().zip(&want).enumerate() {
                assert!(
                    (a - b).abs() < 1e-10,
                    "stream isolation broken at t={t}: {a} vs {b}"
                );
            }
        }));
    }
    for w in workers {
        w.join().unwrap();
    }
    server.join().unwrap();
}

#[test]
fn sharded_server_mixed_traffic_bit_identical_and_isolated() {
    // the shard-per-core front must be invisible end to end: concurrent
    // connections (each streaming on its home shard's hub while also
    // firing stateless predicts dealt to the least-loaded shard) all get
    // bit-for-bit their solo trajectories
    let model = Arc::new(serving_model(13));
    let task = MsoTask::new(2);
    let clients = 5;
    // explicit 2 shards, no hold-off, event-loop transport
    let (addr, server) = spawn_server_on(Arc::clone(&model), clients, Some(2), false);

    let mut workers = Vec::new();
    for i in 0..clients {
        let model = Arc::clone(&model);
        let addr = addr.clone();
        let stream_in: Vec<f64> = task.input[i * 40..i * 40 + 42].to_vec();
        let predict_in: Vec<f64> = task.input[i * 23..i * 23 + 30 + i].to_vec();
        workers.push(std::thread::spawn(move || {
            let mut client = Client::connect(&addr).unwrap();
            let mut got = Vec::new();
            for chunk in stream_in.chunks(9 + i) {
                // interleave a stateless predict between stream chunks —
                // it must not perturb this connection's lane state
                let p = client.predict(&predict_in).unwrap();
                let p_want = model.predict(&predict_in);
                assert_eq!(p.len(), p_want.len());
                for (a, b) in p.iter().zip(&p_want) {
                    assert!(
                        (a - b).abs() == 0.0,
                        "sharded predict not bit-identical: {a} vs {b}"
                    );
                }
                got.extend(client.stream(chunk).unwrap());
            }
            let want = model.predict(&stream_in);
            assert_eq!(got.len(), want.len());
            for (t, (a, b)) in got.iter().zip(&want).enumerate() {
                assert!(
                    (a - b).abs() == 0.0,
                    "sharded stream diverged at t={t}: {a} vs {b}"
                );
            }
        }));
    }
    for w in workers {
        w.join().unwrap();
    }
    server.join().unwrap();
}

// ---------------------------------------------------------------------------
// event-loop concurrency: thread-free idle connections
// ---------------------------------------------------------------------------

/// Total threads of a process (`/proc/<pid>/status` `Threads:` line).
#[cfg(target_os = "linux")]
fn thread_count(pid: u32) -> usize {
    std::fs::read_to_string(format!("/proc/{pid}/status"))
        .ok()
        .and_then(|s| {
            s.lines()
                .find_map(|l| l.strip_prefix("Threads:"))
                .and_then(|v| v.trim().parse::<usize>().ok())
        })
        .expect("read Threads: from /proc/<pid>/status")
}

/// Raise the soft RLIMIT_NOFILE toward the hard limit (raw syscalls —
/// no crates) and return the effective soft limit: this test holds
/// ~2 fds per connection in one process, which outruns the common 1024
/// default.
#[cfg(target_os = "linux")]
fn raise_nofile_limit() -> u64 {
    #[repr(C)]
    struct RLimit {
        cur: u64,
        max: u64,
    }
    extern "C" {
        fn getrlimit(resource: i32, rlim: *mut RLimit) -> i32;
        fn setrlimit(resource: i32, rlim: *const RLimit) -> i32;
    }
    const RLIMIT_NOFILE: i32 = 7;
    unsafe {
        let mut r = RLimit { cur: 0, max: 0 };
        if getrlimit(RLIMIT_NOFILE, &mut r) != 0 {
            return 1024;
        }
        // RLIM_INFINITY is u64::MAX; 64k is plenty and always ≤ hard
        let want = r.max.min(1 << 16);
        if r.cur < want {
            let bumped = RLimit {
                cur: want,
                max: r.max,
            };
            let _ = setrlimit(RLIMIT_NOFILE, &bumped);
        }
        let mut after = RLimit { cur: 0, max: 0 };
        if getrlimit(RLIMIT_NOFILE, &mut after) == 0 {
            after.cur
        } else {
            r.cur
        }
    }
}

#[cfg(target_os = "linux")]
#[test]
fn event_loop_holds_512_idle_streaming_connections_thread_free() {
    // the tentpole claim: N idle streaming connections are served by
    // S sweeper threads + 1 poll thread — the server's thread count is
    // INDEPENDENT of the connection count (the threaded transport would
    // add one thread per connection here). The server runs as a
    // DEDICATED child process (`repro serve`, the real CLI), so the
    // /proc thread count is exact: parallel tests in this process spawn
    // threads of their own and would make a /proc/self delta flaky.
    use std::io::BufRead;
    let fd_budget = raise_nofile_limit();
    // test side: 2 fds per Client (try_clone'd reader + writer); child
    // side (inherits the bumped limit): 1 per accepted socket
    let conns = 512usize.min((fd_budget.saturating_sub(128) / 2) as usize);
    assert!(
        conns >= 128,
        "fd limit {fd_budget} too low to exercise idle concurrency"
    );
    let mut child = std::process::Command::new(env!("CARGO_BIN_EXE_repro"))
        .args([
            "serve", "--addr", "127.0.0.1:0", "--k", "2", "--n", "50",
            "--shards", "2",
        ])
        .stdout(std::process::Stdio::piped())
        .stderr(std::process::Stdio::null())
        .spawn()
        .expect("spawn repro serve");
    // the startup banner ("serving … on 127.0.0.1:PORT …") prints after
    // the listener is bound: parse the discovered ephemeral port from it
    let mut banner_reader = std::io::BufReader::new(child.stdout.take().unwrap());
    let mut banner = String::new();
    banner_reader.read_line(&mut banner).unwrap();
    let addr = banner
        .rsplit(" on ")
        .next()
        .and_then(|s| s.split_whitespace().next())
        .expect("bound address in startup banner")
        .to_string();

    // every connection is a *streaming* client: one stream round-trip
    // proves the server fully registered it, then it sits idle. All
    // loopback clients share one peer IP, hence ONE home shard: the
    // first 64 claim that shard's hub lanes, the rest run the local
    // fallback (identical bits) — the claim under test is thread-free
    // idling, not hub capacity
    let probe = [0.07f64, -0.11, 0.23];
    let connect_streaming = || {
        let mut c = Client::connect(&addr).unwrap();
        let out = c.stream(&probe).unwrap();
        assert_eq!(out.len(), probe.len());
        assert!(out.iter().all(|v| v.is_finite()));
        c
    };
    let mut clients = Vec::with_capacity(conns);
    for _ in 0..8 {
        clients.push(connect_streaming());
    }
    let baseline = thread_count(child.id());
    for _ in 8..conns {
        clients.push(connect_streaming());
    }
    let with_load = thread_count(child.id());
    // the child is exactly 1 poll (main) thread + 2 sweepers; the
    // threaded transport would sit ~(conns - 8) above baseline here
    assert!(
        with_load <= baseline + 2,
        "event-loop server thread count must be connection-independent: \
         {baseline} -> {with_load} after {} extra idle streaming conns",
        conns - 8
    );
    assert!(
        baseline <= 8,
        "expected S sweepers + 1 poll thread, got {baseline}"
    );
    // the idle connections are all still live and ordered: round-trip
    // the first and last again
    for idx in [0, conns - 1] {
        let out = clients[idx].stream(&probe).unwrap();
        assert_eq!(out.len(), probe.len());
    }
    drop(clients);
    let _ = child.kill();
    let _ = child.wait();
}

// ---------------------------------------------------------------------------
// failure injection & edge cases
// ---------------------------------------------------------------------------

#[test]
fn server_rejects_malformed_requests_without_dying() {
    use linear_reservoir::util::json::{parse, Json};
    use std::io::{BufRead, BufReader, Write};

    let n = 20;
    let config = EsnConfig::default().with_n(n).with_seed(9);
    let mut rng = Pcg64::new(9, 200);
    let spec = golden_spectrum(n, GoldenParams { sr: 0.9, sigma: 0.0 }, &mut rng);
    let esn = DiagonalEsn::from_dpg(spec, &config, &mut rng);
    let task = MsoTask::new(1);
    let feats = esn.run(&task.input_mat());
    let x = slice_rows(&feats, 100..400);
    let y = task.target_mat(100..400);
    let readout = fit(&x, &y, 1e-8, true, Regularizer::Identity).unwrap();
    let model = Arc::new(Model::new(esn, readout));

    let (addr, handle) = spawn_server_on(Arc::clone(&model), 1, None, false);

    let stream = std::net::TcpStream::connect(&addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut w = stream;
    let mut line = String::new();
    // garbage JSON → error response, connection stays alive
    for bad in ["not json at all", "{\"op\": \"nope\"}", "{\"op\": \"predict\"}"] {
        w.write_all(bad.as_bytes()).unwrap();
        w.write_all(b"\n").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        let resp = parse(line.trim()).unwrap();
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)), "{bad} → {line}");
    }
    // then a VALID request still works on the same connection
    w.write_all(br#"{"op": "predict", "input": [0.1, 0.2]}"#).unwrap();
    w.write_all(b"\n").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    let resp = parse(line.trim()).unwrap();
    assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
    // close BOTH halves (reader holds a try_clone of the socket) or the
    // server never sees EOF and join() deadlocks
    drop(w);
    drop(reader);
    handle.join().unwrap();
}

#[test]
fn degenerate_reservoirs_fail_gracefully_not_loudly() {
    use linear_reservoir::linalg::Mat as M;
    // zero matrix: diagonalizable (trivially) but the eigenbasis from
    // inverse iteration may be arbitrary — must not panic either way
    let w = M::zeros(8, 8);
    let w_in = M::from_rows(1, 8, &[1.0; 8]);
    let esn = linear_reservoir::reservoir::StandardEsn::from_parts(
        w,
        w_in,
        EsnConfig::default().with_n(8),
    );
    match DiagonalEsn::from_standard(&esn) {
        Ok(diag) => {
            // if it succeeds, dynamics must still be sane: zero W ⇒ states
            // are pure input projections each step
            let mut rng = Pcg64::seeded(1);
            let u = Mat::randn(10, 1, &mut rng);
            let feats = diag.run(&u);
            assert!(feats.data().iter().all(|v| v.is_finite()));
        }
        Err(_) => {} // clean refusal also acceptable
    }
}

#[test]
fn tiny_reservoirs_full_pipeline() {
    // N = 1 and N = 2 exercise every layout edge (no complex slots / no
    // real slots / single pair)
    for n in [1usize, 2, 3] {
        let gs = GridSearch {
            spec: GridSpec::quick(),
            n,
            connectivity: 1.0,
        };
        let r = gs.run_mso(1, MethodKind::DpgUniform, 0).unwrap();
        assert!(r.test_rmse.is_finite(), "N={n}");
    }
}

#[test]
fn empty_and_single_step_sequences() {
    let n = 10;
    let config = EsnConfig::default().with_n(n).with_seed(4);
    let mut rng = Pcg64::new(4, 201);
    let spec =
        linear_reservoir::spectral::uniform::uniform_spectrum(n, 0.9, &mut rng);
    let esn = DiagonalEsn::from_dpg(spec, &config, &mut rng);
    let empty = esn.run(&Mat::zeros(0, 1));
    assert_eq!(empty.rows(), 0);
    let one = esn.run(&Mat::from_rows(1, 1, &[1.0]));
    assert_eq!(one.rows(), 1);
    assert!(one.row(0).iter().any(|v| *v != 0.0));
}
