//! Chaos suite: deterministic fault injection against the live server.
//!
//! Built only with `--features fault-inject` (see the `[[test]]` entry in
//! Cargo.toml). Every test arms a failure through
//! `linear_reservoir::server::fault`, drives a real loopback server, and
//! asserts the degradation is a TYPED error code — never a hang, a
//! connection drop, or silently corrupted state. The acceptance bar for
//! the failover tests is bit-identity: a client that restores from its
//! last checkpoint must continue exactly the uninterrupted run's output.
//!
//! The fault hooks are process-global, so the suite serializes on
//! [`FAULT_LOCK`] (one armed fault at a time) and every test disarms on
//! exit — including assert-failure exits — via [`DisarmGuard`].

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Duration;

use linear_reservoir::readout::{fit, Regularizer};
use linear_reservoir::reservoir::{DiagonalEsn, EsnConfig};
use linear_reservoir::rng::Pcg64;
use linear_reservoir::server::{
    fault, serve_on_opts, Client, Model, Precision, ServeOpts,
};
use linear_reservoir::spectral::uniform::uniform_spectrum;
use linear_reservoir::tasks::mso::{slice_rows, MsoTask};
use linear_reservoir::util::json::{parse, Json};

/// One armed fault at a time: the hooks are process-global statics.
static FAULT_LOCK: Mutex<()> = Mutex::new(());

/// Serialize on the fault lock (a poisoned lock — an earlier test's
/// assert failure — is fine to inherit: the guard below disarmed it) and
/// guarantee a clean disarm when this test unwinds.
fn fault_guard() -> (MutexGuard<'static, ()>, DisarmGuard) {
    let g = FAULT_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    fault::disarm();
    (g, DisarmGuard)
}

struct DisarmGuard;

impl Drop for DisarmGuard {
    fn drop(&mut self) {
        fault::disarm();
    }
}

// ---------------------------------------------------------------------------
// fixtures
// ---------------------------------------------------------------------------

/// The server-subtree test model (mirrors the in-crate fixture): N = 30
/// uniform spectrum, MSO1 readout.
fn make_model(precision: Precision) -> Arc<Model> {
    let config = EsnConfig::default().with_n(30).with_sr(0.9).with_seed(1);
    let mut rng = Pcg64::new(1, 2);
    let spec = uniform_spectrum(30, 0.9, &mut rng);
    let esn = DiagonalEsn::from_dpg(spec, &config, &mut rng);
    let task = MsoTask::new(1);
    let u = task.input_mat();
    let feats = esn.run(&u);
    let x = slice_rows(&feats, 100..400);
    let y = task.target_mat(100..400);
    let readout = fit(&x, &y, 1e-8, true, Regularizer::Identity).unwrap();
    Arc::new(Model::with_precision(esn, readout, precision))
}

/// Bind port 0, serve exactly `max_conns` connections on one shard (so
/// every client shares the sweeper under test), return the address.
fn spawn_server(
    model: Arc<Model>,
    max_conns: usize,
    threaded: bool,
) -> (String, std::thread::JoinHandle<()>) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let handle = std::thread::spawn(move || {
        serve_on_opts(
            listener,
            model,
            Some(max_conns),
            ServeOpts {
                shards: Some(1),
                threaded,
                ..Default::default()
            },
        )
        .map(|_| ())
        .unwrap();
    });
    (addr, handle)
}

// ---------------------------------------------------------------------------
// a client with read timeouts — a chaos test must FAIL on a hang, not park
// ---------------------------------------------------------------------------

struct CClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl CClient {
    fn connect(addr: &str) -> CClient {
        let stream = TcpStream::connect(addr).unwrap();
        // generous ceiling: any reply slower than this is a hang, and the
        // read errs the test instead of parking it forever
        stream
            .set_read_timeout(Some(Duration::from_secs(30)))
            .unwrap();
        CClient {
            reader: BufReader::new(stream.try_clone().unwrap()),
            writer: stream,
        }
    }

    fn request(&mut self, req: &Json) -> Json {
        self.writer
            .write_all(req.to_string_compact().as_bytes())
            .unwrap();
        self.writer.write_all(b"\n").unwrap();
        let mut line = String::new();
        self.reader
            .read_line(&mut line)
            .expect("reply within the timeout (no silent hang)");
        assert!(
            !line.is_empty(),
            "server closed the connection instead of answering"
        );
        parse(line.trim()).unwrap()
    }

    /// Issue a request that must succeed and carry an `output` array.
    fn output_of(&mut self, req: &Json) -> Vec<f64> {
        let resp = self.request(req);
        assert_eq!(
            resp.get("ok"),
            Some(&Json::Bool(true)),
            "expected success, got {resp:?}"
        );
        resp.get("output")
            .and_then(Json::as_arr)
            .expect("output array")
            .iter()
            .map(|v| v.as_f64().expect("numeric output"))
            .collect()
    }

    /// Issue a request that must succeed and carry a `version`.
    fn version_of(&mut self, req: &Json) -> u64 {
        let resp = self.request(req);
        assert_eq!(
            resp.get("ok"),
            Some(&Json::Bool(true)),
            "expected success, got {resp:?}"
        );
        resp.get("version").and_then(Json::as_f64).expect("version") as u64
    }

    /// Issue a `train` that must succeed; returns the lane's total rows.
    fn rows_of(&mut self, req: &Json) -> u64 {
        let resp = self.request(req);
        assert_eq!(
            resp.get("ok"),
            Some(&Json::Bool(true)),
            "expected success, got {resp:?}"
        );
        resp.get("rows").and_then(Json::as_f64).expect("rows") as u64
    }

    /// Issue a request that must FAIL with exactly this typed code.
    fn expect_code(&mut self, req: &Json, code: &str) {
        let resp = self.request(req);
        assert_eq!(
            resp.get("ok"),
            Some(&Json::Bool(false)),
            "expected typed failure {code:?}, got {resp:?}"
        );
        assert_eq!(
            resp.get("code").and_then(Json::as_str),
            Some(code),
            "wrong error code: {resp:?}"
        );
    }

    fn checkpoint(&mut self) -> Json {
        let resp = self.request(&op("checkpoint"));
        assert_eq!(
            resp.get("ok"),
            Some(&Json::Bool(true)),
            "checkpoint failed: {resp:?}"
        );
        resp.get("checkpoint").cloned().expect("checkpoint object")
    }

    fn info(&mut self) -> Json {
        let resp = self.request(&op("info"));
        assert_eq!(
            resp.get("ok"),
            Some(&Json::Bool(true)),
            "info failed: {resp:?}"
        );
        resp
    }

    /// `shutdown_drain` and assert the ok — every PR 7 test exits its
    /// server this way so a variable connection count never wedges the
    /// accept loop's join.
    fn drain(&mut self) {
        let resp = self.request(&op("shutdown_drain"));
        assert_eq!(
            resp.get("ok"),
            Some(&Json::Bool(true)),
            "shutdown_drain failed: {resp:?}"
        );
    }
}

fn jnums(v: &[f64]) -> Json {
    Json::Arr(v.iter().map(|&x| Json::Num(x)).collect())
}

fn op(name: &str) -> Json {
    Json::obj(vec![("op", Json::Str(name.into()))])
}

fn stream_req(input: &[f64]) -> Json {
    Json::obj(vec![
        ("op", Json::Str("stream".into())),
        ("input", jnums(input)),
    ])
}

fn predict_req(input: &[f64]) -> Json {
    Json::obj(vec![
        ("op", Json::Str("predict".into())),
        ("input", jnums(input)),
    ])
}

fn train_req(input: &[f64], target: &[f64]) -> Json {
    Json::obj(vec![
        ("op", Json::Str("train".into())),
        ("input", jnums(input)),
        ("target", jnums(target)),
    ])
}

fn commit_req(alpha: f64) -> Json {
    Json::obj(vec![
        ("op", Json::Str("commit".into())),
        ("alpha", Json::Num(alpha)),
    ])
}

fn rollback_req(version: u64) -> Json {
    Json::obj(vec![
        ("op", Json::Str("rollback".into())),
        ("version", Json::Num(version as f64)),
    ])
}

fn restore_req(checkpoint: &Json) -> Json {
    Json::obj(vec![
        ("op", Json::Str("restore".into())),
        ("checkpoint", checkpoint.clone()),
    ])
}

fn migrate_req(shard: usize) -> Json {
    Json::obj(vec![
        ("op", Json::Str("migrate".into())),
        ("shard", Json::Num(shard as f64)),
    ])
}

/// Promotion adopt: bind a lane the standby parked from pushed deltas.
fn adopt_req(lane_id: u64) -> Json {
    Json::obj(vec![
        ("op", Json::Str("migrate_in".into())),
        ("lane_id", Json::Num(lane_id as f64)),
    ])
}

/// Stamp a per-request deadline onto any wire request.
fn with_deadline(req: Json, ms: u64) -> Json {
    match req {
        Json::Obj(mut m) => {
            m.insert("deadline_ms".into(), Json::Num(ms as f64));
            Json::Obj(m)
        }
        other => other,
    }
}

/// The exact model `repro serve --k K --n N` constructs (golden
/// spectrum, seed 0, stream 70). The standby-promotion test pairs an
/// in-test replica with a real subprocess primary, and promotion is
/// only bit-identical if the weights on both sides are.
fn make_cli_model(k: usize, n: usize, precision: Precision) -> Arc<Model> {
    use linear_reservoir::spectral::golden::{golden_spectrum, GoldenParams};
    let config = EsnConfig::default().with_n(n).with_sr(0.9).with_seed(0);
    let mut rng = Pcg64::new(0, 70);
    let spec = golden_spectrum(n, GoldenParams { sr: 0.9, sigma: 0.2 }, &mut rng);
    let esn = DiagonalEsn::from_dpg(spec, &config, &mut rng);
    let task = MsoTask::new(k);
    let splits = MsoTask::splits();
    let feats = esn.run(&task.input_mat());
    let x = slice_rows(&feats, splits.train.clone());
    let y = task.target_mat(splits.train.clone());
    let readout = fit(&x, &y, 1e-8, true, Regularizer::Identity).unwrap();
    Arc::new(Model::with_precision(esn, readout, precision))
}

// ---------------------------------------------------------------------------
// tentpole proof: contained sweeper panic → checkpoint failover, bit-exact
// ---------------------------------------------------------------------------

/// The acceptance-criteria chaos proof, on both transports and both
/// precisions: the sweeper is panicked mid-stream; the interrupted op
/// answers the typed `unavailable`, the quarantined lane answers the
/// typed `lane_poisoned`, an untouched lane on the SAME sweeper keeps
/// bit-identical state across the panic, and a fresh connection restoring
/// the victim's last checkpoint continues bit-identically to an
/// uninterrupted run.
#[test]
fn contained_sweeper_panic_failover_is_bit_identical() {
    let (_lock, _disarm) = fault_guard();
    let task = MsoTask::new(1);
    let input = &task.input[..60];
    for threaded in [false, true] {
        for precision in [Precision::F64, Precision::F32] {
            let model = make_model(precision);
            let (addr, handle) = spawn_server(model, 4, threaded);

            // the uninterrupted reference run
            let mut reference = CClient::connect(&addr);
            let want = reference.output_of(&stream_req(input));
            assert_eq!(want.len(), 60);

            // victim: half the run, then a checkpoint
            let mut victim = CClient::connect(&addr);
            let first = victim.output_of(&stream_req(&input[..30]));
            assert_eq!(first, want[..30]);
            let cp = victim.checkpoint();

            // bystander: half the run on its own lane, same sweeper
            let mut bystander = CClient::connect(&addr);
            let by_first = bystander.output_of(&stream_req(&input[..30]));
            assert_eq!(by_first, want[..30]);

            // the very next stateful job panics the sweep mid-batch
            fault::arm_sweeper_panic(1);
            victim.expect_code(&stream_req(&input[30..45]), "unavailable");
            // the lane is quarantined with a typed refusal — stream and
            // checkpoint alike — not a hang and not stale state
            victim.expect_code(&stream_req(&input[30..45]), "lane_poisoned");
            victim.expect_code(&op("checkpoint"), "lane_poisoned");

            // the restarted sweeper serves untouched lanes bit-identically
            let by_rest = bystander.output_of(&stream_req(&input[30..]));
            assert_eq!(
                by_rest,
                want[30..],
                "bystander lane diverged across a contained panic \
                 (threaded={threaded}, {})",
                if precision == Precision::F64 { "f64" } else { "f32" },
            );

            // warm failover: a NEW connection restores the checkpoint and
            // continues exactly where the uninterrupted run would be
            let mut revived = CClient::connect(&addr);
            assert_eq!(revived.version_of(&restore_req(&cp)), 0);
            let rest = revived.output_of(&stream_req(&input[30..]));
            assert_eq!(
                rest,
                want[30..],
                "restored run diverged from the uninterrupted reference \
                 (threaded={threaded}, {})",
                if precision == Precision::F64 { "f64" } else { "f32" },
            );

            drop(reference);
            drop(victim);
            drop(bystander);
            drop(revived);
            handle.join().unwrap();
        }
    }
}

/// The escalation twin: a hard sweeper KILL (the legacy failure mode the
/// containment path replaced) degrades every stateful op to the typed
/// `unavailable` — no hangs — while stateless predicts fall back to
/// direct computation and keep serving.
#[test]
fn sweeper_kill_degrades_to_typed_unavailable_with_predict_fallback() {
    let (_lock, _disarm) = fault_guard();
    let task = MsoTask::new(1);
    let input = &task.input[..40];
    for threaded in [false, true] {
        let model = make_model(Precision::F64);
        let (addr, handle) = spawn_server(Arc::clone(&model), 2, threaded);

        let mut a = CClient::connect(&addr);
        let _ = a.output_of(&stream_req(&input[..10]));

        fault::arm_sweeper_kill(1);
        // the killing op's reply is dropped mid-flight
        a.expect_code(&stream_req(&input[10..20]), "unavailable");
        // the front is permanently gone: every lane-resident op refuses
        // with the same typed code, immediately
        a.expect_code(&stream_req(&input[10..20]), "unavailable");
        a.expect_code(&commit_req(1e-4), "unavailable");
        a.expect_code(&op("checkpoint"), "unavailable");

        // stateless predict still serves through the direct fallback,
        // bit-identical to the model oracle
        let mut b = CClient::connect(&addr);
        let got = b.output_of(&predict_req(input));
        assert_eq!(got, model.predict(input));

        drop(a);
        drop(b);
        handle.join().unwrap();
    }
}

// ---------------------------------------------------------------------------
// versioning under chaos: rollback is bit-exact and keeps the rows
// ---------------------------------------------------------------------------

/// Twin-lane proof over the wire that `rollback` reinstalls a PRIOR
/// committed readout bit-exactly without dropping the accumulator: the
/// twin lane runs the identical history but never commits v2, so equal
/// streams after `rollback(1)` mean the rolled-back readout is
/// bit-identical to the originally installed v1 — and training continues
/// from the undropped row count.
#[test]
fn rollback_is_bit_exact_and_keeps_accumulated_rows() {
    let (_lock, _disarm) = fault_guard();
    let task = MsoTask::new(1);
    for threaded in [false, true] {
        let model = make_model(Precision::F64);
        let (addr, handle) = spawn_server(model, 2, threaded);

        let mut a = CClient::connect(&addr);
        let mut twin = CClient::connect(&addr);
        let t1 = (&task.input[..100], &task.target[..100]);
        let t2 = (&task.input[100..150], &task.target[100..150]);
        for c in [&mut a, &mut twin] {
            assert_eq!(c.rows_of(&train_req(t1.0, t1.1)), 100);
            assert_eq!(c.version_of(&commit_req(1e-4)), 1);
            assert_eq!(c.rows_of(&train_req(t2.0, t2.1)), 150);
        }
        // only `a` commits v2 (readouts now differ), then rolls back;
        // unknown versions refuse with the typed code and change nothing
        assert_eq!(a.version_of(&commit_req(1e-2)), 2);
        a.expect_code(&rollback_req(99), "rollback_unknown_version");
        assert_eq!(a.version_of(&rollback_req(1)), 1);

        // identical streams ⇒ the reinstalled v1 readout (and the lane
        // state) is bit-identical to the twin that never left v1
        let probe = &task.input[400..430];
        assert_eq!(
            a.output_of(&stream_req(probe)),
            twin.output_of(&stream_req(probe)),
            "rollback(1) did not reinstall v1 bit-exactly (threaded={threaded})"
        );

        // the accumulator survived the rollback: rows continue from 150
        // (plus the 30 probe steps which don't train), and the next
        // commit id is monotonic past the rolled-back v2
        assert_eq!(
            a.rows_of(&train_req(&task.input[150..180], &task.target[150..180])),
            180
        );
        assert_eq!(a.version_of(&commit_req(1e-2)), 3);

        drop(a);
        drop(twin);
        handle.join().unwrap();
    }
}

/// Forced trainer-budget exhaustion answers the typed `trainer_budget`
/// refusal BEFORE any state advances (checkpoint-identical lane), and the
/// same op succeeds once the budget pressure clears.
#[test]
fn forced_trainer_budget_refuses_without_corrupting_the_lane() {
    let (_lock, _disarm) = fault_guard();
    let task = MsoTask::new(1);
    let model = make_model(Precision::F64);
    let (addr, handle) = spawn_server(model, 1, false);

    let mut c = CClient::connect(&addr);
    let _ = c.output_of(&stream_req(&task.input[..20]));
    let before = c.checkpoint();

    fault::force_trainer_budget(0);
    c.expect_code(
        &train_req(&task.input[20..50], &task.target[20..50]),
        "trainer_budget",
    );
    // the refusal left the lane untouched — bit-for-bit
    assert_eq!(c.checkpoint(), before);

    fault::disarm();
    assert_eq!(
        c.rows_of(&train_req(&task.input[20..50], &task.target[20..50])),
        30
    );

    drop(c);
    handle.join().unwrap();
}

// ---------------------------------------------------------------------------
// event-loop plumbing under chaos (Linux-only: epoll transport)
// ---------------------------------------------------------------------------

/// Injected short writes turn a large reply into a long chunk-by-chunk
/// flush; the idle wheel must NOT reap the connection mid-flush (busy) or
/// right after it (the flush restamps `last_active`), even though the
/// wall time far exceeds the idle timeout.
#[cfg(target_os = "linux")]
#[test]
fn idle_wheel_restamps_on_flush_under_injected_slow_writes() {
    let (_lock, _disarm) = fault_guard();
    let model = make_model(Precision::F64);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let handle = std::thread::spawn(move || {
        serve_on_opts(
            listener,
            model,
            Some(2),
            ServeOpts {
                shards: Some(1),
                idle_timeout: Some(Duration::from_millis(300)),
                ..Default::default()
            },
        )
        .map(|_| ())
        .unwrap();
    });
    let big: Vec<f64> = (0..3000).map(|t| (0.17 * t as f64).sin()).collect();
    let follow: Vec<f64> = (0..30).map(|t| (0.05 * t as f64).cos()).collect();

    // unshaped reference first: expected outputs for both requests
    let mut reference = CClient::connect(&addr);
    let want_big = reference.output_of(&stream_req(&big));
    let want_follow = reference.output_of(&stream_req(&follow));
    drop(reference); // free its lane before the slow run

    // ~60 KiB reply at 1 KiB per 10 ms ⇒ ≥ 600 ms of flushing, double
    // the idle timeout — survivable only because flushing counts as
    // activity
    fault::set_short_writes(1024, Duration::from_millis(10));
    let mut victim = CClient::connect(&addr);
    assert_eq!(victim.output_of(&stream_req(&big)), want_big);
    // the connection is still alive right after the long flush
    assert_eq!(victim.output_of(&stream_req(&follow)), want_follow);
    fault::disarm();

    drop(victim);
    handle.join().unwrap();
}

/// Accept-path tolerance: a server whose fd table is exhausted (EMFILE,
/// forced via RLIMIT_NOFILE in a child process) throttles and retries
/// instead of dying, skips aborted pending connections, and serves
/// normally once fds free up.
#[cfg(target_os = "linux")]
#[test]
fn emfile_accept_storm_in_a_tiny_fd_table_does_not_kill_the_listener() {
    use std::os::fd::AsRawFd;
    use std::os::unix::process::CommandExt;
    use std::process::Stdio;

    // raw FFI (no libc crate in the offline registry): glibc/musl Linux,
    // RLIMIT_NOFILE = 7, rlim_t = u64
    #[repr(C)]
    struct Rlimit {
        cur: u64,
        max: u64,
    }
    #[repr(C)]
    struct Linger {
        onoff: i32,
        linger: i32,
    }
    extern "C" {
        fn setrlimit(resource: i32, rlim: *const Rlimit) -> i32;
        fn setsockopt(
            fd: i32,
            level: i32,
            optname: i32,
            optval: *const std::ffi::c_void,
            optlen: u32,
        ) -> i32;
    }
    const RLIMIT_NOFILE: i32 = 7;
    const SOL_SOCKET: i32 = 1;
    const SO_LINGER: i32 = 13;

    struct ChildGuard(std::process::Child);
    impl Drop for ChildGuard {
        fn drop(&mut self) {
            let _ = self.0.kill();
            let _ = self.0.wait();
        }
    }

    let (_lock, _disarm) = fault_guard();
    // child server with ~16 fds total (stdio + listener + epoll + wake
    // eventfd leave ~10 for connections); fault statics are per-process,
    // so nothing armed here reaches it — this test is about the unarmed
    // accept path under real resource exhaustion
    let mut cmd = std::process::Command::new(env!("CARGO_BIN_EXE_repro"));
    cmd.args([
        "serve",
        "--addr",
        "127.0.0.1:0",
        "--k",
        "1",
        "--n",
        "30",
        "--shards",
        "1",
    ])
    .stdin(Stdio::null())
    .stdout(Stdio::piped())
    .stderr(Stdio::null());
    unsafe {
        cmd.pre_exec(|| {
            let lim = Rlimit { cur: 16, max: 16 };
            if setrlimit(RLIMIT_NOFILE, &lim) != 0 {
                return Err(std::io::Error::last_os_error());
            }
            Ok(())
        });
    }
    let mut child = ChildGuard(cmd.spawn().expect("spawn repro serve"));

    // the serve banner ends "… on <addr> …" and is printed before the
    // accept loop starts; line-buffered stdout delivers it through the
    // pipe
    let stdout = child.0.stdout.take().unwrap();
    let mut lines = BufReader::new(stdout);
    let addr = loop {
        let mut line = String::new();
        assert!(
            lines.read_line(&mut line).unwrap() > 0,
            "child exited before announcing its address"
        );
        if let Some(rest) = line.rsplit(" on ").next() {
            if line.contains(" on ") {
                break rest.split_whitespace().next().unwrap().to_string();
            }
        }
    };

    // storm: far more simultaneous connections than the child has fds.
    // Loopback connect() succeeds once the connection is in the listen
    // backlog, so holding them open pins the child at EMFILE.
    let mut storm = Vec::new();
    for _ in 0..24 {
        if let Ok(s) = TcpStream::connect(&addr) {
            storm.push(s);
        }
    }
    assert!(storm.len() >= 20, "loopback connect storm failed to build");
    std::thread::sleep(Duration::from_millis(300)); // let accepts hit EMFILE

    // abort half the still-pending connections with an RST (SO_LINGER 0)
    // while the table is full — the ECONNABORTED/EPROTO skip path
    for s in storm.drain(..12) {
        let lin = Linger {
            onoff: 1,
            linger: 0,
        };
        unsafe {
            setsockopt(
                s.as_raw_fd(),
                SOL_SOCKET,
                SO_LINGER,
                (&lin as *const Linger).cast(),
                std::mem::size_of::<Linger>() as u32,
            );
        }
        drop(s); // RST
    }
    drop(storm); // release every remaining fd

    // the listener must still be alive: a fresh client gets served once
    // fds free up (bounded retries — failure here is a test failure, not
    // a hang)
    let input: Vec<f64> = (0..20).map(|t| (0.3 * t as f64).sin()).collect();
    let mut served = false;
    for _ in 0..100 {
        let Ok(stream) = TcpStream::connect(&addr) else {
            std::thread::sleep(Duration::from_millis(100));
            continue;
        };
        stream
            .set_read_timeout(Some(Duration::from_secs(5)))
            .unwrap();
        let mut writer = stream.try_clone().unwrap();
        let mut reader = BufReader::new(stream);
        let req = predict_req(&input).to_string_compact();
        if writer.write_all(req.as_bytes()).is_err()
            || writer.write_all(b"\n").is_err()
        {
            std::thread::sleep(Duration::from_millis(100));
            continue;
        }
        let mut line = String::new();
        match reader.read_line(&mut line) {
            Ok(n) if n > 0 => {
                let resp = parse(line.trim()).unwrap();
                assert_eq!(
                    resp.get("ok"),
                    Some(&Json::Bool(true)),
                    "post-storm predict failed: {resp:?}"
                );
                served = true;
                break;
            }
            _ => std::thread::sleep(Duration::from_millis(100)),
        }
    }
    assert!(
        served,
        "listener never recovered from the EMFILE storm within the retry budget"
    );
}

// ---------------------------------------------------------------------------
// PR 7: live migration, standby promotion, deadline-bounded overload
// ---------------------------------------------------------------------------

/// Migration moves a lane OUT of a failure domain, mid-stream. The mover
/// streams half its run, migrates off its home shard, and then the OLD
/// home's sweeper is panicked. The migrated lane continues bit-identical
/// on the target shard (beyond the blast radius of its former home), a
/// bystander still homed on the panicked shard survives the contained
/// restart bit-identically, and only the sacrificial lane that absorbed
/// the panic is quarantined — with a typed code, never a hang.
#[test]
fn migrated_lane_survives_a_source_shard_sweeper_panic() {
    let (_lock, _disarm) = fault_guard();
    let task = MsoTask::new(1);
    let input = &task.input[..60];
    for threaded in [false, true] {
        let model = make_model(Precision::F64);
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let handle = std::thread::spawn(move || {
            serve_on_opts(
                listener,
                model,
                Some(16),
                ServeOpts {
                    shards: Some(2),
                    threaded,
                    ..Default::default()
                },
            )
            .map(|_| ())
            .unwrap();
        });

        // the uninterrupted reference run
        let mut reference = CClient::connect(&addr);
        let want = reference.output_of(&stream_req(input));

        // mover: half the run on its home shard, then migrate away
        let mut mover = CClient::connect(&addr);
        assert_eq!(mover.output_of(&stream_req(&input[..30])), want[..30]);
        let src = mover
            .info()
            .get("lane_shard")
            .and_then(Json::as_f64)
            .expect("lane_shard") as usize;
        let dst = 1 - src;
        let resp = mover.request(&migrate_req(dst));
        assert_eq!(
            resp.get("ok"),
            Some(&Json::Bool(true)),
            "migrate failed: {resp:?}"
        );
        assert_eq!(resp.get("shard").and_then(Json::as_f64), Some(dst as f64));

        // find a bystander and a sacrifice still homed on the SOURCE
        // shard (connections round-robin across the two shards, so a
        // handful of probes is guaranteed to land two there)
        let mut on_src = Vec::new();
        let mut others = Vec::new();
        while on_src.len() < 2 {
            assert!(
                on_src.len() + others.len() < 6,
                "round-robin never landed two lanes on shard {src}"
            );
            let mut c = CClient::connect(&addr);
            assert_eq!(c.output_of(&stream_req(&input[..30])), want[..30]);
            let home = c
                .info()
                .get("lane_shard")
                .and_then(Json::as_f64)
                .expect("lane_shard") as usize;
            if home == src {
                on_src.push(c);
            } else {
                others.push(c);
            }
        }
        let mut sacrifice = on_src.pop().unwrap();
        let mut bystander = on_src.pop().unwrap();

        // panic the source shard's sweeper: the sacrifice absorbs it and
        // is quarantined with typed refusals
        fault::target_sweeper_thread(&format!("lr-shard-{src}-sweeper"));
        fault::arm_sweeper_panic(1);
        sacrifice.expect_code(&stream_req(&input[30..45]), "unavailable");
        sacrifice.expect_code(&stream_req(&input[30..45]), "lane_poisoned");
        fault::disarm();

        // the bystander (still on src) survives the contained restart …
        assert_eq!(
            bystander.output_of(&stream_req(&input[30..])),
            want[30..],
            "bystander on the panicked shard diverged (threaded={threaded})"
        );
        // … and the migrated mover never felt the panic at all
        assert_eq!(
            mover.output_of(&stream_req(&input[30..])),
            want[30..],
            "migrated lane diverged after its old home panicked \
             (threaded={threaded})"
        );
        let info = mover.info();
        assert_eq!(
            info.get("lane_shard").and_then(Json::as_f64),
            Some(dst as f64),
            "migrated lane is not homed on the target shard"
        );
        assert!(
            info.get("lanes_migrated").and_then(Json::as_f64).unwrap() >= 1.0
        );

        mover.drain();
        drop(reference);
        drop(mover);
        drop(bystander);
        drop(sacrifice);
        drop(others);
        handle.join().unwrap();
    }
}

/// The acceptance-criteria failover proof: a real subprocess primary
/// streams per-lane checkpoint deltas to a warm in-test standby; the
/// primary is hard-killed (SIGKILL — no drain, no goodbye); adopting the
/// victim lane on the standby continues bit-identically to the
/// uninterrupted primary run.
#[test]
fn standby_promotion_after_primary_sigkill_is_bit_identical() {
    use std::process::Stdio;

    struct ChildGuard(std::process::Child);
    impl Drop for ChildGuard {
        fn drop(&mut self) {
            let _ = self.0.kill();
            let _ = self.0.wait();
        }
    }

    let (_lock, _disarm) = fault_guard();

    // warm standby: an in-test replica serving the SAME model the CLI
    // builds (promotion is only bit-identical if the weights are)
    let standby_model = make_cli_model(1, 30, Precision::F64);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let standby_addr = listener.local_addr().unwrap().to_string();
    let standby = std::thread::spawn(move || {
        serve_on_opts(
            listener,
            standby_model,
            Some(64),
            ServeOpts {
                shards: Some(1),
                threaded: true,
                ..Default::default()
            },
        )
        .map(|_| ())
        .unwrap();
    });

    // primary: a real subprocess pushing 20 ms delta rounds at the replica
    let mut cmd = std::process::Command::new(env!("CARGO_BIN_EXE_repro"));
    cmd.args([
        "serve",
        "--addr",
        "127.0.0.1:0",
        "--k",
        "1",
        "--n",
        "30",
        "--shards",
        "1",
        "--standby",
        &standby_addr,
        "--standby-interval-ms",
        "20",
    ])
    .stdin(Stdio::null())
    .stdout(Stdio::piped())
    .stderr(Stdio::null());
    let mut child = ChildGuard(cmd.spawn().expect("spawn repro serve"));
    let stdout = child.0.stdout.take().unwrap();
    let mut lines = BufReader::new(stdout);
    let addr = loop {
        let mut line = String::new();
        assert!(
            lines.read_line(&mut line).unwrap() > 0,
            "primary exited before announcing its address"
        );
        if line.contains(" on ") {
            break line
                .rsplit(" on ")
                .next()
                .unwrap()
                .split_whitespace()
                .next()
                .unwrap()
                .to_string();
        }
    };

    let task = MsoTask::new(1);
    let input = &task.input[..60];

    // the uninterrupted reference run, on the primary
    let mut reference = CClient::connect(&addr);
    let want = reference.output_of(&stream_req(input));

    // victim: half the run, then wait for the pusher to drain its delta
    let mut victim = CClient::connect(&addr);
    assert_eq!(victim.output_of(&stream_req(&input[..30])), want[..30]);
    let lane_id = victim
        .info()
        .get("lane_id")
        .and_then(Json::as_f64)
        .expect("lane_id") as u64;
    let patience = std::time::Instant::now() + Duration::from_secs(20);
    loop {
        let lag = victim
            .info()
            .get("standby_lag_lanes")
            .and_then(Json::as_f64)
            .expect("standby_lag_lanes");
        if lag == 0.0 {
            break;
        }
        assert!(
            std::time::Instant::now() < patience,
            "standby pusher never drained ({lag} lane(s) still lagging)"
        );
        std::thread::sleep(Duration::from_millis(25));
    }

    // hard kill — SIGKILL, so nothing on the primary gets to flush
    child.0.kill().expect("SIGKILL the primary");
    let _ = child.0.wait();

    // promote: adopt the victim's lane on the replica and continue
    let mut promoted = CClient::connect(&standby_addr);
    let resp = promoted.request(&adopt_req(lane_id));
    assert_eq!(
        resp.get("ok"),
        Some(&Json::Bool(true)),
        "promotion adopt failed: {resp:?}"
    );
    assert_eq!(
        promoted.output_of(&stream_req(&input[30..])),
        want[30..],
        "promoted standby diverged from the uninterrupted primary run"
    );

    promoted.drain();
    drop(promoted);
    drop(reference);
    drop(victim);
    standby.join().unwrap();
}

/// Overload protection under degraded I/O, on the epoll transport: with
/// socket writes shaped slow and the sweeper coalescing jobs for 80 ms,
/// a 5 ms deadline is deterministically dead by sweep time and answers
/// the typed `deadline_exceeded`; a forced zero admission depth answers
/// the typed `overloaded`; and neither refusal advances lane state — the
/// continuation stream is bit-identical to the uninterrupted reference.
/// Every read is bounded by the client timeout, so a hang FAILS.
#[cfg(target_os = "linux")]
#[test]
fn deadline_and_admission_refusals_are_typed_under_slow_writes() {
    let (_lock, _disarm) = fault_guard();
    let task = MsoTask::new(1);
    let input = &task.input[..60];
    let big: Vec<f64> = (0..3000).map(|t| (0.13 * t as f64).sin()).collect();

    let model = make_model(Precision::F64);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let handle = std::thread::spawn(move || {
        serve_on_opts(
            listener,
            model,
            Some(8),
            ServeOpts {
                // coalescing window: every job waits ~80 ms before its
                // sweep, so a 5 ms deadline expires before execution —
                // no timing race
                holdoff_us: 80_000,
                shards: Some(1),
                ..Default::default()
            },
        )
        .map(|_| ())
        .unwrap();
    });

    // unshaped reference outputs first
    let mut reference = CClient::connect(&addr);
    let want = reference.output_of(&stream_req(input));
    let want_big = reference.output_of(&stream_req(&big));

    let mut c = CClient::connect(&addr);
    assert_eq!(
        c.output_of(&with_deadline(stream_req(&input[..20]), 30_000)),
        want[..20]
    );

    // shape every poll-loop write from here on — 1 KiB per write(2) with
    // a 5 ms pre-write sleep; even the typed refusals below must flush
    // through this without tripping the client's hang bound
    fault::set_short_writes(1024, Duration::from_millis(5));

    // 5 ms << the 80 ms holdoff: expired by sweep time, typed refusal
    c.expect_code(
        &with_deadline(stream_req(&input[20..40]), 5),
        "deadline_exceeded",
    );
    c.expect_code(&with_deadline(predict_req(&input[..10]), 5), "deadline_exceeded");

    // forced zero-depth admission: shed with a type, immediately
    fault::force_admit_depth(0);
    c.expect_code(&stream_req(&input[20..40]), "overloaded");
    c.expect_code(&predict_req(&input[..10]), "overloaded");
    // clear the admission override but keep the write shaping armed
    fault::disarm();
    fault::set_short_writes(1024, Duration::from_millis(5));

    // none of the refusals advanced the lane: bit-identical continuation
    assert_eq!(
        c.output_of(&with_deadline(stream_req(&input[20..]), 30_000)),
        want[20..],
        "a typed refusal advanced lane state"
    );

    // a ~60 KiB reply through 1 KiB shaped writes: slow, bounded, correct
    let mut b = CClient::connect(&addr);
    assert_eq!(b.output_of(&stream_req(&big)), want_big);
    fault::disarm();

    // the typed-refusal accounting reached the info counters
    let info = c.info();
    assert!(
        info.get("deadline_misses").and_then(Json::as_f64).unwrap() >= 2.0,
        "deadline_misses not counted: {info:?}"
    );
    assert!(
        info.get("jobs_shed").and_then(Json::as_f64).unwrap() >= 2.0,
        "jobs_shed not counted: {info:?}"
    );

    c.drain();
    drop(reference);
    drop(c);
    drop(b);
    handle.join().unwrap();
}

// ---------------------------------------------------------------------------
// PR 8 tentpole proof: cluster failover after a real SIGKILL, with redirects
// ---------------------------------------------------------------------------

/// The acceptance-criteria cluster proof, on both transports and both
/// precisions: a three-node group (two in-test survivors running the
/// full membership config, one real subprocess primary that answers
/// their gossip pings) streams lanes on the primary while the standby
/// fan-out parks per-lane deltas on BOTH survivors. The primary is
/// SIGKILLed mid-stream. The survivors' failure detectors declare it
/// dead, the hash ring reassigns its range, and a client connected to
/// the WRONG survivor follows the `moved` redirect to the new owner,
/// adopts every affected lane there, and continues bit-identically to
/// the uninterrupted run. Every read is timeout-bounded: a hang FAILS.
#[test]
fn cluster_failover_after_primary_sigkill_redirects_and_resumes() {
    use std::process::Stdio;

    struct ChildGuard(std::process::Child);
    impl Drop for ChildGuard {
        fn drop(&mut self) {
            let _ = self.0.kill();
            let _ = self.0.wait();
        }
    }

    let (_lock, _disarm) = fault_guard();
    let task = MsoTask::new(1);
    let input = &task.input[..60];

    for precision in [Precision::F64, Precision::F32] {
        for threaded in [false, true] {
            // epoll is the Linux-only transport; elsewhere the flag is
            // inert and the combos would duplicate each other
            if !threaded && !cfg!(target_os = "linux") {
                continue;
            }
            // survivors: in-test nodes serving the CLI's exact model
            // (promotion is only bit-identical if the weights are)
            let l1 = TcpListener::bind("127.0.0.1:0").unwrap();
            let l2 = TcpListener::bind("127.0.0.1:0").unwrap();
            let s1_addr = l1.local_addr().unwrap().to_string();
            let s2_addr = l2.local_addr().unwrap().to_string();
            // pre-reserve the primary's port so the survivors can name
            // it in --peers before the subprocess exists
            let primary_addr = {
                let probe = TcpListener::bind("127.0.0.1:0").unwrap();
                let a = probe.local_addr().unwrap().to_string();
                drop(probe);
                a
            };
            let mut survivors = Vec::new();
            for (listener, advertise, peers) in [
                (
                    l1,
                    s1_addr.clone(),
                    format!("{primary_addr},{s2_addr}"),
                ),
                (
                    l2,
                    s2_addr.clone(),
                    format!("{primary_addr},{s1_addr}"),
                ),
            ] {
                let model = make_cli_model(1, 30, precision);
                survivors.push(std::thread::spawn(move || {
                    serve_on_opts(
                        listener,
                        model,
                        Some(64),
                        ServeOpts {
                            shards: Some(1),
                            threaded,
                            peers: Some(peers),
                            advertise: Some(advertise),
                            ping_interval_ms: 25,
                            ..Default::default()
                        },
                    )
                    .map(|_| ())
                    .unwrap();
                }));
            }

            // primary: a real subprocess, standby fan-out to BOTH
            // survivors. It runs unguarded (no --peers) so it owns
            // every key, but it answers the survivors' gossip pings —
            // to their detectors it is a live group member.
            let mut cmd =
                std::process::Command::new(env!("CARGO_BIN_EXE_repro"));
            cmd.args([
                "serve",
                "--addr",
                &primary_addr,
                "--k",
                "1",
                "--n",
                "30",
                "--shards",
                "1",
                "--standby",
                &format!("{s1_addr},{s2_addr}"),
                "--standby-interval-ms",
                "20",
            ]);
            if threaded {
                cmd.arg("--threaded");
            }
            if precision == Precision::F32 {
                cmd.arg("--f32");
            }
            cmd.stdin(Stdio::null()).stdout(Stdio::piped()).stderr(Stdio::null());
            let mut child = ChildGuard(cmd.spawn().expect("spawn repro serve"));
            let stdout = child.0.stdout.take().unwrap();
            let mut lines = BufReader::new(stdout);
            loop {
                let mut line = String::new();
                assert!(
                    lines.read_line(&mut line).unwrap() > 0,
                    "primary exited before announcing its address"
                );
                if line.contains(" on ") {
                    break;
                }
            }

            // uninterrupted reference, then two victim lanes cut at
            // different offsets — "every affected lane" means both
            let mut reference = CClient::connect(&primary_addr);
            let want = reference.output_of(&stream_req(input));
            let mut v1 = CClient::connect(&primary_addr);
            let mut v2 = CClient::connect(&primary_addr);
            assert_eq!(v1.output_of(&stream_req(&input[..30])), want[..30]);
            assert_eq!(v2.output_of(&stream_req(&input[..40])), want[..40]);
            let lane1 = v1.info().get("lane_id").and_then(Json::as_f64).unwrap() as u64;
            let lane2 = v2.info().get("lane_id").and_then(Json::as_f64).unwrap() as u64;
            assert_ne!(lane1, lane2);
            let info = v1.info();
            assert_eq!(
                info.get("standby_replicas").and_then(Json::as_f64),
                Some(2.0),
                "fan-out must report both replicas: {info:?}"
            );
            // wait until BOTH replicas hold every lane's latest delta
            let patience = std::time::Instant::now() + Duration::from_secs(20);
            loop {
                let lag = v1
                    .info()
                    .get("standby_lag_lanes")
                    .and_then(Json::as_f64)
                    .expect("standby_lag_lanes");
                if lag == 0.0 {
                    break;
                }
                assert!(
                    std::time::Instant::now() < patience,
                    "standby fan-out never drained ({lag} lane-replicas behind)"
                );
                std::thread::sleep(Duration::from_millis(25));
            }

            // hard kill — SIGKILL, nothing on the primary flushes
            child.0.kill().expect("SIGKILL the primary");
            let _ = child.0.wait();

            // both survivors must declare the primary dead (miss
            // threshold x ping interval) and agree on the new owner
            let mut owner = String::new();
            for addr in [&s1_addr, &s2_addr] {
                let mut probe = CClient::connect(addr);
                let patience =
                    std::time::Instant::now() + Duration::from_secs(20);
                loop {
                    let info = probe.info();
                    let live = info
                        .get("cluster_live")
                        .and_then(Json::as_f64)
                        .expect("cluster_live");
                    if live == 2.0 {
                        let o = info
                            .get("cluster_owner")
                            .and_then(Json::as_str)
                            .expect("cluster_owner")
                            .to_string();
                        if owner.is_empty() {
                            owner = o;
                        } else {
                            assert_eq!(
                                owner, o,
                                "survivors disagree on the failed-over owner"
                            );
                        }
                        break;
                    }
                    assert!(
                        std::time::Instant::now() < patience,
                        "failure detector never declared the primary dead"
                    );
                    std::thread::sleep(Duration::from_millis(25));
                }
            }
            assert!(owner == s1_addr || owner == s2_addr);
            let loser = if owner == s1_addr { &s2_addr } else { &s1_addr };

            // raw protocol view on the non-owner: key-homed ops answer
            // `moved` naming the owner
            let mut raw = CClient::connect(loser);
            let resp = raw.request(&adopt_req(lane1));
            assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
            assert_eq!(
                resp.get("code").and_then(Json::as_str),
                Some("moved"),
                "non-owner must redirect: {resp:?}"
            );
            assert_eq!(
                resp.get("addr").and_then(Json::as_str),
                Some(owner.as_str()),
                "moved must name the promoted owner"
            );
            drop(raw);

            // redirect-following clients connected to the WRONG node:
            // adopt + continue every affected lane, bit-identically
            for (lane, done) in [(lane1, 30usize), (lane2, 40usize)] {
                let mut c = Client::connect(loser).unwrap();
                c.set_io_timeout(Some(Duration::from_secs(30))).unwrap();
                c.adopt(lane).expect("promotion adopt via redirect");
                assert_eq!(
                    c.stream(&input[done..]).unwrap(),
                    want[done..],
                    "lane {lane} diverged after failover \
                     (threaded={threaded}, {:?})",
                    precision
                );
            }

            // teardown: drain both survivors (drain is guard-exempt)
            drop(reference);
            drop(v1);
            drop(v2);
            for addr in [&s1_addr, &s2_addr] {
                let mut d = CClient::connect(addr);
                d.drain();
                drop(d);
            }
            for h in survivors {
                h.join().unwrap();
            }
        }
    }
}

// ---------------------------------------------------------------------------
// satellite proof: torn standby delta frames never apply partially
// ---------------------------------------------------------------------------

/// Deterministic torn-frame hardening: with short-write shaping armed,
/// every standby delta frame is cut mid-line (the newline never leaves
/// the primary), so the replica never sees a complete request and never
/// applies a partial delta — the lane just stays lagging on the pusher.
/// Disarming lets the next round replicate the full checkpoint, and the
/// promoted lane is bit-identical. Threaded transport on both ends so
/// the shaping hits ONLY the pusher's frames.
#[test]
fn torn_standby_delta_frames_never_apply_partially() {
    let (_lock, _disarm) = fault_guard();
    let task = MsoTask::new(1);
    let input = &task.input[..60];

    // tear every pusher frame 8 bytes in, before the servers even start
    fault::set_short_writes(8, Duration::ZERO);

    let replica_model = make_model(Precision::F64);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let replica_addr = listener.local_addr().unwrap().to_string();
    let replica = std::thread::spawn(move || {
        serve_on_opts(
            listener,
            replica_model,
            Some(64),
            ServeOpts {
                shards: Some(1),
                threaded: true,
                ..Default::default()
            },
        )
        .map(|_| ())
        .unwrap();
    });

    let primary_model = make_model(Precision::F64);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let primary_addr = listener.local_addr().unwrap().to_string();
    let replica_for_primary = replica_addr.clone();
    let primary = std::thread::spawn(move || {
        serve_on_opts(
            listener,
            primary_model,
            Some(64),
            ServeOpts {
                shards: Some(1),
                threaded: true,
                standby: Some(replica_for_primary),
                standby_interval_ms: 20,
                ..Default::default()
            },
        )
        .map(|_| ())
        .unwrap();
    });

    let mut reference = CClient::connect(&primary_addr);
    let want = reference.output_of(&stream_req(input));
    let mut victim = CClient::connect(&primary_addr);
    assert_eq!(victim.output_of(&stream_req(&input[..30])), want[..30]);
    let lane_id =
        victim.info().get("lane_id").and_then(Json::as_f64).unwrap() as u64;

    // ≥10 torn push rounds: the lag never drains and the replica never
    // parks the lane (a partial frame that applied would park it)
    let mut adopt_probe = CClient::connect(&replica_addr);
    for _ in 0..3 {
        std::thread::sleep(Duration::from_millis(70));
        let lag = victim
            .info()
            .get("standby_lag_lanes")
            .and_then(Json::as_f64)
            .expect("standby_lag_lanes");
        assert!(
            lag >= 1.0,
            "a torn delta frame was counted as replicated"
        );
        adopt_probe.expect_code(&adopt_req(lane_id), "unknown_lane");
    }
    drop(adopt_probe);

    // heal the link: the next rounds push the full checkpoint
    fault::disarm();
    let patience = std::time::Instant::now() + Duration::from_secs(20);
    loop {
        let lag = victim
            .info()
            .get("standby_lag_lanes")
            .and_then(Json::as_f64)
            .expect("standby_lag_lanes");
        if lag == 0.0 {
            break;
        }
        assert!(
            std::time::Instant::now() < patience,
            "standby lag never drained after disarm ({lag} behind)"
        );
        std::thread::sleep(Duration::from_millis(25));
    }

    // promote on the replica: bit-identical continuation
    let mut promoted = CClient::connect(&replica_addr);
    let resp = promoted.request(&adopt_req(lane_id));
    assert_eq!(
        resp.get("ok"),
        Some(&Json::Bool(true)),
        "promotion adopt failed: {resp:?}"
    );
    assert_eq!(
        promoted.output_of(&stream_req(&input[30..])),
        want[30..],
        "replica state diverged after torn-frame rounds"
    );

    // teardown: drain the primary first (stops the pusher), then the
    // replica
    victim.drain();
    drop(victim);
    drop(reference);
    primary.join().unwrap();
    promoted.drain();
    drop(promoted);
    replica.join().unwrap();
}

// ---------------------------------------------------------------------------
// PR 9: registry budget exhaustion — typed refusal, nothing allocated
// ---------------------------------------------------------------------------

fn create_model_req(seed: u64, n: usize, sr: f64) -> Json {
    Json::obj(vec![
        ("op", Json::Str("create_model".into())),
        ("seed", Json::Num(seed as f64)),
        ("n", Json::Num(n as f64)),
        ("spectral_radius", Json::Num(sr)),
    ])
}

fn bind_model_req(model: u64) -> Json {
    Json::obj(vec![
        ("op", Json::Str("ping".into())),
        ("model", Json::Num(model as f64)),
    ])
}

/// `create_model` past `--max-models` must answer the typed
/// `model_budget` error BEFORE minting anything: the registry count is
/// unchanged, the refused recipe's (deterministic) id stays unknown, the
/// already-registered tenant keeps serving, and the idempotent re-create
/// of an existing recipe still succeeds inside the exhausted budget —
/// on both transports.
#[test]
fn model_budget_exhaustion_refuses_typed_and_allocates_nothing() {
    use linear_reservoir::server::ModelRecipe;
    let model = make_model(Precision::F64);
    let task = MsoTask::new(1);
    for threaded in [false, true] {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let m = Arc::clone(&model);
        let handle = std::thread::spawn(move || {
            serve_on_opts(
                listener,
                m,
                Some(8),
                ServeOpts {
                    shards: Some(1),
                    threaded,
                    max_models: Some(1),
                    ..Default::default()
                },
            )
            .map(|_| ())
            .unwrap();
        });
        let mut c = CClient::connect(&addr);
        // fill the single budget slot
        let resp = c.request(&create_model_req(7, 40, 0.8));
        assert_eq!(
            resp.get("ok"),
            Some(&Json::Bool(true)),
            "first create must fit the budget: {resp:?}"
        );
        let a = resp.get("model").and_then(Json::as_f64).unwrap() as u64;
        // the wall: a second DISTINCT recipe refuses typed
        c.expect_code(&create_model_req(8, 40, 0.8), "model_budget");
        // nothing was allocated: exactly one tenant registered
        let info = c.info();
        assert_eq!(
            info.get("models").and_then(Json::as_f64),
            Some(1.0),
            "threaded={threaded}: a refused create left registry residue"
        );
        assert_eq!(info.get("max_models").and_then(Json::as_f64), Some(1.0));
        // the refused recipe's id (a pure function of the recipe) does
        // not exist — no half-created tenant to bind to
        let refused = ModelRecipe::new(8, 40, 0.8, "uniform").unwrap().id();
        let mut c2 = CClient::connect(&addr);
        c2.expect_code(&bind_model_req(refused), "unknown_model");
        // idempotent re-create of the EXISTING recipe still succeeds
        // against the exhausted budget (nothing new to allocate)
        let resp = c.request(&create_model_req(7, 40, 0.8));
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(resp.get("created"), Some(&Json::Bool(false)));
        assert_eq!(resp.get("model").and_then(Json::as_f64), Some(a as f64));
        // and the registered tenant still serves
        let mut ct = CClient::connect(&addr);
        let bound = ct.request(&bind_model_req(a));
        assert_eq!(bound.get("ok"), Some(&Json::Bool(true)));
        let out = ct.output_of(&stream_req(&task.input[..10]));
        assert_eq!(out.len(), 10);
        c.drain();
        drop(c);
        drop(c2);
        drop(ct);
        handle.join().unwrap();
    }
}

/// PR 10: killing ONE poll thread of a multi-thread event loop must not
/// take the server down. The victim thread's connections each receive a
/// final typed `unavailable` and a clean close; sibling threads' conns
/// keep serving bit-identically; new connections are dealt around the
/// dead thread; the per-thread observability stays readable.
#[cfg(target_os = "linux")]
#[test]
fn poll_thread_kill_leaves_sibling_threads_serving() {
    let (_g, _d) = fault_guard();
    let model = make_model(Precision::F64);
    let task = MsoTask::new(1);
    // P = 2 poll threads on the event-loop transport, one shard
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let server_model = Arc::clone(&model);
    let handle = std::thread::spawn(move || {
        serve_on_opts(
            listener,
            server_model,
            Some(3),
            ServeOpts {
                shards: Some(1),
                poll_threads: 2,
                ..Default::default()
            },
        )
        .map(|_| ())
        .unwrap();
    });
    // round-robin dealing: conn 0 → poll thread 0, conn 1 → poll thread 1
    let mut survivor = CClient::connect(&addr);
    let mut victim = CClient::connect(&addr);
    let home = |c: &mut CClient| {
        c.info().get("poll_thread").and_then(Json::as_f64).unwrap() as usize
    };
    assert_eq!(home(&mut survivor), 0);
    assert_eq!(home(&mut victim), 1);
    // arm the kill; thread 1 consumes it at the head of its next
    // readiness round — poke it awake with a ping (whose reply may or
    // may not beat the kill, so read everything until EOF below)
    fault::arm_poll_thread_kill(1);
    victim
        .writer
        .write_all(op("ping").to_string_compact().as_bytes())
        .unwrap();
    victim.writer.write_all(b"\n").unwrap();
    let mut saw_unavailable = false;
    let mut line = String::new();
    loop {
        line.clear();
        let n = victim
            .reader
            .read_line(&mut line)
            .expect("typed goodbye then EOF, not a hang");
        if n == 0 {
            break; // clean close after the goodbye
        }
        let resp = parse(line.trim()).unwrap();
        if resp.get("code").and_then(Json::as_str) == Some("unavailable") {
            saw_unavailable = true;
        }
    }
    assert!(
        saw_unavailable,
        "the victim connection must get a typed `unavailable` goodbye \
         before the close"
    );
    // sibling thread 0's connection keeps serving, bit-identically
    let want = model.predict(&task.input[..12]);
    let out = survivor.output_of(&predict_req(&task.input[..12]));
    for (a, b) in out.iter().zip(&want) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
    // a NEW connection is dealt to a live thread and serves
    let mut fresh = CClient::connect(&addr);
    let out = fresh.output_of(&predict_req(&task.input[..12]));
    for (a, b) in out.iter().zip(&want) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
    // observability survives the death: still two round counters
    let info = fresh.info();
    assert_eq!(info.get("poll_threads").and_then(Json::as_f64), Some(2.0));
    assert_eq!(
        info.get("poll_rounds").and_then(Json::as_arr).map(|a| a.len()),
        Some(2)
    );
    drop(victim);
    drop(survivor);
    drop(fresh);
    handle.join().unwrap();
}
