//! The f32 lane engine vs the f64 oracle — error-budget harness and SoA
//! layout properties (randomized via the property harness).
//!
//! ## Error-budget model
//!
//! The f32 engine differs from the oracle through (a) one-time parameter
//! rounding of `(Λ, [W_in]_Q, W_out)` and (b) per-step arithmetic
//! rounding, both ~`ε₃₂ = 2⁻²³` relative. A relative eigenvalue
//! perturbation `ε` reaches the state amplified by the effective memory
//! horizon `min(T, (1−|λ|max)⁻¹)` — beyond the horizon the contraction
//! forgets old rounding as fast as new rounding arrives, so the error
//! saturates. The fused readout folds the feature error through
//! `Σ|w_j·f_j|` (no cancellation credit is taken). The asserted bounds:
//!
//! ```text
//! |f32_feat − f64_feat|  ≤ C·ε₃₂·H·max|feat|          H = min(T, (1−ρ)⁻¹)
//! |f32_y    − f64_y|     ≤ C·ε₃₂·(H + √N)·max_t Σ_j |w_j·f_j(t)| + |b|·ε₃₂·C
//! ```
//!
//! with `C = 32` margin. Both scale with `T·(1−|λ|max)⁻¹` in the regime
//! where `T` is below the horizon, and saturate past it.

use linear_reservoir::coordinator::WorkerPool;
use linear_reservoir::linalg::Mat;
use linear_reservoir::metrics::nrmse;
use linear_reservoir::readout::{GramAcc, GramStats, Readout};
use linear_reservoir::reservoir::parallel::{
    run_parallel_batch_train_prec, TrainSpec,
};
use linear_reservoir::reservoir::{BatchEsn, DiagonalEsn, EsnConfig, QBasisEsn};
use linear_reservoir::rng::{Distributions, Pcg64};
use linear_reservoir::spectral::uniform::uniform_spectrum;
use linear_reservoir::testing::check;

const EPS32: f64 = f32::EPSILON as f64;
const C_BOUND: f64 = 32.0;

fn qbasis(n: usize, rho: f64, seed: u64) -> QBasisEsn {
    let config = EsnConfig::default().with_n(n).with_seed(seed);
    let mut rng = Pcg64::new(seed, 150);
    let spec = uniform_spectrum(n, rho, &mut rng);
    let diag = DiagonalEsn::from_dpg(spec, &config, &mut rng);
    QBasisEsn::from_diagonal(&diag)
}

fn column(u: &Mat, b: usize) -> Mat {
    let col: Vec<f64> = (0..u.rows()).map(|t| u[(t, b)]).collect();
    Mat::from_rows(u.rows(), 1, &col)
}

/// Effective memory horizon `min(T, (1−ρ)⁻¹)` of the error recursion.
fn horizon(t_len: usize, rho: f64) -> f64 {
    (1.0 / (1.0 - rho)).min(t_len as f64)
}

#[test]
fn prop_f32_features_within_error_budget_of_f64_oracle() {
    check("f32 features ≤ budget vs f64", 12, |rng| {
        let n = 16 + rng.next_below(120) as usize;
        let rho = rng.uniform(0.5, 0.95);
        let b = 1 + rng.next_below(6) as usize;
        let t_len = 200;
        let q = qbasis(n, rho, rng.next_u64());
        let u = Mat::randn(t_len, b, rng);
        let mut e32 = BatchEsn::<f32>::with_precision(q.clone(), b);
        e32.sweep(&u);
        let budget = C_BOUND * EPS32 * horizon(t_len, rho);
        let mut feat32 = vec![0.0; n];
        for lane in 0..b {
            let oracle = q.run(&column(&u, lane)); // [T × N] f64 features
            e32.lane_state(lane, &mut feat32);
            let fscale = oracle
                .data()
                .iter()
                .fold(1e-30f64, |m, x| m.max(x.abs()));
            let last = oracle.row(t_len - 1);
            let mut worst = 0.0f64;
            for (a, bfeat) in feat32.iter().zip(last) {
                worst = worst.max((a - bfeat).abs());
            }
            let rel = worst / fscale;
            if rel > budget {
                return Err(format!(
                    "n={n} ρ={rho:.3} lane={lane}: rel feature error \
                     {rel:.3e} > budget {budget:.3e}"
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_f32_readout_within_error_budget_of_f64_oracle() {
    check("f32 readout ≤ budget vs f64", 12, |rng| {
        let n = 16 + rng.next_below(120) as usize;
        let rho = rng.uniform(0.5, 0.95);
        let b = 1 + rng.next_below(4) as usize;
        let t_len = 200;
        let q = qbasis(n, rho, rng.next_u64());
        let ro = Readout {
            w: Mat::randn(n, 1, rng),
            b: vec![rng.normal()],
        };
        let u = Mat::randn(t_len, b, rng);
        let mut e32 = BatchEsn::<f32>::with_precision(q.clone(), b);
        let y32 = e32.run_readout(&u, &ro);
        let hor = horizon(t_len, rho);
        for lane in 0..b {
            let u1 = column(&u, lane);
            let want = q.run_readout(&u1, &ro); // f64 oracle outputs
            let feats = q.run(&u1); // for the conditioning factor
            // amplitude the rounding passes through: max_t Σ_j |w_j·f_j|
            let mut amp = 0.0f64;
            for t in 0..t_len {
                let row = feats.row(t);
                let mut s = ro.b[0].abs();
                for (j, &f) in row.iter().enumerate() {
                    s += (f * ro.w[(j, 0)]).abs();
                }
                amp = amp.max(s);
            }
            let budget =
                C_BOUND * EPS32 * (hor + (n as f64).sqrt()) * amp.max(1e-30);
            let mut worst = 0.0f64;
            for t in 0..t_len {
                worst = worst.max((y32[(t, lane)] - want[(t, 0)]).abs());
            }
            if worst > budget {
                return Err(format!(
                    "n={n} ρ={rho:.3} lane={lane}: abs readout error \
                     {worst:.3e} > budget {budget:.3e} (amp {amp:.3e})"
                ));
            }
            if y32.row(t_len - 1).iter().any(|v| !v.is_finite()) {
                return Err(format!("n={n} lane={lane}: non-finite output"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_error_budget_scales_with_contraction_horizon() {
    // sanity of the budget MODEL itself: a fast-forgetting spectrum
    // (ρ = 0.3) must land an order of magnitude under the slow-spectrum
    // budget — i.e. the horizon term is doing real work, the bound is not
    // just a huge constant
    check("budget scales with (1−ρ)⁻¹", 6, |rng| {
        let n = 40 + rng.next_below(40) as usize;
        let t_len = 150;
        let rho = 0.3;
        let q = qbasis(n, rho, rng.next_u64());
        let u = Mat::randn(t_len, 1, rng);
        let mut e32 = BatchEsn::<f32>::with_precision(q.clone(), 1);
        e32.sweep(&u);
        let oracle = q.run(&u);
        let mut feat32 = vec![0.0; n];
        e32.lane_state(0, &mut feat32);
        let fscale = oracle
            .data()
            .iter()
            .fold(1e-30f64, |m, x| m.max(x.abs()));
        let mut worst = 0.0f64;
        for (a, bfeat) in feat32.iter().zip(oracle.row(t_len - 1)) {
            worst = worst.max((a - bfeat).abs());
        }
        let rel = worst / fscale;
        // tight-spectrum budget (the ρ = 0.95 horizon would be 20; here
        // the horizon is ~1.4, so the same C must still cover it)
        let budget = C_BOUND * EPS32 * horizon(t_len, rho);
        if rel > budget {
            return Err(format!(
                "n={n}: rel {rel:.3e} > tight budget {budget:.3e}"
            ));
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// SoA layout properties (both precisions)
// ---------------------------------------------------------------------------

#[test]
fn prop_all_inactive_masked_step_is_a_noop_both_precisions() {
    check("step_masked(all-inactive) is a no-op", 8, |rng| {
        let n = 8 + rng.next_below(40) as usize;
        let b = 1 + rng.next_below(9) as usize;
        let q = qbasis(n, rng.uniform(0.3, 0.95), rng.next_u64());

        fn run_case<S: linear_reservoir::num::Scalar>(
            q: &QBasisEsn,
            b: usize,
            rng: &mut Pcg64,
        ) -> Result<(), String> {
            let n = q.n();
            let mut e = BatchEsn::<S>::with_precision(q.clone(), b);
            // warm every lane to a non-trivial state
            for _ in 0..12 {
                let u: Vec<f64> = (0..b).map(|_| rng.normal()).collect();
                e.step(&u);
            }
            let before: Vec<Vec<f64>> = (0..b)
                .map(|lane| {
                    let mut s = vec![0.0; n];
                    e.lane_state(lane, &mut s);
                    s
                })
                .collect();
            let inactive = vec![false; b];
            for _ in 0..5 {
                let u: Vec<f64> = (0..b).map(|_| rng.normal() * 100.0).collect();
                e.step_masked(&u, &inactive);
            }
            for (lane, want) in before.iter().enumerate() {
                let mut after = vec![0.0; n];
                e.lane_state(lane, &mut after);
                if after != *want {
                    return Err(format!(
                        "{} lane {lane} moved under an all-inactive mask",
                        S::NAME
                    ));
                }
            }
            Ok(())
        }

        run_case::<f64>(&q, b, rng)?;
        run_case::<f32>(&q, b, rng)
    });
}

#[test]
fn prop_lane_results_independent_of_batch_position_both_precisions() {
    // THE SoA invariant: a lane's trajectory depends only on its own
    // input, never on its position in the planes or on the batch size —
    // bit-for-bit, at both precisions (this is what makes the F32 serving
    // paths mutually consistent)
    check("lane ⊥ batch position", 8, |rng| {
        let n = 8 + rng.next_below(40) as usize;
        let t_len = 30;
        let q = qbasis(n, rng.uniform(0.3, 0.95), rng.next_u64());
        let input = Mat::randn(t_len, 1, rng);
        let ro = Readout {
            w: Mat::randn(n, 1, rng),
            b: vec![rng.normal()],
        };
        let b1 = 1 + rng.next_below(10) as usize;
        let b2 = 1 + rng.next_below(10) as usize;
        let p1 = rng.next_below(b1 as u64) as usize;
        let p2 = rng.next_below(b2 as u64) as usize;

        fn outputs_at<S: linear_reservoir::num::Scalar>(
            q: &QBasisEsn,
            input: &Mat,
            ro: &Readout,
            batch: usize,
            pos: usize,
            rng: &mut Pcg64,
        ) -> Vec<f64> {
            let t_len = input.rows();
            // distinct noise in every other lane so cross-talk would show
            let mut u = Mat::randn(t_len, batch, rng);
            for t in 0..t_len {
                u[(t, pos)] = input[(t, 0)];
            }
            let mut e = BatchEsn::<S>::with_precision(q.clone(), batch);
            let y = e.run_readout(&u, ro);
            (0..t_len).map(|t| y[(t, pos)]).collect()
        }

        fn case<S: linear_reservoir::num::Scalar>(
            q: &QBasisEsn,
            input: &Mat,
            ro: &Readout,
            (b1, p1): (usize, usize),
            (b2, p2): (usize, usize),
            rng: &mut Pcg64,
        ) -> Result<(), String> {
            let a = outputs_at::<S>(q, input, ro, b1, p1, rng);
            let b = outputs_at::<S>(q, input, ro, b2, p2, rng);
            for (t, (x, y)) in a.iter().zip(&b).enumerate() {
                if x != y {
                    return Err(format!(
                        "{}: lane output differs by position at t={t}: \
                         ({b1},{p1}) → {x} vs ({b2},{p2}) → {y}",
                        S::NAME
                    ));
                }
            }
            Ok(())
        }

        case::<f64>(&q, &input, &ro, (b1, p1), (b2, p2), rng)?;
        case::<f32>(&q, &input, &ro, (b1, p1), (b2, p2), rng)
    });
}

// ---------------------------------------------------------------------------
// training stack: streaming Gram accumulation + precision budget
// ---------------------------------------------------------------------------

fn copy_rows(m: &Mat, lo: usize, hi: usize) -> Mat {
    let mut out = Mat::zeros(hi - lo, m.cols());
    for (r, t) in (lo..hi).enumerate() {
        out.row_mut(r).copy_from_slice(m.row(t));
    }
    out
}

#[test]
fn prop_chunked_gram_acc_push_and_merge_bit_identical_to_monolithic() {
    // the streaming accumulator's exactness contract at f64:
    //  (a) ANY chunking of a row stream into one GramAcc ≡ the monolithic
    //      GramStats::new over the same rows (the carry keeps the rank-2
    //      pairing aligned across chunk boundaries), and
    //  (b) a merge of two independently-chunked streams ≡ the merge of
    //      their monolithic one-push accumulators (chunking-invariance
    //      composes through the deterministic reduction).
    // Bitwise comparison surface: the solved ridge readouts (a
    // deterministic function of the statistics).
    check("GramAcc push/merge ≡ GramStats::new (f64, bitwise)", 16, |rng| {
        let t = 20 + rng.next_below(180) as usize;
        let f = 2 + rng.next_below(10) as usize;
        let d = 1 + rng.next_below(3) as usize;
        let x = Mat::randn(t, f, rng);
        let y = Mat::randn(t, d, rng);
        let solve_points = [(1e-6, 1.0), (0.3, 0.05)];

        // (a) random chunking vs monolithic
        let mut acc = GramAcc::<f64>::new(f, d);
        let mut lo = 0;
        while lo < t {
            let len = 1 + rng.next_below((t - lo) as u64) as usize;
            acc.push_rows(&copy_rows(&x, lo, lo + len), &copy_rows(&y, lo, lo + len));
            lo += len;
        }
        let mono = GramStats::new(&x, &y);
        for (alpha, s) in solve_points {
            let got = acc.solve_scaled(alpha, s).map_err(|e| e.to_string())?;
            let want = mono.solve_scaled(alpha, s).map_err(|e| e.to_string())?;
            if got.w.data() != want.w.data() || got.b != want.b {
                return Err(format!(
                    "t={t} f={f} d={d} α={alpha} s={s}: chunked push \
                     diverged from GramStats::new"
                ));
            }
        }

        // (b) split + merge, each side randomly chunked
        let k = rng.next_below(t as u64 + 1) as usize;
        let chunked = |rng: &mut Pcg64, lo0: usize, hi: usize| {
            let mut a = GramAcc::<f64>::new(f, d);
            let mut lo = lo0;
            while lo < hi {
                let len = 1 + rng.next_below((hi - lo) as u64) as usize;
                a.push_rows(
                    &copy_rows(&x, lo, lo + len),
                    &copy_rows(&y, lo, lo + len),
                );
                lo += len;
            }
            a
        };
        let mut merged = chunked(&mut *rng, 0, k);
        merged.merge(chunked(&mut *rng, k, t));
        // reference: one-push (monolithic) per stream, merged in order
        let mut want = GramAcc::<f64>::new(f, d);
        want.push_rows(&copy_rows(&x, 0, k), &copy_rows(&y, 0, k));
        let mut right = GramAcc::<f64>::new(f, d);
        right.push_rows(&copy_rows(&x, k, t), &copy_rows(&y, k, t));
        want.merge(right);
        for (alpha, s) in solve_points {
            let got = merged.solve_scaled(alpha, s).map_err(|e| e.to_string())?;
            let ref_ro = want.solve_scaled(alpha, s).map_err(|e| e.to_string())?;
            if got.w.data() != ref_ro.w.data() || got.b != ref_ro.b {
                return Err(format!(
                    "t={t} k={k}: merged chunked streams diverged from \
                     merged monolithic streams"
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn f32_fused_training_nrmse_within_conditioned_budget_of_f64() {
    // END-TO-END f32 training (state scan + Gram accumulation + ridge
    // solve, all at f32) vs the all-f64 oracle on a next-step-forecast
    // task. Error model (the PR-2 budget extended through the normal
    // equations): feature rounding reaches the statistics amplified by
    // the memory horizon H = min(T, (1−ρ)⁻¹); the solve amplifies the
    // relative statistic perturbation by at most the ridge condition
    // proxy κ = 1 + λmax(G)/α ≤ 1 + trace(G)/α; the prediction error is
    // that relative error times the readout amplitude; NRMSE divides by
    // the target std. With C = 32 margin:
    //
    //   |nrmse32 − nrmse64| ≤ C·ε₃₂·H·κ·amp / σ_y
    let n = 64;
    let rho = 0.9;
    let t_total = 600;
    let train = 100..500;
    let test = 500..600;
    let config = EsnConfig::default().with_n(n).with_seed(77);
    let mut rng = Pcg64::new(77, 170);
    let spec = uniform_spectrum(n, rho, &mut rng);
    let esn = DiagonalEsn::from_dpg(spec, &config, &mut rng);
    // sine-mixture next-step forecast
    let series: Vec<f64> = (0..=t_total)
        .map(|t| (0.2 * t as f64).sin() + (0.311 * t as f64).sin())
        .collect();
    let u = Mat::from_rows(t_total, 1, &series[..t_total]);
    let y_train = Mat::from_rows(
        train.len(),
        1,
        &series[train.start + 1..train.end + 1],
    );
    let y_test = Mat::from_rows(
        test.len(),
        1,
        &series[test.start + 1..test.end + 1],
    );
    let pool = WorkerPool::new(2);
    let tspec = TrainSpec {
        train: train.clone(),
        // materialize the test span (for evaluation) and the train span
        // (only to compute the budget's trace term — the f32 path never
        // sees it)
        eval: vec![test.clone(), train.clone()],
    };

    let (a64, mut evals) = run_parallel_batch_train_prec::<f64>(
        &esn,
        std::slice::from_ref(&u),
        std::slice::from_ref(&y_train),
        std::slice::from_ref(&tspec),
        &pool,
        128,
    );
    let mut spans = evals.pop().unwrap();
    let x_train = spans.pop().unwrap();
    let x_test = spans.pop().unwrap();
    let (a32, _) = run_parallel_batch_train_prec::<f32>(
        &esn,
        std::slice::from_ref(&u),
        std::slice::from_ref(&y_train),
        std::slice::from_ref(&tspec),
        &pool,
        128,
    );

    // α relative to the Gram scale: trace(G) = Σ_t ‖x_t‖²
    let trace: f64 = x_train.data().iter().map(|v| v * v).sum();
    let alpha = 1e-3 * trace;
    let ro64 = a64.solve_scaled(alpha, 1.0).unwrap();
    let ro32 = a32.solve_scaled(alpha, 1.0).unwrap();
    // both evaluated on the SAME f64 test features: the delta isolates
    // the training path (accumulate + solve), which is what's budgeted
    let nrmse64 = nrmse(&ro64.predict(&x_test), &y_test);
    let nrmse32 = nrmse(&ro32.predict(&x_test), &y_test);
    assert!(
        nrmse64 < 0.5,
        "fused f64 training failed to learn the task: NRMSE {nrmse64}"
    );
    assert!(nrmse32.is_finite(), "f32 training produced non-finite NRMSE");

    let hor = horizon(train.len(), rho);
    let kappa = 1.0 + trace / alpha;
    // readout amplitude the rounding passes through (f64 fit, no
    // cancellation credit)
    let mut amp = 0.0f64;
    for t in 0..x_test.rows() {
        let row = x_test.row(t);
        let mut s = ro64.b[0].abs();
        for (j, &f) in row.iter().enumerate() {
            s += (f * ro64.w[(j, 0)]).abs();
        }
        amp = amp.max(s);
    }
    let sigma_y = {
        let m = y_test.data().iter().sum::<f64>() / y_test.rows() as f64;
        (y_test.data().iter().map(|v| (v - m) * (v - m)).sum::<f64>()
            / y_test.rows() as f64)
            .sqrt()
            .max(1e-30)
    };
    let budget = C_BOUND * EPS32 * hor * kappa * amp / sigma_y;
    let delta = (nrmse32 - nrmse64).abs();
    assert!(
        delta <= budget,
        "f32 training NRMSE delta {delta:.3e} exceeds budget {budget:.3e} \
         (nrmse64={nrmse64:.3e}, nrmse32={nrmse32:.3e}, κ={kappa:.1e}, H={hor:.1})"
    );
    // and the f32 path genuinely ran at f32
    assert!(
        ro64.w.max_abs_diff(&ro32.w) > 0.0,
        "f32 training suspiciously exact (ran at f64?)"
    );
}

#[test]
fn f32_wire_values_roundtrip_exactly_through_f64_json_boundary() {
    // the server's wire contract: f32-computed outputs cross the JSON
    // boundary as f64 — widening then re-narrowing must be the identity,
    // so the wire loses nothing
    let q = qbasis(30, 0.9, 99);
    let mut rng = Pcg64::seeded(100);
    let ro = Readout {
        w: Mat::randn(30, 1, &mut rng),
        b: vec![0.2],
    };
    let u = Mat::randn(60, 2, &mut rng);
    let mut e = BatchEsn::<f32>::with_precision(q, 2);
    let y = e.run_readout(&u, &ro);
    for t in 0..60 {
        for lane in 0..2 {
            let wide = y[(t, lane)]; // f64 at the API boundary
            assert_eq!(
                (wide as f32) as f64,
                wide,
                "f32-computed value not exactly representable at the wire"
            );
        }
    }
}
