//! The f32 lane engine vs the f64 oracle — error-budget harness and SoA
//! layout properties (randomized via the property harness).
//!
//! ## Error-budget model
//!
//! The f32 engine differs from the oracle through (a) one-time parameter
//! rounding of `(Λ, [W_in]_Q, W_out)` and (b) per-step arithmetic
//! rounding, both ~`ε₃₂ = 2⁻²³` relative. A relative eigenvalue
//! perturbation `ε` reaches the state amplified by the effective memory
//! horizon `min(T, (1−|λ|max)⁻¹)` — beyond the horizon the contraction
//! forgets old rounding as fast as new rounding arrives, so the error
//! saturates. The fused readout folds the feature error through
//! `Σ|w_j·f_j|` (no cancellation credit is taken). The asserted bounds:
//!
//! ```text
//! |f32_feat − f64_feat|  ≤ C·ε₃₂·H·max|feat|          H = min(T, (1−ρ)⁻¹)
//! |f32_y    − f64_y|     ≤ C·ε₃₂·(H + √N)·max_t Σ_j |w_j·f_j(t)| + |b|·ε₃₂·C
//! ```
//!
//! with `C = 32` margin. Both scale with `T·(1−|λ|max)⁻¹` in the regime
//! where `T` is below the horizon, and saturate past it.

use linear_reservoir::linalg::Mat;
use linear_reservoir::readout::Readout;
use linear_reservoir::reservoir::{BatchEsn, DiagonalEsn, EsnConfig, QBasisEsn};
use linear_reservoir::rng::{Distributions, Pcg64};
use linear_reservoir::spectral::uniform::uniform_spectrum;
use linear_reservoir::testing::check;

const EPS32: f64 = f32::EPSILON as f64;
const C_BOUND: f64 = 32.0;

fn qbasis(n: usize, rho: f64, seed: u64) -> QBasisEsn {
    let config = EsnConfig::default().with_n(n).with_seed(seed);
    let mut rng = Pcg64::new(seed, 150);
    let spec = uniform_spectrum(n, rho, &mut rng);
    let diag = DiagonalEsn::from_dpg(spec, &config, &mut rng);
    QBasisEsn::from_diagonal(&diag)
}

fn column(u: &Mat, b: usize) -> Mat {
    let col: Vec<f64> = (0..u.rows()).map(|t| u[(t, b)]).collect();
    Mat::from_rows(u.rows(), 1, &col)
}

/// Effective memory horizon `min(T, (1−ρ)⁻¹)` of the error recursion.
fn horizon(t_len: usize, rho: f64) -> f64 {
    (1.0 / (1.0 - rho)).min(t_len as f64)
}

#[test]
fn prop_f32_features_within_error_budget_of_f64_oracle() {
    check("f32 features ≤ budget vs f64", 12, |rng| {
        let n = 16 + rng.next_below(120) as usize;
        let rho = rng.uniform(0.5, 0.95);
        let b = 1 + rng.next_below(6) as usize;
        let t_len = 200;
        let q = qbasis(n, rho, rng.next_u64());
        let u = Mat::randn(t_len, b, rng);
        let mut e32 = BatchEsn::<f32>::with_precision(q.clone(), b);
        e32.sweep(&u);
        let budget = C_BOUND * EPS32 * horizon(t_len, rho);
        let mut feat32 = vec![0.0; n];
        for lane in 0..b {
            let oracle = q.run(&column(&u, lane)); // [T × N] f64 features
            e32.lane_state(lane, &mut feat32);
            let fscale = oracle
                .data()
                .iter()
                .fold(1e-30f64, |m, x| m.max(x.abs()));
            let last = oracle.row(t_len - 1);
            let mut worst = 0.0f64;
            for (a, bfeat) in feat32.iter().zip(last) {
                worst = worst.max((a - bfeat).abs());
            }
            let rel = worst / fscale;
            if rel > budget {
                return Err(format!(
                    "n={n} ρ={rho:.3} lane={lane}: rel feature error \
                     {rel:.3e} > budget {budget:.3e}"
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_f32_readout_within_error_budget_of_f64_oracle() {
    check("f32 readout ≤ budget vs f64", 12, |rng| {
        let n = 16 + rng.next_below(120) as usize;
        let rho = rng.uniform(0.5, 0.95);
        let b = 1 + rng.next_below(4) as usize;
        let t_len = 200;
        let q = qbasis(n, rho, rng.next_u64());
        let ro = Readout {
            w: Mat::randn(n, 1, rng),
            b: vec![rng.normal()],
        };
        let u = Mat::randn(t_len, b, rng);
        let mut e32 = BatchEsn::<f32>::with_precision(q.clone(), b);
        let y32 = e32.run_readout(&u, &ro);
        let hor = horizon(t_len, rho);
        for lane in 0..b {
            let u1 = column(&u, lane);
            let want = q.run_readout(&u1, &ro); // f64 oracle outputs
            let feats = q.run(&u1); // for the conditioning factor
            // amplitude the rounding passes through: max_t Σ_j |w_j·f_j|
            let mut amp = 0.0f64;
            for t in 0..t_len {
                let row = feats.row(t);
                let mut s = ro.b[0].abs();
                for (j, &f) in row.iter().enumerate() {
                    s += (f * ro.w[(j, 0)]).abs();
                }
                amp = amp.max(s);
            }
            let budget =
                C_BOUND * EPS32 * (hor + (n as f64).sqrt()) * amp.max(1e-30);
            let mut worst = 0.0f64;
            for t in 0..t_len {
                worst = worst.max((y32[(t, lane)] - want[(t, 0)]).abs());
            }
            if worst > budget {
                return Err(format!(
                    "n={n} ρ={rho:.3} lane={lane}: abs readout error \
                     {worst:.3e} > budget {budget:.3e} (amp {amp:.3e})"
                ));
            }
            if y32.row(t_len - 1).iter().any(|v| !v.is_finite()) {
                return Err(format!("n={n} lane={lane}: non-finite output"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_error_budget_scales_with_contraction_horizon() {
    // sanity of the budget MODEL itself: a fast-forgetting spectrum
    // (ρ = 0.3) must land an order of magnitude under the slow-spectrum
    // budget — i.e. the horizon term is doing real work, the bound is not
    // just a huge constant
    check("budget scales with (1−ρ)⁻¹", 6, |rng| {
        let n = 40 + rng.next_below(40) as usize;
        let t_len = 150;
        let rho = 0.3;
        let q = qbasis(n, rho, rng.next_u64());
        let u = Mat::randn(t_len, 1, rng);
        let mut e32 = BatchEsn::<f32>::with_precision(q.clone(), 1);
        e32.sweep(&u);
        let oracle = q.run(&u);
        let mut feat32 = vec![0.0; n];
        e32.lane_state(0, &mut feat32);
        let fscale = oracle
            .data()
            .iter()
            .fold(1e-30f64, |m, x| m.max(x.abs()));
        let mut worst = 0.0f64;
        for (a, bfeat) in feat32.iter().zip(oracle.row(t_len - 1)) {
            worst = worst.max((a - bfeat).abs());
        }
        let rel = worst / fscale;
        // tight-spectrum budget (the ρ = 0.95 horizon would be 20; here
        // the horizon is ~1.4, so the same C must still cover it)
        let budget = C_BOUND * EPS32 * horizon(t_len, rho);
        if rel > budget {
            return Err(format!(
                "n={n}: rel {rel:.3e} > tight budget {budget:.3e}"
            ));
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// SoA layout properties (both precisions)
// ---------------------------------------------------------------------------

#[test]
fn prop_all_inactive_masked_step_is_a_noop_both_precisions() {
    check("step_masked(all-inactive) is a no-op", 8, |rng| {
        let n = 8 + rng.next_below(40) as usize;
        let b = 1 + rng.next_below(9) as usize;
        let q = qbasis(n, rng.uniform(0.3, 0.95), rng.next_u64());

        fn run_case<S: linear_reservoir::num::Scalar>(
            q: &QBasisEsn,
            b: usize,
            rng: &mut Pcg64,
        ) -> Result<(), String> {
            let n = q.n();
            let mut e = BatchEsn::<S>::with_precision(q.clone(), b);
            // warm every lane to a non-trivial state
            for _ in 0..12 {
                let u: Vec<f64> = (0..b).map(|_| rng.normal()).collect();
                e.step(&u);
            }
            let before: Vec<Vec<f64>> = (0..b)
                .map(|lane| {
                    let mut s = vec![0.0; n];
                    e.lane_state(lane, &mut s);
                    s
                })
                .collect();
            let inactive = vec![false; b];
            for _ in 0..5 {
                let u: Vec<f64> = (0..b).map(|_| rng.normal() * 100.0).collect();
                e.step_masked(&u, &inactive);
            }
            for (lane, want) in before.iter().enumerate() {
                let mut after = vec![0.0; n];
                e.lane_state(lane, &mut after);
                if after != *want {
                    return Err(format!(
                        "{} lane {lane} moved under an all-inactive mask",
                        S::NAME
                    ));
                }
            }
            Ok(())
        }

        run_case::<f64>(&q, b, rng)?;
        run_case::<f32>(&q, b, rng)
    });
}

#[test]
fn prop_lane_results_independent_of_batch_position_both_precisions() {
    // THE SoA invariant: a lane's trajectory depends only on its own
    // input, never on its position in the planes or on the batch size —
    // bit-for-bit, at both precisions (this is what makes the F32 serving
    // paths mutually consistent)
    check("lane ⊥ batch position", 8, |rng| {
        let n = 8 + rng.next_below(40) as usize;
        let t_len = 30;
        let q = qbasis(n, rng.uniform(0.3, 0.95), rng.next_u64());
        let input = Mat::randn(t_len, 1, rng);
        let ro = Readout {
            w: Mat::randn(n, 1, rng),
            b: vec![rng.normal()],
        };
        let b1 = 1 + rng.next_below(10) as usize;
        let b2 = 1 + rng.next_below(10) as usize;
        let p1 = rng.next_below(b1 as u64) as usize;
        let p2 = rng.next_below(b2 as u64) as usize;

        fn outputs_at<S: linear_reservoir::num::Scalar>(
            q: &QBasisEsn,
            input: &Mat,
            ro: &Readout,
            batch: usize,
            pos: usize,
            rng: &mut Pcg64,
        ) -> Vec<f64> {
            let t_len = input.rows();
            // distinct noise in every other lane so cross-talk would show
            let mut u = Mat::randn(t_len, batch, rng);
            for t in 0..t_len {
                u[(t, pos)] = input[(t, 0)];
            }
            let mut e = BatchEsn::<S>::with_precision(q.clone(), batch);
            let y = e.run_readout(&u, ro);
            (0..t_len).map(|t| y[(t, pos)]).collect()
        }

        fn case<S: linear_reservoir::num::Scalar>(
            q: &QBasisEsn,
            input: &Mat,
            ro: &Readout,
            (b1, p1): (usize, usize),
            (b2, p2): (usize, usize),
            rng: &mut Pcg64,
        ) -> Result<(), String> {
            let a = outputs_at::<S>(q, input, ro, b1, p1, rng);
            let b = outputs_at::<S>(q, input, ro, b2, p2, rng);
            for (t, (x, y)) in a.iter().zip(&b).enumerate() {
                if x != y {
                    return Err(format!(
                        "{}: lane output differs by position at t={t}: \
                         ({b1},{p1}) → {x} vs ({b2},{p2}) → {y}",
                        S::NAME
                    ));
                }
            }
            Ok(())
        }

        case::<f64>(&q, &input, &ro, (b1, p1), (b2, p2), rng)?;
        case::<f32>(&q, &input, &ro, (b1, p1), (b2, p2), rng)
    });
}

#[test]
fn f32_wire_values_roundtrip_exactly_through_f64_json_boundary() {
    // the server's wire contract: f32-computed outputs cross the JSON
    // boundary as f64 — widening then re-narrowing must be the identity,
    // so the wire loses nothing
    let q = qbasis(30, 0.9, 99);
    let mut rng = Pcg64::seeded(100);
    let ro = Readout {
        w: Mat::randn(30, 1, &mut rng),
        b: vec![0.2],
    };
    let u = Mat::randn(60, 2, &mut rng);
    let mut e = BatchEsn::<f32>::with_precision(q, 2);
    let y = e.run_readout(&u, &ro);
    for t in 0..60 {
        for lane in 0..2 {
            let wide = y[(t, lane)]; // f64 at the API boundary
            assert_eq!(
                (wide as f32) as f64,
                wide,
                "f32-computed value not exactly representable at the wire"
            );
        }
    }
}
