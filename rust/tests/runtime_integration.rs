//! Integration: the compiled HLO artifacts (L1 Pallas + L2 JAX, lowered by
//! aot.py) executed through the PJRT runtime must reproduce the native
//! Rust engines. This is the cross-layer correctness seal of the stack.
//!
//! Requires `make artifacts` (skips gracefully otherwise so `cargo test`
//! works on a fresh checkout).

use linear_reservoir::linalg::Mat;
use linear_reservoir::readout::{fit, GramStats, Regularizer};
use linear_reservoir::reservoir::{DiagonalEsn, EsnConfig, StandardEsn};
use linear_reservoir::rng::{Distributions, Pcg64};
use linear_reservoir::runtime::{DiagRuntime, Runtime};
use linear_reservoir::spectral::uniform::uniform_spectrum;

fn have_artifacts() -> bool {
    Runtime::default_dir().join("manifest.json").exists()
}

fn small_dpg(n: usize, d_in: usize, seed: u64) -> DiagonalEsn {
    let config = EsnConfig::default()
        .with_n(n)
        .with_d_in(d_in)
        .with_sr(0.9)
        .with_seed(seed);
    let mut rng = Pcg64::new(seed, 80);
    let spec = uniform_spectrum(n, 0.9, &mut rng);
    DiagonalEsn::from_dpg(spec, &config, &mut rng)
}

fn rel_err(a: &Mat, b: &Mat) -> f64 {
    let scale = b.data().iter().fold(1.0f64, |m, x| m.max(x.abs()));
    a.max_abs_diff(b) / scale
}

#[test]
fn hlo_diag_states_match_native_engine() {
    if !have_artifacts() {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    let mut drt = DiagRuntime::open_default().unwrap();
    // T=32, d_in=2 matches the quick artifact (slots capacity 16 → N ≤ 16
    // with padding headroom)
    let esn = small_dpg(14, 2, 1);
    let mut rng = Pcg64::seeded(2);
    let u = Mat::randn(32, 2, &mut rng);
    let native = esn.run(&u);
    let hlo = drt.run(&esn, &u, false).unwrap();
    let err = rel_err(&hlo, &native);
    assert!(err < 1e-5, "HLO vs native: {err}");
}

#[test]
fn hlo_assoc_scan_matches_sequential() {
    if !have_artifacts() {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    let mut drt = DiagRuntime::open_default().unwrap();
    let esn = small_dpg(16, 2, 3);
    let mut rng = Pcg64::seeded(4);
    let u = Mat::randn(32, 2, &mut rng);
    let seq = drt.run(&esn, &u, false).unwrap();
    let assoc = drt.run(&esn, &u, true).unwrap();
    let err = rel_err(&assoc, &seq);
    assert!(err < 1e-4, "assoc vs seq through HLO: {err}");
}

#[test]
fn hlo_ridge_stats_match_native_gram() {
    if !have_artifacts() {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    let mut drt = DiagRuntime::open_default().unwrap();
    let mut rng = Pcg64::seeded(5);
    let x = Mat::randn(32, 17, &mut rng);
    let y = Mat::randn(32, 2, &mut rng);
    let (xtx, xty) = drt.ridge_stats(&x, &y).unwrap();
    let want_xtx = x.transpose().matmul(&x);
    let want_xty = x.transpose().matmul(&y);
    assert!(rel_err(&xtx, &want_xtx) < 1e-5);
    assert!(rel_err(&xty, &want_xty) < 1e-5);
}

#[test]
fn hlo_readout_apply_matches_native_matmul() {
    if !have_artifacts() {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    let mut drt = DiagRuntime::open_default().unwrap();
    let mut rng = Pcg64::seeded(6);
    let x = Mat::randn(32, 17, &mut rng);
    let w = Mat::randn(17, 2, &mut rng);
    let y = drt.readout_apply(&x, &w).unwrap();
    assert!(rel_err(&y, &x.matmul(&w)) < 1e-5);
}

#[test]
fn hlo_dense_baseline_matches_standard_esn() {
    if !have_artifacts() {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    let mut drt = DiagRuntime::open_default().unwrap();
    let config = EsnConfig::default()
        .with_n(16)
        .with_d_in(2)
        .with_sr(0.8)
        .with_seed(7);
    let esn = StandardEsn::generate(config);
    let mut rng = Pcg64::seeded(8);
    let u = Mat::randn(32, 2, &mut rng);
    let native = esn.run(&u);
    let hlo = drt
        .dense_states(&u, &esn.w_dense(), &esn.w_in)
        .unwrap();
    assert!(rel_err(&hlo, &native) < 1e-5);
}

#[test]
fn full_training_pipeline_through_hlo_stats() {
    // states (HLO) → Gram (HLO) → ridge solve (native) ≈ all-native fit
    if !have_artifacts() {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    let mut drt = DiagRuntime::open_default().unwrap();
    let esn = small_dpg(15, 2, 9);
    let mut rng = Pcg64::seeded(10);
    let u = Mat::randn(32, 2, &mut rng);
    let feats_n = esn.n(); // 15
    let feats = drt.run(&esn, &u, false).unwrap();
    // pad features to the artifact's n_feat=17 (bias col + padding zeros)
    let mut x = Mat::zeros(32, 17);
    for t in 0..32 {
        for j in 0..feats_n {
            x[(t, j)] = feats[(t, j)];
        }
        x[(t, 16)] = 1.0; // bias column
    }
    let y = Mat::randn(32, 2, &mut rng);
    let (xtx, xty) = drt.ridge_stats(&x, &y).unwrap();
    // native ridge solve on HLO-computed (f32) stats
    let alpha = 1e-3;
    let mut g = xtx.clone();
    g.add_diag(alpha);
    let w = linear_reservoir::linalg::Lu::factor(&g).solve_mat(&xty).unwrap();
    // compare against fully-native normal equations — in PREDICTION space
    // (the Gram matrix is f32 through the HLO path, and weight-space error
    // is amplified by the Gram conditioning; predictions are the contract)
    let stats = GramStats::new(&x, &y);
    let _ = stats; // direct fit below (bias folded into the padded column)
    let native = fit(&x, &y, alpha, false, Regularizer::Identity).unwrap();
    let pred_hlo = x.matmul(&w);
    let pred_native = x.matmul(&native.w);
    let err = rel_err(&pred_hlo, &pred_native);
    assert!(err < 1e-3, "prediction err={err}");
}

#[test]
fn seeds_produce_distinct_but_reproducible_hlo_runs() {
    if !have_artifacts() {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    let mut drt = DiagRuntime::open_default().unwrap();
    let mut rng = Pcg64::seeded(11);
    let u = Mat::randn(32, 2, &mut rng);
    let a1 = drt.run(&small_dpg(12, 2, 100), &u, false).unwrap();
    let a2 = drt.run(&small_dpg(12, 2, 100), &u, false).unwrap();
    let b = drt.run(&small_dpg(12, 2, 101), &u, false).unwrap();
    assert_eq!(a1.max_abs_diff(&a2), 0.0, "same seed must be bit-identical");
    assert!(a1.max_abs_diff(&b) > 1e-6, "different seeds must differ");
}
