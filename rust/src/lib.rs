//! # linear-reservoir
//!
//! Production reproduction of *“Linear Reservoir: A Diagonalization-Based
//! Optimization”* (de Coudenhove, Bendi-Ouis, Strock, Hinaut): linear Echo
//! State Networks whose recurrent update is rewritten in the eigenbasis of
//! the reservoir matrix, reducing the per-step cost from `O(N²)` to `O(N)`.
//!
//! Three deployment methods from the paper are first-class:
//! * **EWT** — Eigenbasis Weight Transformation: diagonalize a trained
//!   standard ESN and transform its readout
//!   ([`reservoir::DiagonalEsn::from_standard`]).
//! * **EET** — End-to-End Eigenbasis Training: train the readout directly in
//!   the transformed space with the generalized Tikhonov term of Theorem 1
//!   ([`readout`]).
//! * **DPG** — Direct Parameter Generation: skip the matrix entirely and
//!   sample `(Λ, P)` directly ([`spectral`]): Uniform, Golden, Noisy-Golden
//!   and Sim distributions.
//!
//! Architecture (see `DESIGN.md`): this crate is Layer 3 of a three-layer
//! stack. Layers 1–2 (Pallas kernel + JAX graph) are compiled **ahead of
//! time** to HLO-text artifacts which the `runtime` module loads and
//! executes through the PJRT CPU client (`xla` crate, behind the optional
//! `xla` feature — the offline default build is fully self-contained);
//! Python never runs on the request path. Native Rust engines in
//! [`reservoir`] mirror the compiled graphs and are used for
//! cross-validation and for shapes that have no artifact.
//!
//! The serving path is batched, fused, precision-generic, and sharded
//! per core: [`reservoir::BatchEsn`] advances B independent sequences in
//! SoA split planes through one pass over `Λ` per step at `f64` (the
//! bit-exact oracle) or `f32` (2× SIMD width, the compiled kernels'
//! precision — [`num::Scalar`]), and the `run_readout` family folds
//! `y = f·W_out + b` into the sweep so requests never materialize a
//! `[T × N]` trajectory. [`server`] runs one micro-batching
//! [`server::BatchFront`] sweeper per core behind a
//! [`server::ShardedFront`] (connections hash to a home shard, stateless
//! predicts go to the least-loaded one), selecting the precision per
//! [`server::Model`] — `cores × B` lanes, no locks on the hot path. On
//! Linux the wire layer is an epoll readiness loop (hand-rolled, raw
//! libc FFI): S sweepers + 1 poll thread serve every connection, so
//! idle streaming clients cost a file descriptor, not an OS thread
//! (`server::serve_on`; `--threaded` keeps the thread-per-connection
//! twin for A/B).
//!
//! The offline build environment provides no general-purpose crates, so the
//! substrates are all local: [`rng`], [`linalg`] (including a from-scratch
//! non-symmetric eigensolver), [`sparse`], [`util`] (JSON/CSV), a thread
//! pool ([`coordinator`]), a bench harness ([`bench`]) and a property-test
//! harness ([`testing`]).

pub mod bench;
pub mod cli;
pub mod coordinator;
pub mod experiments;
pub mod linalg;
pub mod metrics;
pub mod num;
pub mod readout;
pub mod reservoir;
pub mod rng;
#[cfg(feature = "xla")]
pub mod runtime;
pub mod server;
pub mod sparse;
pub mod spectral;
pub mod tasks;
pub mod testing;
pub mod util;

/// Crate-wide result alias (anyhow is in the offline dependency closure).
pub type Result<T> = anyhow::Result<T>;
