//! Hand-rolled CLI argument parsing (clap is not in the offline registry).
//!
//! Grammar: `repro <subcommand> [--key value]... [--flag]...`.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: String,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Self> {
        let mut it = args.into_iter().peekable();
        let subcommand = it
            .next()
            .ok_or_else(|| anyhow!("missing subcommand (try `repro help`)"))?;
        if subcommand.starts_with("--") {
            bail!("expected a subcommand before options, got {subcommand:?}");
        }
        let mut opts = BTreeMap::new();
        let mut flags = Vec::new();
        while let Some(tok) = it.next() {
            let key = tok
                .strip_prefix("--")
                .ok_or_else(|| anyhow!("expected --option, got {tok:?}"))?
                .to_string();
            match it.peek() {
                Some(v) if !v.starts_with("--") => {
                    opts.insert(key, it.next().unwrap());
                }
                _ => flags.push(key),
            }
        }
        Ok(Self {
            subcommand,
            opts,
            flags,
        })
    }

    pub fn from_env() -> Result<Self> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.opts.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow!("--{name} expects an integer: {e}")),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.opts.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow!("--{name} expects an integer: {e}")),
        }
    }

    /// Like [`get_u64`](Self::get_u64) but with no default: `None` when
    /// the option is absent, so callers can distinguish "unset" from any
    /// sentinel value (e.g. `--trainer-budget-mb` where absence means
    /// unlimited).
    pub fn get_opt_u64(&self, name: &str) -> Result<Option<u64>> {
        match self.opts.get(name) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|e| anyhow!("--{name} expects an integer: {e}")),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.opts.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow!("--{name} expects a float: {e}")),
        }
    }

    pub fn get_str<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// Optional path-valued flag: `None` when absent (the common "feature
    /// off" default for things like `--drain-checkpoint <dir>`).
    pub fn get_path(&self, name: &str) -> Option<std::path::PathBuf> {
        self.get(name).map(std::path::PathBuf::from)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<Args> {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn full_grammar() {
        let a = parse("table2 --tasks 1,2,3 --seeds 10 --quick").unwrap();
        assert_eq!(a.subcommand, "table2");
        assert_eq!(a.get("tasks"), Some("1,2,3"));
        assert_eq!(a.get_usize("seeds", 0).unwrap(), 10);
        assert!(a.flag("quick"));
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn defaults_and_types() {
        let a = parse("fig6 --alpha 1e-7").unwrap();
        assert_eq!(a.get_f64("alpha", 0.0).unwrap(), 1e-7);
        assert_eq!(a.get_usize("n", 100).unwrap(), 100);
        assert_eq!(a.get_str("out", "results"), "results");
    }

    #[test]
    fn rejects_option_without_subcommand() {
        assert!(parse("--bad first").is_err());
        assert!(parse("").is_err());
    }

    #[test]
    fn bad_number_is_error() {
        let a = parse("x --n abc").unwrap();
        assert!(a.get_usize("n", 1).is_err());
    }

    #[test]
    fn path_flag_is_none_when_absent() {
        let a = parse("serve --drain-checkpoint /tmp/spill").unwrap();
        assert_eq!(
            a.get_path("drain-checkpoint"),
            Some(std::path::PathBuf::from("/tmp/spill"))
        );
        let b = parse("serve").unwrap();
        assert_eq!(b.get_path("drain-checkpoint"), None);
    }

    #[test]
    fn registry_serve_flags_parse() {
        // the PR-9 serving surface: --max-models caps the tenant
        // registry, --pin-cores is a bare flag
        let a = parse("serve --max-models 64 --pin-cores").unwrap();
        assert_eq!(a.get_opt_u64("max-models").unwrap(), Some(64));
        assert!(a.flag("pin-cores"));
        let b = parse("serve").unwrap();
        assert_eq!(b.get_opt_u64("max-models").unwrap(), None);
        assert!(!b.flag("pin-cores"));
        // 0 is legal (registry disabled, base model only) and distinct
        // from absent (server default budget)
        let c = parse("serve --max-models 0").unwrap();
        assert_eq!(c.get_opt_u64("max-models").unwrap(), Some(0));
    }

    #[test]
    fn wirepath_flags_parse() {
        // the PR-10 wire-path surface: --poll-threads shards the event
        // loop, --binary is the client-side frame-protocol opt-in (a
        // bare flag, used by the demo/bench client drivers)
        let a = parse("serve --poll-threads 4 --binary").unwrap();
        assert_eq!(a.get_usize("poll-threads", 1).unwrap(), 4);
        assert!(a.flag("binary"));
        let b = parse("serve").unwrap();
        assert_eq!(b.get_usize("poll-threads", 1).unwrap(), 1);
        assert!(!b.flag("binary"));
        let c = parse("serve --poll-threads many").unwrap();
        assert!(c.get_usize("poll-threads", 1).is_err());
    }

    #[test]
    fn optional_u64_distinguishes_absent_from_zero() {
        let a = parse("serve --trainer-budget-mb 0").unwrap();
        assert_eq!(a.get_opt_u64("trainer-budget-mb").unwrap(), Some(0));
        let b = parse("serve").unwrap();
        assert_eq!(b.get_opt_u64("trainer-budget-mb").unwrap(), None);
        let c = parse("serve --trainer-budget-mb lots").unwrap();
        assert!(c.get_opt_u64("trainer-budget-mb").is_err());
    }
}
