//! Deterministic pseudo-random generation (no `rand` in the offline
//! registry, so this is a from-scratch substrate — see DESIGN.md §1).
//!
//! [`Pcg64`] is PCG-XSL-RR 128/64 (O'Neill 2014): 128-bit LCG state with a
//! 64-bit xorshift-rotate output. Seeding goes through SplitMix64 so that
//! small consecutive seeds (0, 1, 2 …, the seed grid of the experiments)
//! give uncorrelated streams. Every experiment in this repo derives its
//! randomness from an explicit `(seed, stream)` pair, making every table
//! and figure bit-reproducible.

pub mod distributions;

pub use distributions::Distributions;

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

/// PCG-XSL-RR 128/64 generator.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

/// SplitMix64 — used to expand user seeds into PCG state material.
fn splitmix64(x: &mut u64) -> u64 {
    *x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    let mut z = *x;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// Pure SplitMix64 step (stateless form of the mixer above): the
/// canonical 64-bit finalizer for hash-style consumers — deterministic,
/// cheap, well-mixed. The server's connection→shard map uses it; keeping
/// one copy of the magic constants lives here.
#[inline]
pub fn splitmix64_mix(x: u64) -> u64 {
    let mut s = x;
    splitmix64(&mut s)
}

impl Pcg64 {
    /// Seed a generator; `stream` selects an independent sequence (odd
    /// increment), so `(seed, 0)`, `(seed, 1)` … never collide.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut sm = seed;
        let s0 = splitmix64(&mut sm);
        let s1 = splitmix64(&mut sm);
        let mut sm2 = stream ^ 0xda3e_39cb_94b9_5bdb;
        let i0 = splitmix64(&mut sm2);
        let i1 = splitmix64(&mut sm2);
        let mut rng = Self {
            state: ((s0 as u128) << 64) | s1 as u128,
            inc: (((i0 as u128) << 64) | i1 as u128) | 1,
        };
        // decorrelate state from seed bits
        rng.next_u64();
        rng.next_u64();
        rng
    }

    /// Convenience: stream 0.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self
            .state
            .wrapping_mul(PCG_MULT)
            .wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform in `[0, 1)` with 53 random bits.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, bound)` (Lemire-style rejection-free for our
    /// needs: simple modulo bias is avoided via rejection loop).
    pub fn next_below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0);
        let threshold = bound.wrapping_neg() % bound;
        loop {
            let x = self.next_u64();
            if x >= threshold {
                return x % bound;
            }
        }
    }

    /// Split off an independent child generator (used by the coordinator to
    /// hand each worker its own stream deterministically).
    pub fn split(&mut self, stream: u64) -> Pcg64 {
        Pcg64::new(self.next_u64(), stream)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Pcg64::seeded(42);
        let mut b = Pcg64::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Pcg64::seeded(0);
        let mut b = Pcg64::seeded(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg64::new(7, 0);
        let mut b = Pcg64::new(7, 1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Pcg64::seeded(3);
        for _ in 0..10_000 {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn f64_mean_is_half() {
        let mut rng = Pcg64::seeded(4);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| rng.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn next_below_unbiased_small_bound() {
        let mut rng = Pcg64::seeded(5);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[rng.next_below(3) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 500.0, "{counts:?}");
        }
    }
}
