//! Sampling distributions on top of [`Pcg64`](super::Pcg64): the set needed
//! by the paper's generators (normal entries for `W`/`W_in`/eigenvectors,
//! uniform for eigenvalue moduli/angles and MC task inputs, Bernoulli for
//! connectivity masks).

use super::Pcg64;

/// Extension trait adding distribution sampling to the raw generator.
pub trait Distributions {
    /// Uniform in `[lo, hi)`.
    fn uniform(&mut self, lo: f64, hi: f64) -> f64;
    /// Standard normal via Box–Muller (pair-cached would add state; the
    /// single-draw form keeps reproducibility trivially composable).
    fn normal(&mut self) -> f64;
    /// Normal with given mean / standard deviation.
    fn normal_ms(&mut self, mean: f64, std: f64) -> f64;
    /// Bernoulli with probability `p`.
    fn bernoulli(&mut self, p: f64) -> bool;
    /// Fill a vector with i.i.d. uniform draws.
    fn uniform_vec(&mut self, n: usize, lo: f64, hi: f64) -> Vec<f64>;
    /// Fill a vector with i.i.d. standard normal draws.
    fn normal_vec(&mut self, n: usize) -> Vec<f64>;
    /// Fisher–Yates shuffle.
    fn shuffle<T>(&mut self, xs: &mut [T]);
}

impl Distributions for Pcg64 {
    #[inline]
    fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    #[inline]
    fn normal(&mut self) -> f64 {
        // Box–Muller; guard against log(0).
        let u1 = loop {
            let u = self.next_f64();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    #[inline]
    fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    #[inline]
    fn bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    fn uniform_vec(&mut self, n: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..n).map(|_| self.uniform(lo, hi)).collect()
    }

    fn normal_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.normal()).collect()
    }

    fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::seeded(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn normal_tails_reasonable() {
        let mut rng = Pcg64::seeded(12);
        let n = 100_000;
        let beyond3 = (0..n).filter(|_| rng.normal().abs() > 3.0).count();
        // P(|Z|>3) ≈ 0.0027
        assert!((beyond3 as f64 / n as f64 - 0.0027).abs() < 0.002);
    }

    #[test]
    fn uniform_bounds() {
        let mut rng = Pcg64::seeded(13);
        for _ in 0..10_000 {
            let x = rng.uniform(-2.5, 7.0);
            assert!((-2.5..7.0).contains(&x));
        }
    }

    #[test]
    fn bernoulli_rate() {
        let mut rng = Pcg64::seeded(14);
        let hits = (0..100_000).filter(|_| rng.bernoulli(0.3)).count();
        assert!((hits as f64 / 100_000.0 - 0.3).abs() < 0.01);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::seeded(15);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }
}
