//! The §2 baseline: a standard linear ESN with an explicit reservoir
//! matrix. `O(c_r·N²)` per step (sparse) or `O(N²)` (dense).

use crate::linalg::{eigenvalues, Mat};
use crate::rng::{Distributions, Pcg64};
use crate::sparse::Csr;

use super::EsnConfig;

/// Reservoir matrix storage. Below `DENSE_THRESHOLD` connectivity the CSR
/// form wins; above it the dense row-major form does.
#[derive(Clone, Debug)]
pub enum WStore {
    Dense(Mat),
    Sparse(Csr),
}

const DENSE_THRESHOLD: f64 = 0.35;

/// Standard linear Echo State Network (fixed `W`, `W_in`, optional
/// `W_fb`; Eq. 1 dynamics with the leaking-rate reparametrization of
/// Eq. 4 already folded in).
#[derive(Clone, Debug)]
pub struct StandardEsn {
    pub w: WStore,
    /// `D_in × N` input weights (input scaling + leak already applied).
    pub w_in: Mat,
    /// Optional `D_out × N` output-feedback weights (Eq. 1's
    /// `y(t−1)·W_fb` term; leak applied).
    pub w_fb: Option<Mat>,
    pub config: EsnConfig,
    /// Spectral radius of the *unleaked* scaled `W` (diagnostics).
    pub rho0: f64,
}

impl StandardEsn {
    /// Generate per §2.5: `W` entries present with prob `connectivity`,
    /// i.i.d. normal values, scaled so the spectral radius equals
    /// `config.spectral_radius`; `W_in` entries present with prob
    /// `input_connectivity`, uniform on `(−1, 1)`, times `input_scaling`.
    /// Leak (Eq. 4): `W ← lr·W + (1−lr)·I`, `W_in ← lr·W_in`.
    pub fn generate(config: EsnConfig) -> Self {
        config.validate();
        let mut rng = Pcg64::new(config.seed, 1);
        let n = config.n;

        let mut w = Csr::random(n, n, config.connectivity, &mut rng).to_dense();
        // spectral-radius scaling (the O(N³) step the paper's §2.5 charges
        // the baseline for)
        let rho0 = eigenvalues(&w)
            .iter()
            .map(|z| z.abs())
            .fold(0.0, f64::max);
        if rho0 > 0.0 {
            w.scale(config.spectral_radius / rho0);
        }

        let mut w_in = Mat::from_fn(config.d_in, n, |_, _| {
            if rng.bernoulli(config.input_connectivity) {
                rng.uniform(-1.0, 1.0)
            } else {
                0.0
            }
        });
        w_in.scale(config.input_scaling * config.leak_rate);

        // leak folding: W ← lr·W + (1−lr)·I
        let lr = config.leak_rate;
        if lr < 1.0 {
            w.scale(lr);
            w.add_diag(1.0 - lr);
        }

        let store = if config.connectivity <= DENSE_THRESHOLD && lr >= 1.0 {
            WStore::Sparse(Csr::from_dense(&w))
        } else {
            WStore::Dense(w)
        };
        Self {
            w: store,
            w_in,
            w_fb: None,
            config,
            rho0: config.spectral_radius,
        }
    }

    /// Build directly from parts (tests, EWT round-trips).
    pub fn from_parts(w: Mat, w_in: Mat, config: EsnConfig) -> Self {
        assert_eq!(w.rows(), w.cols());
        assert_eq!(w_in.cols(), w.rows());
        assert_eq!(w_in.rows(), config.d_in);
        Self {
            w: WStore::Dense(w),
            w_in,
            w_fb: None,
            config,
            rho0: f64::NAN,
        }
    }

    /// Dense copy of `W` (for diagonalization / tests).
    pub fn w_dense(&self) -> Mat {
        match &self.w {
            WStore::Dense(m) => m.clone(),
            WStore::Sparse(s) => s.to_dense(),
        }
    }

    pub fn n(&self) -> usize {
        self.config.n
    }

    /// One reservoir step: `r ← r·W + u·W_in` (Eq. 1, no feedback).
    /// `scratch` must have length N; on return holds the new state.
    pub fn step(&self, r: &[f64], u: &[f64], scratch: &mut [f64]) {
        match &self.w {
            WStore::Dense(w) => w.vecmat(r, scratch),
            WStore::Sparse(w) => w.vecmat(r, scratch),
        }
        // + u(t)·W_in
        for (d, &ud) in u.iter().enumerate() {
            if ud == 0.0 {
                continue;
            }
            let row = self.w_in.row(d);
            for j in 0..scratch.len() {
                scratch[j] += ud * row[j];
            }
        }
    }

    /// Attach output-feedback weights (`D_out × N`; Eq. 1's `W_fb`). The
    /// caller is responsible for leak scaling (`W_fb ← lr·W_fb`) if built
    /// outside [`StandardEsn::generate`].
    pub fn with_feedback(mut self, w_fb: Mat) -> Self {
        assert_eq!(w_fb.cols(), self.config.n);
        self.w_fb = Some(w_fb);
        self
    }

    /// One full Eq.-1 step with output feedback:
    /// `r ← r·W + u·W_in + y_prev·W_fb`.
    pub fn step_fb(&self, r: &[f64], u: &[f64], y_prev: &[f64], scratch: &mut [f64]) {
        self.step(r, u, scratch);
        if let Some(w_fb) = &self.w_fb {
            for (d, &yd) in y_prev.iter().enumerate() {
                if yd == 0.0 {
                    continue;
                }
                let row = w_fb.row(d);
                for j in 0..scratch.len() {
                    scratch[j] += yd * row[j];
                }
            }
        }
    }

    /// Teacher-forced run with feedback: `y(t−1)` is the ground-truth
    /// target (y(−1) = 0), as in the paper's training protocol.
    /// `y_teacher: [T × D_out]`. Returns `[T × N]` states.
    pub fn run_teacher_forced(&self, u: &Mat, y_teacher: &Mat) -> Mat {
        assert_eq!(u.rows(), y_teacher.rows());
        let n = self.n();
        let t_len = u.rows();
        let mut states = Mat::zeros(t_len, n);
        let mut r = vec![0.0; n];
        let mut next = vec![0.0; n];
        let zero = vec![0.0; y_teacher.cols()];
        for t in 0..t_len {
            let y_prev: &[f64] = if t == 0 { &zero } else { y_teacher.row(t - 1) };
            self.step_fb(&r, u.row(t), y_prev, &mut next);
            std::mem::swap(&mut r, &mut next);
            states.row_mut(t).copy_from_slice(&r);
        }
        states
    }

    /// Run over a `[T × D_in]` input, returning `[T × N]` states
    /// (`r(0) = 0`).
    pub fn run(&self, u: &Mat) -> Mat {
        assert_eq!(u.cols(), self.config.d_in);
        let n = self.n();
        let t_len = u.rows();
        let mut states = Mat::zeros(t_len, n);
        let mut r = vec![0.0; n];
        let mut next = vec![0.0; n];
        for t in 0..t_len {
            self.step(&r, u.row(t), &mut next);
            std::mem::swap(&mut r, &mut next);
            states.row_mut(t).copy_from_slice(&r);
        }
        states
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(n: usize) -> EsnConfig {
        EsnConfig::default().with_n(n).with_seed(42)
    }

    #[test]
    fn generated_spectral_radius_matches() {
        let esn = StandardEsn::generate(cfg(40).with_sr(0.8));
        let rho = eigenvalues(&esn.w_dense())
            .iter()
            .map(|z| z.abs())
            .fold(0.0, f64::max);
        assert!((rho - 0.8).abs() < 1e-8, "rho={rho}");
    }

    #[test]
    fn leak_folds_identity() {
        // lr < 1: W' = lr·W + (1−lr)I ⇒ spectral radius of W' ≤ lr·ρ + (1−lr)
        let esn = StandardEsn::generate(cfg(30).with_sr(0.5).with_leak(0.3));
        let rho = eigenvalues(&esn.w_dense())
            .iter()
            .map(|z| z.abs())
            .fold(0.0, f64::max);
        assert!(rho <= 0.3 * 0.5 + 0.7 + 1e-9, "rho={rho}");
    }

    #[test]
    fn sparse_storage_used_at_low_connectivity() {
        let esn = StandardEsn::generate(cfg(50).with_connectivity(0.05));
        assert!(matches!(esn.w, WStore::Sparse(_)));
        let dense_esn = StandardEsn::generate(cfg(50).with_connectivity(0.9));
        assert!(matches!(dense_esn.w, WStore::Dense(_)));
    }

    #[test]
    fn sparse_and_dense_paths_agree() {
        let config = cfg(25).with_connectivity(0.2);
        let esn = StandardEsn::generate(config);
        let dense_twin = StandardEsn {
            w: WStore::Dense(esn.w_dense()),
            w_in: esn.w_in.clone(),
            w_fb: None,
            config,
            rho0: esn.rho0,
        };
        let mut rng = Pcg64::seeded(1);
        let u = Mat::randn(30, 1, &mut rng);
        let a = esn.run(&u);
        let b = dense_twin.run(&u);
        assert!(a.max_abs_diff(&b) < 1e-12);
    }

    #[test]
    fn run_matches_manual_recurrence() {
        let esn = StandardEsn::generate(cfg(10));
        let mut rng = Pcg64::seeded(2);
        let u = Mat::randn(15, 1, &mut rng);
        let states = esn.run(&u);
        // manual
        let w = esn.w_dense();
        let mut r = vec![0.0; 10];
        for t in 0..15 {
            let mut next = vec![0.0; 10];
            w.vecmat(&r, &mut next);
            for j in 0..10 {
                next[j] += u[(t, 0)] * esn.w_in[(0, j)];
            }
            r = next;
            for j in 0..10 {
                assert!((states[(t, j)] - r[j]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn input_scaling_scales_states_linearly() {
        // D_in = 1 linear system: states are exactly proportional to the
        // input scaling (the grid-search reuse trick).
        let base = StandardEsn::generate(cfg(12).with_input_scaling(1.0));
        let scaled = StandardEsn::generate(cfg(12).with_input_scaling(0.01));
        let mut rng = Pcg64::seeded(3);
        let u = Mat::randn(20, 1, &mut rng);
        let a = base.run(&u);
        let mut b = scaled.run(&u);
        b.scale(100.0);
        assert!(a.max_abs_diff(&b) < 1e-9);
    }

    #[test]
    fn echo_state_property_fades_initial_differences() {
        // ρ < 1 ⇒ contributions fade: zero input ⇒ state → 0
        let esn = StandardEsn::generate(cfg(20).with_sr(0.5));
        let u = Mat::zeros(200, 1);
        let states = esn.run(&u);
        let last: f64 = states.row(199).iter().map(|x| x.abs()).sum();
        assert!(last < 1e-12);
    }
}
