//! Appendix B — parallelization of the diagonal recurrence across TIME,
//! natively in Rust: the affine maps `(a, b): s ↦ a⊙s + b` form a monoid
//! under composition, so the trajectory is an inclusive prefix scan. This
//! module implements the classic two-phase chunked scan:
//!
//! 1. split the sequence into chunks; scan each chunk independently
//!    (parallel across the worker pool), also composing the chunk's total
//!    affine map;
//! 2. exclusive-scan the chunk summaries sequentially (cheap: one map per
//!    chunk), then fix up each chunk's states with its prefix map
//!    (parallel again).
//!
//! [`run_parallel_batch`] is the batched form: phase 1 of EVERY sequence
//! is flattened into one `sequences × chunks` job list, so the worker
//! pool stays full even when a single sequence has fewer chunks than the
//! pool has threads — the time-scan analogue of `BatchEsn`'s lane
//! batching. On this 1-vCPU container the wall-clock win is nil — the
//! value is the verified ALGORITHM (work O(T·N), depth O(T/C + #chunks)),
//! mirroring the Pallas `assoc_scan` kernel so both sides of the stack
//! implement Appendix B.
//!
//! The scan is **precision-generic** over [`Scalar`] (the same trait the
//! batched lane engine uses): [`run_parallel_prec`] /
//! [`run_parallel_batch_prec`] downcast `(Λ, [W_in]_P)` once and run
//! every chunk scan, summary composition, and fix-up at `S` — so the
//! training path can generate states at the f32 kernel precision point
//! (half the plane traffic) as well as at the f64 oracle. The boundary
//! stays `f64`-in/`f64`-out: inputs are narrowed per step exactly like
//! `BatchEsn` narrows them, and the widening of the output features is
//! exact. The bare [`run_parallel`] / [`run_parallel_batch`] are the
//! `f64` instantiation (bit-compatible with the previous f64-only form).

//! [`run_parallel_batch_train`] fuses **Gram accumulation** into the
//! batched scan: phase 3's fix-up rows are streamed straight into
//! per-worker [`GramAcc`]s (one per sequence, merged deterministically in
//! sequence order) instead of being materialized, so multi-sequence
//! training never assembles a `[T × F]` feature matrix — only the
//! requested eval spans (validation/test slices) become `Mat`s. At f64
//! the fused path is bit-identical to materialize-then-`GramStats::new`
//! (the accumulator's carry keeps the rank-2 row pairing aligned across
//! chunk boundaries; tested below and in `rust/tests/precision.rs`).

use std::ops::Range;

use crate::coordinator::WorkerPool;
use crate::linalg::Mat;
use crate::num::Scalar;
use crate::readout::GramAcc;

use super::DiagonalEsn;

/// Per-slot affine map `(a, b)` over split-complex planes at precision `S`.
#[derive(Clone)]
struct AffineChunk<S> {
    a_re: Vec<S>,
    a_im: Vec<S>,
    b_re: Vec<S>,
    b_im: Vec<S>,
}

impl<S: Scalar> AffineChunk<S> {
    fn identity(slots: usize) -> Self {
        Self {
            a_re: vec![S::ONE; slots],
            a_im: vec![S::ZERO; slots],
            b_re: vec![S::ZERO; slots],
            b_im: vec![S::ZERO; slots],
        }
    }

    /// `self ∘ prev` (apply `prev` first): `(a₂, b₂)∘(a₁, b₁) =
    /// (a₂a₁, a₂b₁ + b₂)`.
    fn compose_after(&self, prev: &AffineChunk<S>) -> AffineChunk<S> {
        let n = self.a_re.len();
        let mut out = AffineChunk::identity(n);
        for j in 0..n {
            let (ar, ai) = (self.a_re[j], self.a_im[j]);
            out.a_re[j] = ar * prev.a_re[j] - ai * prev.a_im[j];
            out.a_im[j] = ar * prev.a_im[j] + ai * prev.a_re[j];
            out.b_re[j] = ar * prev.b_re[j] - ai * prev.b_im[j] + self.b_re[j];
            out.b_im[j] = ar * prev.b_im[j] + ai * prev.b_re[j] + self.b_im[j];
        }
        out
    }
}

/// Phase-1 output for one chunk: its local (from-zero) states — row-major
/// `[len × slots]` split planes — and total affine map.
struct ChunkOut<S> {
    len: usize,
    s_re: Vec<S>,
    s_im: Vec<S>,
    total: AffineChunk<S>,
}

/// The reservoir's parameters downcast once to scan precision `S`:
/// per-slot `Λ` components and `[d_in × slots]` input-weight planes.
#[derive(Clone)]
struct ScanParams<S> {
    slots: usize,
    lam_re: Vec<S>,
    lam_im: Vec<S>,
    win_re: Vec<S>,
    win_im: Vec<S>,
}

impl<S: Scalar> ScanParams<S> {
    fn new(esn: &DiagonalEsn) -> Self {
        let slots = esn.spec.slots();
        let d_in = esn.win_re.rows();
        let lam_re = esn.spec.lam.iter().map(|l| S::from_f64(l.re)).collect();
        let lam_im = esn.spec.lam.iter().map(|l| S::from_f64(l.im)).collect();
        let mut win_re = vec![S::ZERO; d_in * slots];
        let mut win_im = vec![S::ZERO; d_in * slots];
        for d in 0..d_in {
            let wr = esn.win_re.row(d);
            let wi = esn.win_im.row(d);
            for j in 0..slots {
                win_re[d * slots + j] = S::from_f64(wr[j]);
                win_im[d * slots + j] = S::from_f64(wi[j]);
            }
        }
        Self {
            slots,
            lam_re,
            lam_im,
            win_re,
            win_im,
        }
    }

    /// One Corollary-2 step on split planes at precision `S` (the input
    /// row is narrowed per element, exactly like the batched lane engine).
    fn step(&self, s_re: &mut [S], s_im: &mut [S], u: &[f64]) {
        let slots = self.slots;
        for j in 0..slots {
            let (lr, li) = (self.lam_re[j], self.lam_im[j]);
            let (re, im) = (s_re[j], s_im[j]);
            s_re[j] = re * lr - im * li;
            s_im[j] = re * li + im * lr;
        }
        for (d, &ud) in u.iter().enumerate() {
            if ud == 0.0 {
                continue;
            }
            let us = S::from_f64(ud);
            let wr = &self.win_re[d * slots..(d + 1) * slots];
            let wi = &self.win_im[d * slots..(d + 1) * slots];
            for j in 0..slots {
                s_re[j] += us * wr[j];
                s_im[j] += us * wi[j];
            }
        }
    }
}

/// Time-parallel run of a diagonal reservoir at the `f64` oracle
/// precision: identical output to [`DiagonalEsn::run`] (up to f64
/// rounding), computed as a chunked prefix scan over `pool`.
pub fn run_parallel(esn: &DiagonalEsn, u: &Mat, pool: &WorkerPool, chunk: usize) -> Mat {
    run_parallel_prec::<f64>(esn, u, pool, chunk)
}

/// [`run_parallel`] at an explicit scan precision `S`.
pub fn run_parallel_prec<S: Scalar>(
    esn: &DiagonalEsn,
    u: &Mat,
    pool: &WorkerPool,
    chunk: usize,
) -> Mat {
    run_parallel_batch_prec::<S>(esn, std::slice::from_ref(u), pool, chunk)
        .pop()
        .expect("one input, one output")
}

/// Batched time-parallel runs over independent sequences (all `[Tᵢ ×
/// D_in]`) at the `f64` oracle precision. Phase 1 fans `Σᵢ ⌈Tᵢ/chunk⌉`
/// chunk scans across the pool in ONE `map` call; phases 2–3 (summary
/// scan + fix-up) run per sequence. Output `i` is identical to
/// `run_parallel(esn, &inputs[i], …)`.
pub fn run_parallel_batch(
    esn: &DiagonalEsn,
    inputs: &[Mat],
    pool: &WorkerPool,
    chunk: usize,
) -> Vec<Mat> {
    run_parallel_batch_prec::<f64>(esn, inputs, pool, chunk)
}

/// [`run_parallel_batch`] at an explicit scan precision `S`: the whole
/// scan — chunk states, chunk-total maps, summary composition, and
/// fix-up — runs on `S` planes, with parameters downcast once up front.
pub fn run_parallel_batch_prec<S: Scalar>(
    esn: &DiagonalEsn,
    inputs: &[Mat],
    pool: &WorkerPool,
    chunk: usize,
) -> Vec<Mat> {
    let params = ScanParams::<S>::new(esn);
    let chunk = chunk.max(1);
    let per_seq = phase1_chunks(&params, inputs, pool, chunk);
    let nr = esn.spec.n_real;
    let n = esn.n();
    inputs
        .iter()
        .zip(per_seq)
        .map(|(u, chunks)| fixup_sequence(&params, nr, n, u.rows(), &chunks, chunk))
        .collect()
}

/// Phase 1 for a batch of sequences: fan `Σᵢ ⌈Tᵢ/chunk⌉` independent
/// chunk scans across the pool in ONE `map` call — states-from-zero plus
/// each chunk's total affine map — and regroup the results per sequence
/// (jobs are pushed in `(sequence, chunk)` order and `map` preserves
/// input order).
fn phase1_chunks<S: Scalar>(
    params: &ScanParams<S>,
    inputs: &[Mat],
    pool: &WorkerPool,
    chunk: usize,
) -> Vec<Vec<ChunkOut<S>>> {
    let slots = params.slots;

    // flattened job list: (sequence, chunk-within-sequence)
    let mut jobs: Vec<(usize, usize)> = Vec::new();
    for (si, u) in inputs.iter().enumerate() {
        for ci in 0..u.rows().div_ceil(chunk) {
            jobs.push((si, ci));
        }
    }

    let worker_params = params.clone();
    let u_all: Vec<Mat> = inputs.to_vec();
    let chunks: Vec<ChunkOut<S>> = pool.map(jobs, move |(si, ci)| {
        let u = &u_all[si];
        let t_len = u.rows();
        let lo = ci * chunk;
        let hi = ((ci + 1) * chunk).min(t_len);
        let len = hi - lo;
        let mut s_re = vec![S::ZERO; len * slots];
        let mut s_im = vec![S::ZERO; len * slots];
        let mut cur_re = vec![S::ZERO; slots];
        let mut cur_im = vec![S::ZERO; slots];
        // total map: a = λ^len (per slot, accumulated INCREMENTALLY
        // alongside the scan — `powi(len as u32)` both truncates 64-bit
        // chunk lengths and drifts at |λ| ≈ 1; the running product is the
        // same recurrence the phase-3 fix-up uses), b = chunk scan from 0
        let mut a_re = vec![S::ONE; slots];
        let mut a_im = vec![S::ZERO; slots];
        for (row, t) in (lo..hi).enumerate() {
            worker_params.step(&mut cur_re, &mut cur_im, u.row(t));
            for j in 0..slots {
                let (lr, li) = (worker_params.lam_re[j], worker_params.lam_im[j]);
                let (re, im) = (a_re[j], a_im[j]);
                a_re[j] = re * lr - im * li;
                a_im[j] = re * li + im * lr;
            }
            s_re[row * slots..(row + 1) * slots].copy_from_slice(&cur_re);
            s_im[row * slots..(row + 1) * slots].copy_from_slice(&cur_im);
        }
        let mut total = AffineChunk::identity(slots);
        total.a_re.copy_from_slice(&a_re);
        total.a_im.copy_from_slice(&a_im);
        total.b_re.copy_from_slice(&cur_re);
        total.b_im.copy_from_slice(&cur_im);
        ChunkOut {
            len,
            s_re,
            s_im,
            total,
        }
    });

    // split (no copies: the chunk states move) per sequence
    let mut per_seq = Vec::with_capacity(inputs.len());
    let mut rest = chunks;
    for u in inputs {
        let n_chunks = u.rows().div_ceil(chunk);
        let tail = rest.split_off(n_chunks);
        per_seq.push(rest);
        rest = tail;
    }
    per_seq
}

/// Phases 2–3 for one sequence, materialized: the `[T × N]` feature
/// matrix the inference path wants ([`fixup_rows`] does the arithmetic;
/// the row copy preserves bits).
fn fixup_sequence<S: Scalar>(
    params: &ScanParams<S>,
    nr: usize,
    n: usize,
    t_len: usize,
    chunks: &[ChunkOut<S>],
    chunk: usize,
) -> Mat {
    let mut out = Mat::zeros(t_len, n);
    fixup_rows(params, nr, n, chunks, chunk, |t, row| {
        out.row_mut(t).copy_from_slice(row);
    });
    out
}

/// Phases 2–3 for one sequence as a ROW VISITOR: exclusive-scan the
/// chunk summaries, apply each chunk's prefix map to its local states,
/// and hand every fixed-up feature row (global time index + Q-basis
/// layout, widened to the f64 boundary) to `sink` in time order — the
/// shared core of the materializing path ([`fixup_sequence`]) and the
/// streaming trainer ([`run_parallel_batch_train_prec`]), so both see
/// identical bits by construction. All arithmetic at `S`; only the
/// feature write widens.
fn fixup_rows<S: Scalar>(
    params: &ScanParams<S>,
    nr: usize,
    n: usize,
    chunks: &[ChunkOut<S>],
    chunk: usize,
    mut sink: impl FnMut(usize, &[f64]),
) {
    let slots = params.slots;

    // phase 2: exclusive scan of chunk summaries (sequential, cheap)
    let mut prefixes = Vec::with_capacity(chunks.len());
    let mut acc = AffineChunk::identity(slots);
    for c in chunks {
        prefixes.push(acc.clone());
        acc = c.total.compose_after(&acc);
    }

    // phase 3: fix-up — the *state entering the chunk* is b_prefix, so
    // s_global(t) = s_local(t) + λ^(row+1) ⊙ b_prefix.
    let mut feat = vec![0.0f64; n];
    for (ci, c) in chunks.iter().enumerate() {
        let pre = &prefixes[ci];
        let lo = ci * chunk;
        // running power λ^(row+1)
        let mut pw_re: Vec<S> = vec![S::ONE; slots];
        let mut pw_im: Vec<S> = vec![S::ZERO; slots];
        for row in 0..c.len {
            // pw ← pw · λ
            for j in 0..slots {
                let (lr, li) = (params.lam_re[j], params.lam_im[j]);
                let (re, im) = (pw_re[j], pw_im[j]);
                pw_re[j] = re * lr - im * li;
                pw_im[j] = re * li + im * lr;
            }
            let s_re = &c.s_re[row * slots..(row + 1) * slots];
            let s_im = &c.s_im[row * slots..(row + 1) * slots];
            let mut col = 0;
            for j in 0..slots {
                // global state = local + λ^(row+1) ⊙ entering-state
                let gre = s_re[j]
                    + pw_re[j] * pre.b_re[j]
                    - pw_im[j] * pre.b_im[j];
                let gim = s_im[j]
                    + pw_re[j] * pre.b_im[j]
                    + pw_im[j] * pre.b_re[j];
                if j < nr {
                    feat[col] = gre.to_f64();
                    col += 1;
                } else {
                    feat[col] = gre.to_f64();
                    feat[col + 1] = gim.to_f64();
                    col += 2;
                }
            }
            sink(lo + row, &feat);
        }
    }
}

// ---------------------------------------------------------------------------
// fused streaming training scan
// ---------------------------------------------------------------------------

/// What to do with one sequence's trajectory in the fused training scan.
#[derive(Clone, Debug)]
pub struct TrainSpec {
    /// Rows streamed into the Gram accumulator; `targets.row(k)` pairs
    /// with state row `train.start + k`. Rows before `train.start` are a
    /// washout — they drive the state but never touch the statistics.
    pub train: Range<usize>,
    /// Spans materialized as `[len × N]` feature matrices (the
    /// validation/test slices the grid's prediction step needs). May
    /// overlap `train`.
    pub eval: Vec<Range<usize>>,
}

/// [`run_parallel_batch_train_prec`] at the f64 oracle precision.
pub fn run_parallel_batch_train(
    esn: &DiagonalEsn,
    inputs: &[Mat],
    targets: &[Mat],
    specs: &[TrainSpec],
    pool: &WorkerPool,
    chunk: usize,
) -> (GramAcc<f64>, Vec<Vec<Mat>>) {
    run_parallel_batch_train_prec::<f64>(esn, inputs, targets, specs, pool, chunk)
}

/// Fused multi-sequence training scan at precision `S`: the batched
/// two-phase chunk scan of [`run_parallel_batch_prec`], with phase 3
/// streaming each fixed-up feature row straight into a per-worker
/// [`GramAcc`] instead of a feature matrix. One accumulator per sequence
/// (row pairing restarts per sequence), merged **in sequence order** on
/// the coordinator — a deterministic reduction, so the result is
/// bit-identical (f64) to materializing each sequence's `[T × F]` block,
/// slicing its train span, running the monolithic `GramStats::new`, and
/// merging in the same order (tested). Only the `spec.eval` spans are
/// materialized; the training span never exists as a matrix.
///
/// Returns the merged accumulator (solve with
/// [`GramAcc::solve_scaled`], or widen via [`GramAcc::finish`] for the
/// f64 sub-grid sweep) and, per sequence, one `Mat` per requested eval
/// span.
pub fn run_parallel_batch_train_prec<S: Scalar>(
    esn: &DiagonalEsn,
    inputs: &[Mat],
    targets: &[Mat],
    specs: &[TrainSpec],
    pool: &WorkerPool,
    chunk: usize,
) -> (GramAcc<S>, Vec<Vec<Mat>>) {
    assert!(!inputs.is_empty(), "training scan needs at least one sequence");
    assert_eq!(inputs.len(), targets.len(), "inputs/targets length mismatch");
    assert_eq!(inputs.len(), specs.len(), "inputs/specs length mismatch");
    let d = targets[0].cols();
    for ((u, y), spec) in inputs.iter().zip(targets).zip(specs) {
        assert_eq!(y.cols(), d, "target dims must agree across sequences");
        assert_eq!(
            y.rows(),
            spec.train.len(),
            "targets must align with the train span"
        );
        assert!(spec.train.end <= u.rows(), "train span out of range");
        for r in &spec.eval {
            assert!(r.end <= u.rows(), "eval span out of range");
        }
    }

    let params = ScanParams::<S>::new(esn);
    let chunk = chunk.max(1);
    let nr = esn.spec.n_real;
    let n = esn.n();
    let per_seq = phase1_chunks(&params, inputs, pool, chunk);

    // phases 2–3 as per-sequence jobs: each worker replays its sequence's
    // fix-up and feeds the rows straight into its own accumulator / eval
    // mats — parallel across sequences, nothing [T × F] ever allocated.
    let jobs: Vec<(Vec<ChunkOut<S>>, Mat, TrainSpec)> = per_seq
        .into_iter()
        .zip(targets)
        .zip(specs)
        .map(|((chunks, y), spec)| (chunks, y.clone(), spec.clone()))
        .collect();
    let worker_params = params.clone();
    let results: Vec<(GramAcc<S>, Vec<Mat>)> =
        pool.map(jobs, move |(chunks, target, spec)| {
            let mut acc = GramAcc::<S>::new(n, target.cols());
            let mut evals: Vec<Mat> =
                spec.eval.iter().map(|r| Mat::zeros(r.len(), n)).collect();
            fixup_rows(&worker_params, nr, n, &chunks, chunk, |t, row| {
                if spec.train.contains(&t) {
                    acc.push_row(row, target.row(t - spec.train.start));
                }
                for (k, r) in spec.eval.iter().enumerate() {
                    if r.contains(&t) {
                        evals[k].row_mut(t - r.start).copy_from_slice(row);
                    }
                }
            });
            (acc, evals)
        });

    // deterministic reduction: fold from the first sequence's accumulator
    // in sequence order (never from a zero accumulator — `0.0 + (−0.0)`
    // would flip a sign bit and break the bitwise contract)
    let mut it = results.into_iter();
    let (mut acc, first_evals) = it.next().expect("≥ 1 sequence");
    let mut evals = Vec::with_capacity(inputs.len());
    evals.push(first_evals);
    for (a, e) in it {
        acc.merge(a);
        evals.push(e);
    }
    (acc, evals)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::reservoir::EsnConfig;
    use crate::rng::Pcg64;
    use crate::spectral::uniform::uniform_spectrum;

    fn setup(n: usize, seed: u64) -> DiagonalEsn {
        let config = EsnConfig::default().with_n(n).with_seed(seed);
        let mut rng = Pcg64::new(seed, 160);
        let spec = uniform_spectrum(n, 0.9, &mut rng);
        DiagonalEsn::from_dpg(spec, &config, &mut rng)
    }

    #[test]
    fn chunked_scan_equals_sequential() {
        let esn = setup(20, 1);
        let mut rng = Pcg64::seeded(2);
        let u = Mat::randn(103, 1, &mut rng); // deliberately not a multiple
        let pool = WorkerPool::new(3);
        let seq = esn.run(&u);
        for chunk in [1, 7, 16, 50, 103, 200] {
            let par = run_parallel(&esn, &u, &pool, chunk);
            let err = par.max_abs_diff(&seq);
            assert!(err < 1e-9, "chunk={chunk} err={err}");
        }
    }

    #[test]
    fn near_unit_modulus_stability() {
        // |λ| ≈ 1 is the worst case for the chunk-total maps; the
        // incremental product must track λ^len without drift even for a
        // single whole-sequence chunk
        let esn = setup(12, 3);
        let esn = DiagonalEsn::from_parts(
            esn.spec.scaled(1.0 / esn.spec.radius()),
            esn.win_re.clone(),
            esn.win_im.clone(),
            None,
        );
        let mut rng = Pcg64::seeded(4);
        let u = Mat::randn(256, 1, &mut rng);
        let pool = WorkerPool::new(2);
        let seq = esn.run(&u);
        let scale = seq.data().iter().fold(1.0f64, |m, x| m.max(x.abs()));
        for chunk in [32, 256] {
            let par = run_parallel(&esn, &u, &pool, chunk);
            assert!(par.max_abs_diff(&seq) / scale < 1e-10, "chunk={chunk}");
        }
    }

    #[test]
    fn batched_scan_matches_per_sequence_runs() {
        let esn = setup(16, 5);
        let mut rng = Pcg64::seeded(6);
        // uneven lengths: chunks-per-sequence varies, exercising regrouping
        let inputs: Vec<Mat> = [37usize, 64, 5, 103]
            .iter()
            .map(|&t| Mat::randn(t, 1, &mut rng))
            .collect();
        let pool = WorkerPool::new(3);
        let batched = run_parallel_batch(&esn, &inputs, &pool, 16);
        assert_eq!(batched.len(), inputs.len());
        for (u, par) in inputs.iter().zip(&batched) {
            let seq = esn.run(u);
            let err = par.max_abs_diff(&seq);
            assert!(err < 1e-9, "T={} err={err}", u.rows());
        }
    }

    #[test]
    fn batched_scan_empty_and_tiny_sequences() {
        let esn = setup(8, 7);
        let mut rng = Pcg64::seeded(8);
        let inputs = vec![
            Mat::zeros(0, 1),
            Mat::randn(1, 1, &mut rng),
            Mat::randn(2, 1, &mut rng),
        ];
        let pool = WorkerPool::new(2);
        let batched = run_parallel_batch(&esn, &inputs, &pool, 4);
        assert_eq!(batched[0].rows(), 0);
        for (u, par) in inputs.iter().zip(&batched) {
            assert!(par.max_abs_diff(&esn.run(u)) < 1e-12);
        }
    }

    #[test]
    fn f32_scan_tracks_f64_sequential_within_budget() {
        // the f32 instantiation: same algorithm on narrowed planes; error
        // vs the f64 oracle stays within the usual ε₃₂ · horizon budget
        // (coarse bound here — the precise model lives in
        // rust/tests/precision.rs for the lane engine)
        let esn = setup(24, 9);
        let mut rng = Pcg64::seeded(10);
        let u = Mat::randn(128, 1, &mut rng);
        let pool = WorkerPool::new(2);
        let seq = esn.run(&u);
        let scale = seq.data().iter().fold(1.0f64, |m, x| m.max(x.abs()));
        for chunk in [1, 16, 128] {
            let par = run_parallel_prec::<f32>(&esn, &u, &pool, chunk);
            let err = par.max_abs_diff(&seq);
            assert!(
                err < 1e-3 * scale,
                "chunk={chunk} err={err} scale={scale}"
            );
            assert!(err > 0.0, "f32 scan suspiciously exact (ran at f64?)");
        }
    }

    fn slice(m: &Mat, r: std::ops::Range<usize>) -> Mat {
        let mut out = Mat::zeros(r.len(), m.cols());
        for (row, t) in r.enumerate() {
            out.row_mut(row).copy_from_slice(m.row(t));
        }
        out
    }

    #[test]
    fn fused_train_bit_identical_to_materialized_gram() {
        // the tentpole contract: streaming phase-3 rows into the
        // accumulator must be bit-identical to materializing the [T × F]
        // block, slicing the train span, and running GramStats::new —
        // across chunk sizes, with an odd-offset odd-length train span
        use crate::readout::GramStats;
        let esn = setup(18, 21);
        let mut rng = Pcg64::seeded(22);
        let u = Mat::randn(111, 1, &mut rng); // odd length
        let train = 9..86; // odd offset, odd length
        let y = Mat::randn(train.len(), 1, &mut rng);
        let pool = WorkerPool::new(3);
        let spec = TrainSpec {
            train: train.clone(),
            eval: vec![86..111, 0..9],
        };
        for chunk in [7usize, 16, 50, 111] {
            let (acc, evals) = run_parallel_batch_train(
                &esn,
                std::slice::from_ref(&u),
                std::slice::from_ref(&y),
                std::slice::from_ref(&spec),
                &pool,
                chunk,
            );
            assert_eq!(acc.rows(), train.len());
            // reference: materialize with the SAME chunking, then the
            // monolithic constructor over the sliced train block
            let states = run_parallel(&esn, &u, &pool, chunk);
            let want = GramStats::new(&slice(&states, train.clone()), &y);
            for (alpha, s) in [(1e-6, 1.0), (0.5, 0.01)] {
                let got_ro = acc.solve_scaled(alpha, s).unwrap();
                let want_ro = want.solve_scaled(alpha, s).unwrap();
                assert_eq!(
                    got_ro.w.data(),
                    want_ro.w.data(),
                    "chunk={chunk} alpha={alpha} s={s}: fused readout \
                     diverged from materialized fit"
                );
                assert_eq!(got_ro.b, want_ro.b, "chunk={chunk}");
            }
            // eval spans are the materialized slices, bit for bit
            assert_eq!(evals.len(), 1);
            assert_eq!(evals[0].len(), 2);
            for (mat, r) in evals[0].iter().zip([86..111, 0..9]) {
                assert_eq!(
                    mat.data(),
                    slice(&states, r).data(),
                    "chunk={chunk}: eval span diverged"
                );
            }
        }
    }

    #[test]
    fn fused_multi_sequence_merge_matches_per_sequence_accumulators() {
        // multi-sequence grid fit: per-worker accumulators merged in
        // sequence order ≡ per-sequence monolithic accumulation merged in
        // the same order — and uneven lengths exercise the regrouping
        use crate::readout::GramAcc;
        let esn = setup(14, 23);
        let mut rng = Pcg64::seeded(24);
        let lens = [37usize, 64, 5, 103];
        let inputs: Vec<Mat> =
            lens.iter().map(|&t| Mat::randn(t, 1, &mut rng)).collect();
        let specs: Vec<TrainSpec> = lens
            .iter()
            .map(|&t| TrainSpec {
                // washout 3 where it fits, otherwise the whole sequence
                train: if t > 6 { 3..t } else { 0..t },
                eval: vec![],
            })
            .collect();
        let targets: Vec<Mat> = specs
            .iter()
            .map(|s| Mat::randn(s.train.len(), 1, &mut rng))
            .collect();
        let pool = WorkerPool::new(3);
        let (acc, evals) =
            run_parallel_batch_train(&esn, &inputs, &targets, &specs, &pool, 16);
        assert_eq!(evals.len(), inputs.len());
        assert_eq!(
            acc.rows(),
            specs.iter().map(|s| s.train.len()).sum::<usize>()
        );
        // reference: materialize every sequence, one-push per-sequence
        // accumulators, fold-merge in sequence order
        let mats = run_parallel_batch(&esn, &inputs, &pool, 16);
        let mut accs = mats
            .iter()
            .zip(&specs)
            .zip(&targets)
            .map(|((m, s), y)| {
                let mut a = GramAcc::<f64>::new(esn.n(), 1);
                a.push_rows(&slice(m, s.train.clone()), y);
                a
            })
            .collect::<Vec<_>>()
            .into_iter();
        let mut want = accs.next().unwrap();
        for a in accs {
            want.merge(a);
        }
        let got_ro = acc.solve_scaled(1e-5, 1.0).unwrap();
        let want_ro = want.solve_scaled(1e-5, 1.0).unwrap();
        assert_eq!(got_ro.w.data(), want_ro.w.data());
        assert_eq!(got_ro.b, want_ro.b);
    }

    #[test]
    fn f32_fused_training_tracks_f64_within_coarse_budget() {
        // the all-f32 training point: accumulate AND solve at f32; the
        // readout must track the f64 oracle loosely (the calibrated
        // budget model lives in rust/tests/precision.rs) and must not be
        // secretly running at f64
        let esn = setup(16, 25);
        let mut rng = Pcg64::seeded(26);
        let u = Mat::randn(120, 1, &mut rng);
        let train = 10..120;
        let y = Mat::randn(train.len(), 1, &mut rng);
        let pool = WorkerPool::new(2);
        let spec = TrainSpec { train, eval: vec![] };
        let (a64, _) = run_parallel_batch_train_prec::<f64>(
            &esn,
            std::slice::from_ref(&u),
            std::slice::from_ref(&y),
            std::slice::from_ref(&spec),
            &pool,
            16,
        );
        let (a32, _) = run_parallel_batch_train_prec::<f32>(
            &esn,
            std::slice::from_ref(&u),
            std::slice::from_ref(&y),
            std::slice::from_ref(&spec),
            &pool,
            16,
        );
        // generous ridge keeps the system well-conditioned at f32, so the
        // comparison measures accumulation rounding, not κ amplification
        let r64 = a64.solve_scaled(1.0, 1.0).unwrap();
        let r32 = a32.solve_scaled(1.0, 1.0).unwrap();
        let scale = r64.w.data().iter().fold(1.0f64, |m, v| m.max(v.abs()));
        let diff = r64.w.max_abs_diff(&r32.w);
        assert!(
            diff < 0.5 * scale,
            "f32 training readout drifted: {diff} vs scale {scale}"
        );
        assert!(diff > 0.0, "f32 training suspiciously exact (ran at f64?)");
        assert!(r32.w.data().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn f32_chunked_scan_consistent_across_chunk_sizes() {
        // chunking changes the association order, not the algorithm: all
        // f32 chunkings must stay within a few ULP-horizons of each other
        let esn = setup(16, 11);
        let mut rng = Pcg64::seeded(12);
        let u = Mat::randn(96, 1, &mut rng);
        let pool = WorkerPool::new(3);
        let whole = run_parallel_prec::<f32>(&esn, &u, &pool, 96);
        let scale = whole.data().iter().fold(1.0f64, |m, x| m.max(x.abs()));
        for chunk in [4, 13, 32] {
            let par = run_parallel_prec::<f32>(&esn, &u, &pool, chunk);
            let err = par.max_abs_diff(&whole);
            assert!(err < 1e-3 * scale, "chunk={chunk} err={err}");
        }
    }
}
