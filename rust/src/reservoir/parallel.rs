//! Appendix B — parallelization of the diagonal recurrence across TIME,
//! natively in Rust: the affine maps `(a, b): s ↦ a⊙s + b` form a monoid
//! under composition, so the trajectory is an inclusive prefix scan. This
//! module implements the classic two-phase chunked scan:
//!
//! 1. split the sequence into chunks; scan each chunk independently
//!    (parallel across the worker pool), also composing the chunk's total
//!    affine map;
//! 2. exclusive-scan the chunk summaries sequentially (cheap: one map per
//!    chunk), then fix up each chunk's states with its prefix map
//!    (parallel again).
//!
//! [`run_parallel_batch`] is the batched form: phase 1 of EVERY sequence
//! is flattened into one `sequences × chunks` job list, so the worker
//! pool stays full even when a single sequence has fewer chunks than the
//! pool has threads — the time-scan analogue of `BatchEsn`'s lane
//! batching. On this 1-vCPU container the wall-clock win is nil — the
//! value is the verified ALGORITHM (work O(T·N), depth O(T/C + #chunks)),
//! mirroring the Pallas `assoc_scan` kernel so both sides of the stack
//! implement Appendix B.
//!
//! The scan is **precision-generic** over [`Scalar`] (the same trait the
//! batched lane engine uses): [`run_parallel_prec`] /
//! [`run_parallel_batch_prec`] downcast `(Λ, [W_in]_P)` once and run
//! every chunk scan, summary composition, and fix-up at `S` — so the
//! training path can generate states at the f32 kernel precision point
//! (half the plane traffic) as well as at the f64 oracle. The boundary
//! stays `f64`-in/`f64`-out: inputs are narrowed per step exactly like
//! `BatchEsn` narrows them, and the widening of the output features is
//! exact. The bare [`run_parallel`] / [`run_parallel_batch`] are the
//! `f64` instantiation (bit-compatible with the previous f64-only form).

use crate::coordinator::WorkerPool;
use crate::linalg::Mat;
use crate::num::Scalar;

use super::DiagonalEsn;

/// Per-slot affine map `(a, b)` over split-complex planes at precision `S`.
#[derive(Clone)]
struct AffineChunk<S> {
    a_re: Vec<S>,
    a_im: Vec<S>,
    b_re: Vec<S>,
    b_im: Vec<S>,
}

impl<S: Scalar> AffineChunk<S> {
    fn identity(slots: usize) -> Self {
        Self {
            a_re: vec![S::ONE; slots],
            a_im: vec![S::ZERO; slots],
            b_re: vec![S::ZERO; slots],
            b_im: vec![S::ZERO; slots],
        }
    }

    /// `self ∘ prev` (apply `prev` first): `(a₂, b₂)∘(a₁, b₁) =
    /// (a₂a₁, a₂b₁ + b₂)`.
    fn compose_after(&self, prev: &AffineChunk<S>) -> AffineChunk<S> {
        let n = self.a_re.len();
        let mut out = AffineChunk::identity(n);
        for j in 0..n {
            let (ar, ai) = (self.a_re[j], self.a_im[j]);
            out.a_re[j] = ar * prev.a_re[j] - ai * prev.a_im[j];
            out.a_im[j] = ar * prev.a_im[j] + ai * prev.a_re[j];
            out.b_re[j] = ar * prev.b_re[j] - ai * prev.b_im[j] + self.b_re[j];
            out.b_im[j] = ar * prev.b_im[j] + ai * prev.b_re[j] + self.b_im[j];
        }
        out
    }
}

/// Phase-1 output for one chunk: its local (from-zero) states — row-major
/// `[len × slots]` split planes — and total affine map.
struct ChunkOut<S> {
    len: usize,
    s_re: Vec<S>,
    s_im: Vec<S>,
    total: AffineChunk<S>,
}

/// The reservoir's parameters downcast once to scan precision `S`:
/// per-slot `Λ` components and `[d_in × slots]` input-weight planes.
#[derive(Clone)]
struct ScanParams<S> {
    slots: usize,
    lam_re: Vec<S>,
    lam_im: Vec<S>,
    win_re: Vec<S>,
    win_im: Vec<S>,
}

impl<S: Scalar> ScanParams<S> {
    fn new(esn: &DiagonalEsn) -> Self {
        let slots = esn.spec.slots();
        let d_in = esn.win_re.rows();
        let lam_re = esn.spec.lam.iter().map(|l| S::from_f64(l.re)).collect();
        let lam_im = esn.spec.lam.iter().map(|l| S::from_f64(l.im)).collect();
        let mut win_re = vec![S::ZERO; d_in * slots];
        let mut win_im = vec![S::ZERO; d_in * slots];
        for d in 0..d_in {
            let wr = esn.win_re.row(d);
            let wi = esn.win_im.row(d);
            for j in 0..slots {
                win_re[d * slots + j] = S::from_f64(wr[j]);
                win_im[d * slots + j] = S::from_f64(wi[j]);
            }
        }
        Self {
            slots,
            lam_re,
            lam_im,
            win_re,
            win_im,
        }
    }

    /// One Corollary-2 step on split planes at precision `S` (the input
    /// row is narrowed per element, exactly like the batched lane engine).
    fn step(&self, s_re: &mut [S], s_im: &mut [S], u: &[f64]) {
        let slots = self.slots;
        for j in 0..slots {
            let (lr, li) = (self.lam_re[j], self.lam_im[j]);
            let (re, im) = (s_re[j], s_im[j]);
            s_re[j] = re * lr - im * li;
            s_im[j] = re * li + im * lr;
        }
        for (d, &ud) in u.iter().enumerate() {
            if ud == 0.0 {
                continue;
            }
            let us = S::from_f64(ud);
            let wr = &self.win_re[d * slots..(d + 1) * slots];
            let wi = &self.win_im[d * slots..(d + 1) * slots];
            for j in 0..slots {
                s_re[j] += us * wr[j];
                s_im[j] += us * wi[j];
            }
        }
    }
}

/// Time-parallel run of a diagonal reservoir at the `f64` oracle
/// precision: identical output to [`DiagonalEsn::run`] (up to f64
/// rounding), computed as a chunked prefix scan over `pool`.
pub fn run_parallel(esn: &DiagonalEsn, u: &Mat, pool: &WorkerPool, chunk: usize) -> Mat {
    run_parallel_prec::<f64>(esn, u, pool, chunk)
}

/// [`run_parallel`] at an explicit scan precision `S`.
pub fn run_parallel_prec<S: Scalar>(
    esn: &DiagonalEsn,
    u: &Mat,
    pool: &WorkerPool,
    chunk: usize,
) -> Mat {
    run_parallel_batch_prec::<S>(esn, std::slice::from_ref(u), pool, chunk)
        .pop()
        .expect("one input, one output")
}

/// Batched time-parallel runs over independent sequences (all `[Tᵢ ×
/// D_in]`) at the `f64` oracle precision. Phase 1 fans `Σᵢ ⌈Tᵢ/chunk⌉`
/// chunk scans across the pool in ONE `map` call; phases 2–3 (summary
/// scan + fix-up) run per sequence. Output `i` is identical to
/// `run_parallel(esn, &inputs[i], …)`.
pub fn run_parallel_batch(
    esn: &DiagonalEsn,
    inputs: &[Mat],
    pool: &WorkerPool,
    chunk: usize,
) -> Vec<Mat> {
    run_parallel_batch_prec::<f64>(esn, inputs, pool, chunk)
}

/// [`run_parallel_batch`] at an explicit scan precision `S`: the whole
/// scan — chunk states, chunk-total maps, summary composition, and
/// fix-up — runs on `S` planes, with parameters downcast once up front.
pub fn run_parallel_batch_prec<S: Scalar>(
    esn: &DiagonalEsn,
    inputs: &[Mat],
    pool: &WorkerPool,
    chunk: usize,
) -> Vec<Mat> {
    let params = ScanParams::<S>::new(esn);
    let slots = params.slots;
    let chunk = chunk.max(1);

    // flattened job list: (sequence, chunk-within-sequence)
    let mut jobs: Vec<(usize, usize)> = Vec::new();
    for (si, u) in inputs.iter().enumerate() {
        for ci in 0..u.rows().div_ceil(chunk) {
            jobs.push((si, ci));
        }
    }

    // phase 1: independent chunk scans (parallel across sequences AND
    // chunks) — states-from-zero + the chunk's total affine map
    let worker_params = params.clone();
    let u_all: Vec<Mat> = inputs.to_vec();
    let chunks: Vec<ChunkOut<S>> = pool.map(jobs, move |(si, ci)| {
        let u = &u_all[si];
        let t_len = u.rows();
        let lo = ci * chunk;
        let hi = ((ci + 1) * chunk).min(t_len);
        let len = hi - lo;
        let mut s_re = vec![S::ZERO; len * slots];
        let mut s_im = vec![S::ZERO; len * slots];
        let mut cur_re = vec![S::ZERO; slots];
        let mut cur_im = vec![S::ZERO; slots];
        // total map: a = λ^len (per slot, accumulated INCREMENTALLY
        // alongside the scan — `powi(len as u32)` both truncates 64-bit
        // chunk lengths and drifts at |λ| ≈ 1; the running product is the
        // same recurrence the phase-3 fix-up uses), b = chunk scan from 0
        let mut a_re = vec![S::ONE; slots];
        let mut a_im = vec![S::ZERO; slots];
        for (row, t) in (lo..hi).enumerate() {
            worker_params.step(&mut cur_re, &mut cur_im, u.row(t));
            for j in 0..slots {
                let (lr, li) = (worker_params.lam_re[j], worker_params.lam_im[j]);
                let (re, im) = (a_re[j], a_im[j]);
                a_re[j] = re * lr - im * li;
                a_im[j] = re * li + im * lr;
            }
            s_re[row * slots..(row + 1) * slots].copy_from_slice(&cur_re);
            s_im[row * slots..(row + 1) * slots].copy_from_slice(&cur_im);
        }
        let mut total = AffineChunk::identity(slots);
        total.a_re.copy_from_slice(&a_re);
        total.a_im.copy_from_slice(&a_im);
        total.b_re.copy_from_slice(&cur_re);
        total.b_im.copy_from_slice(&cur_im);
        ChunkOut {
            len,
            s_re,
            s_im,
            total,
        }
    });

    // regroup phase-1 results per sequence (jobs were pushed in
    // (sequence, chunk) order and `map` preserves input order)
    let mut outs = Vec::with_capacity(inputs.len());
    let mut cursor = 0;
    for u in inputs {
        let n_chunks = u.rows().div_ceil(chunk);
        let seq_chunks = &chunks[cursor..cursor + n_chunks];
        cursor += n_chunks;
        outs.push(fixup_sequence(esn, &params, u.rows(), seq_chunks, chunk));
    }
    outs
}

/// Phases 2–3 for one sequence: exclusive-scan the chunk summaries, then
/// apply each chunk's prefix map to its local states. All arithmetic at
/// `S`; only the final feature write widens to the f64 boundary.
fn fixup_sequence<S: Scalar>(
    esn: &DiagonalEsn,
    params: &ScanParams<S>,
    t_len: usize,
    chunks: &[ChunkOut<S>],
    chunk: usize,
) -> Mat {
    let slots = params.slots;

    // phase 2: exclusive scan of chunk summaries (sequential, cheap)
    let mut prefixes = Vec::with_capacity(chunks.len());
    let mut acc = AffineChunk::identity(slots);
    for c in chunks {
        prefixes.push(acc.clone());
        acc = c.total.compose_after(&acc);
    }

    // phase 3: fix-up — the *state entering the chunk* is b_prefix, so
    // s_global(t) = s_local(t) + λ^(row+1) ⊙ b_prefix.
    let mut out = Mat::zeros(t_len, esn.n());
    let nr = esn.spec.n_real;
    for (ci, c) in chunks.iter().enumerate() {
        let pre = &prefixes[ci];
        let lo = ci * chunk;
        // running power λ^(row+1)
        let mut pw_re: Vec<S> = vec![S::ONE; slots];
        let mut pw_im: Vec<S> = vec![S::ZERO; slots];
        for row in 0..c.len {
            // pw ← pw · λ
            for j in 0..slots {
                let (lr, li) = (params.lam_re[j], params.lam_im[j]);
                let (re, im) = (pw_re[j], pw_im[j]);
                pw_re[j] = re * lr - im * li;
                pw_im[j] = re * li + im * lr;
            }
            let s_re = &c.s_re[row * slots..(row + 1) * slots];
            let s_im = &c.s_im[row * slots..(row + 1) * slots];
            let feat = out.row_mut(lo + row);
            let mut col = 0;
            for j in 0..slots {
                // global state = local + λ^(row+1) ⊙ entering-state
                let gre = s_re[j]
                    + pw_re[j] * pre.b_re[j]
                    - pw_im[j] * pre.b_im[j];
                let gim = s_im[j]
                    + pw_re[j] * pre.b_im[j]
                    + pw_im[j] * pre.b_re[j];
                if j < nr {
                    feat[col] = gre.to_f64();
                    col += 1;
                } else {
                    feat[col] = gre.to_f64();
                    feat[col + 1] = gim.to_f64();
                    col += 2;
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::reservoir::EsnConfig;
    use crate::rng::Pcg64;
    use crate::spectral::uniform::uniform_spectrum;

    fn setup(n: usize, seed: u64) -> DiagonalEsn {
        let config = EsnConfig::default().with_n(n).with_seed(seed);
        let mut rng = Pcg64::new(seed, 160);
        let spec = uniform_spectrum(n, 0.9, &mut rng);
        DiagonalEsn::from_dpg(spec, &config, &mut rng)
    }

    #[test]
    fn chunked_scan_equals_sequential() {
        let esn = setup(20, 1);
        let mut rng = Pcg64::seeded(2);
        let u = Mat::randn(103, 1, &mut rng); // deliberately not a multiple
        let pool = WorkerPool::new(3);
        let seq = esn.run(&u);
        for chunk in [1, 7, 16, 50, 103, 200] {
            let par = run_parallel(&esn, &u, &pool, chunk);
            let err = par.max_abs_diff(&seq);
            assert!(err < 1e-9, "chunk={chunk} err={err}");
        }
    }

    #[test]
    fn near_unit_modulus_stability() {
        // |λ| ≈ 1 is the worst case for the chunk-total maps; the
        // incremental product must track λ^len without drift even for a
        // single whole-sequence chunk
        let esn = setup(12, 3);
        let esn = DiagonalEsn::from_parts(
            esn.spec.scaled(1.0 / esn.spec.radius()),
            esn.win_re.clone(),
            esn.win_im.clone(),
            None,
        );
        let mut rng = Pcg64::seeded(4);
        let u = Mat::randn(256, 1, &mut rng);
        let pool = WorkerPool::new(2);
        let seq = esn.run(&u);
        let scale = seq.data().iter().fold(1.0f64, |m, x| m.max(x.abs()));
        for chunk in [32, 256] {
            let par = run_parallel(&esn, &u, &pool, chunk);
            assert!(par.max_abs_diff(&seq) / scale < 1e-10, "chunk={chunk}");
        }
    }

    #[test]
    fn batched_scan_matches_per_sequence_runs() {
        let esn = setup(16, 5);
        let mut rng = Pcg64::seeded(6);
        // uneven lengths: chunks-per-sequence varies, exercising regrouping
        let inputs: Vec<Mat> = [37usize, 64, 5, 103]
            .iter()
            .map(|&t| Mat::randn(t, 1, &mut rng))
            .collect();
        let pool = WorkerPool::new(3);
        let batched = run_parallel_batch(&esn, &inputs, &pool, 16);
        assert_eq!(batched.len(), inputs.len());
        for (u, par) in inputs.iter().zip(&batched) {
            let seq = esn.run(u);
            let err = par.max_abs_diff(&seq);
            assert!(err < 1e-9, "T={} err={err}", u.rows());
        }
    }

    #[test]
    fn batched_scan_empty_and_tiny_sequences() {
        let esn = setup(8, 7);
        let mut rng = Pcg64::seeded(8);
        let inputs = vec![
            Mat::zeros(0, 1),
            Mat::randn(1, 1, &mut rng),
            Mat::randn(2, 1, &mut rng),
        ];
        let pool = WorkerPool::new(2);
        let batched = run_parallel_batch(&esn, &inputs, &pool, 4);
        assert_eq!(batched[0].rows(), 0);
        for (u, par) in inputs.iter().zip(&batched) {
            assert!(par.max_abs_diff(&esn.run(u)) < 1e-12);
        }
    }

    #[test]
    fn f32_scan_tracks_f64_sequential_within_budget() {
        // the f32 instantiation: same algorithm on narrowed planes; error
        // vs the f64 oracle stays within the usual ε₃₂ · horizon budget
        // (coarse bound here — the precise model lives in
        // rust/tests/precision.rs for the lane engine)
        let esn = setup(24, 9);
        let mut rng = Pcg64::seeded(10);
        let u = Mat::randn(128, 1, &mut rng);
        let pool = WorkerPool::new(2);
        let seq = esn.run(&u);
        let scale = seq.data().iter().fold(1.0f64, |m, x| m.max(x.abs()));
        for chunk in [1, 16, 128] {
            let par = run_parallel_prec::<f32>(&esn, &u, &pool, chunk);
            let err = par.max_abs_diff(&seq);
            assert!(
                err < 1e-3 * scale,
                "chunk={chunk} err={err} scale={scale}"
            );
            assert!(err > 0.0, "f32 scan suspiciously exact (ran at f64?)");
        }
    }

    #[test]
    fn f32_chunked_scan_consistent_across_chunk_sizes() {
        // chunking changes the association order, not the algorithm: all
        // f32 chunkings must stay within a few ULP-horizons of each other
        let esn = setup(16, 11);
        let mut rng = Pcg64::seeded(12);
        let u = Mat::randn(96, 1, &mut rng);
        let pool = WorkerPool::new(3);
        let whole = run_parallel_prec::<f32>(&esn, &u, &pool, 96);
        let scale = whole.data().iter().fold(1.0f64, |m, x| m.max(x.abs()));
        for chunk in [4, 13, 32] {
            let par = run_parallel_prec::<f32>(&esn, &u, &pool, chunk);
            let err = par.max_abs_diff(&whole);
            assert!(err < 1e-3 * scale, "chunk={chunk} err={err}");
        }
    }
}
