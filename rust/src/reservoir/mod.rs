//! Reservoir engines.
//!
//! * [`StandardEsn`] — the paper's §2 baseline: explicit `W` (dense or CSR
//!   sparse), `O(c_r·N²)` per step.
//! * [`DiagonalEsn`] — the paper's §3 contribution: slot-form spectrum +
//!   transformed input weights, `O(N)` per step, producing real Q-basis
//!   features (Appendix A layout). Constructed either by diagonalizing a
//!   standard ESN (EWT/EET paths, Theorem 1) or directly from DPG parts.
//! * [`BatchEsn`] — the batched multi-sequence engine: B independent
//!   states in SoA split planes `re/im [slots × B⁺]` (lane blocks padded
//!   to the cache-line width), advanced through one pass over `Λ` per
//!   step with a fused streaming readout — the serving hot path (one
//!   λ-sweep amortized across B users). Precision-generic over
//!   [`crate::num::Scalar`]: `f64` is the bit-exact oracle, `f32` doubles
//!   SIMD width and lanes per cache line.
//! * [`state_matrix`] — Theorem 5: input-weight-independent state matrix
//!   `R(t)`, used to share state computations across the input-scaling
//!   sweep of the grid search and for Appendix C's γ-reparametrization.
//!
//! All engines consume a `[T × D_in]` input matrix and produce a
//! `[T × N]` state/feature matrix whose row `t` is the state after
//! consuming input row `t` (`r(t+1)` in the paper's 1-based indexing).

mod batch;
mod config;
mod diagonal;
pub mod parallel;
mod qbasis;
mod standard;
pub mod state_matrix;

pub use batch::{BatchEsn, LaneReadout};
pub use config::EsnConfig;
pub use diagonal::DiagonalEsn;
pub use qbasis::QBasisEsn;
pub use standard::{StandardEsn, WStore};
