//! Theorem 5 / §3.3: the input-weight-independent state matrix `R(t)`.
//!
//! `R(t) ∈ ℂ^{D_in × slots}` evolves as `R(t) = R(t−1) ⊙ Λ + u(t)ᵀ·1ᵀ`
//! (each row sees the same Λ, each column the same input component), and
//! the actual reservoir state is recovered afterwards by
//! `r(t) = 1ᵀ(W_in ⊙ R(t))` — so states for MANY different `W_in` /
//! input-scaling values can be derived from ONE temporal sweep. The grid
//! search uses this to divide state-computation cost by the size of the
//! input-scaling grid (exactly the speedup the paper reports in §5.1).
//!
//! Appendix C (Theorem 6): for `D_in = D_out = 1` the readout can even be
//! trained directly on `R(t)` (`γ = w_inᵀ ⊙ w_out`), bypassing `W_in`
//! entirely — implemented as [`gamma_features`] + recovery.

use crate::linalg::Mat;
use crate::spectral::Spectrum;

/// The `R(t)` trajectory for one input dimension (`D_in` of these make the
/// full Theorem-4 matrix; MSO and MC are `D_in = 1`).
pub struct StateMatrix {
    /// `[T × slots]` planes of the unweighted states.
    pub r_re: Mat,
    pub r_im: Mat,
    pub spec: Spectrum,
}

/// Sweep `R(t)` for a single input dimension: `R ← R ⊙ Λ + u(t)` (the
/// input enters *unweighted*).
pub fn state_matrix_1d(spec: &Spectrum, u: &[f64]) -> StateMatrix {
    let slots = spec.slots();
    let t_len = u.len();
    let mut r_re = Mat::zeros(t_len, slots);
    let mut r_im = Mat::zeros(t_len, slots);
    let mut s_re = vec![0.0; slots];
    let mut s_im = vec![0.0; slots];
    for (t, &ut) in u.iter().enumerate() {
        for j in 0..slots {
            let l = spec.lam[j];
            let (re, im) = (s_re[j], s_im[j]);
            s_re[j] = re * l.re - im * l.im + ut;
            s_im[j] = re * l.im + im * l.re;
        }
        r_re.row_mut(t).copy_from_slice(&s_re);
        r_im.row_mut(t).copy_from_slice(&s_im);
    }
    StateMatrix {
        r_re,
        r_im,
        spec: spec.clone(),
    }
}

impl StateMatrix {
    /// Theorem 5 recovery: `r(t) = w_in ⊙ R(t)` (1-D case), emitted as
    /// Q-basis features `[T × N]` for a given complex `[W_in]_P` row
    /// (split planes of length `slots`).
    pub fn features_for(&self, win_re: &[f64], win_im: &[f64]) -> Mat {
        let slots = self.spec.slots();
        assert_eq!(win_re.len(), slots);
        let nr = self.spec.n_real;
        let t_len = self.r_re.rows();
        let mut out = Mat::zeros(t_len, self.spec.n);
        for t in 0..t_len {
            let rr = self.r_re.row(t);
            let ri = self.r_im.row(t);
            let row = out.row_mut(t);
            for j in 0..nr {
                // real slot: win_im[j] == 0 ⇒ feature = win_re·R_re
                row[j] = win_re[j] * rr[j] - win_im[j] * ri[j];
            }
            let mut col = nr;
            for j in nr..slots {
                let fre = win_re[j] * rr[j] - win_im[j] * ri[j];
                let fim = win_re[j] * ri[j] + win_im[j] * rr[j];
                row[col] = fre;
                row[col + 1] = fim;
                col += 2;
            }
        }
        out
    }

    /// Appendix C: the raw `R(t)` as Q-layout features (train `γ` on these
    /// directly; `w_out = γ ⊘ w_in` recovers the usual readout when no
    /// `w_in` entry is zero).
    pub fn gamma_features(&self) -> Mat {
        let slots = self.spec.slots();
        let ones_re = vec![1.0; slots];
        let ones_im = vec![0.0; slots];
        let _ = (&ones_re, &ones_im);
        let nr = self.spec.n_real;
        let t_len = self.r_re.rows();
        let mut out = Mat::zeros(t_len, self.spec.n);
        for t in 0..t_len {
            let rr = self.r_re.row(t);
            let ri = self.r_im.row(t);
            let row = out.row_mut(t);
            row[..nr].copy_from_slice(&rr[..nr]);
            let mut col = nr;
            for j in nr..slots {
                row[col] = rr[j];
                row[col + 1] = ri[j];
                col += 2;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::reservoir::{DiagonalEsn, EsnConfig};
    use crate::rng::{Distributions, Pcg64};
    use crate::spectral::uniform::uniform_spectrum;

    #[test]
    fn theorem5_matches_direct_run() {
        let mut rng = Pcg64::seeded(1);
        let config = EsnConfig::default().with_n(20).with_seed(4);
        let spec = uniform_spectrum(20, 0.9, &mut rng);
        let esn = DiagonalEsn::from_dpg(spec, &config, &mut rng);

        let u: Vec<f64> = rng.normal_vec(50);
        let u_mat = Mat::from_rows(50, 1, &u);

        let direct = esn.run(&u_mat);
        let sm = state_matrix_1d(&esn.spec, &u);
        let via_r = sm.features_for(esn.win_re.row(0), esn.win_im.row(0));
        let err = via_r.max_abs_diff(&direct);
        assert!(err < 1e-9, "Theorem 5 violated: {err}");
    }

    #[test]
    fn input_scaling_reuse() {
        // features for scaled W_in == scale × features for base W_in
        let mut rng = Pcg64::seeded(2);
        let spec = uniform_spectrum(16, 0.8, &mut rng);
        let u: Vec<f64> = rng.normal_vec(30);
        let sm = state_matrix_1d(&spec, &u);
        let wr: Vec<f64> = rng.normal_vec(spec.slots());
        let wi: Vec<f64> = rng.normal_vec(spec.slots());
        let base = sm.features_for(&wr, &wi);
        let scaled_w: Vec<f64> = wr.iter().map(|x| x * 0.01).collect();
        let scaled_wi: Vec<f64> = wi.iter().map(|x| x * 0.01).collect();
        let mut scaled = sm.features_for(&scaled_w, &scaled_wi);
        scaled.scale(100.0);
        assert!(scaled.max_abs_diff(&base) < 1e-9);
    }

    #[test]
    fn gamma_features_equal_unit_win() {
        let mut rng = Pcg64::seeded(3);
        let spec = uniform_spectrum(12, 0.7, &mut rng);
        let u: Vec<f64> = rng.normal_vec(25);
        let sm = state_matrix_1d(&spec, &u);
        let ones = vec![1.0; spec.slots()];
        let zeros = vec![0.0; spec.slots()];
        let a = sm.gamma_features();
        let b = sm.features_for(&ones, &zeros);
        assert!(a.max_abs_diff(&b) < 1e-12);
    }
}
