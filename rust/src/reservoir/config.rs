//! Hyper-parameter record shared by every engine and the grid search —
//! mirrors the paper's Table 1 rows plus the generation knobs of §2.5.

/// Echo-State-Network hyper-parameters.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EsnConfig {
    /// Reservoir size `N`.
    pub n: usize,
    /// Input dimensionality `D_in`.
    pub d_in: usize,
    /// Target spectral radius `ρ` (applied to `W` or to `Λ`).
    pub spectral_radius: f64,
    /// Leaking rate `lr ∈ (0, 1]` (Eq. 4 reparametrization).
    pub leak_rate: f64,
    /// Input scaling multiplier on `W_in`.
    pub input_scaling: f64,
    /// Reservoir connectivity `c_r` (probability an entry of `W` is
    /// non-zero).
    pub connectivity: f64,
    /// Input connectivity `c_in`.
    pub input_connectivity: f64,
    /// Base seed for all generation randomness.
    pub seed: u64,
}

impl Default for EsnConfig {
    fn default() -> Self {
        Self {
            n: 100,
            d_in: 1,
            spectral_radius: 0.9,
            leak_rate: 1.0,
            input_scaling: 1.0,
            connectivity: 1.0,
            input_connectivity: 1.0,
            seed: 0,
        }
    }
}

impl EsnConfig {
    /// Builder-style setters (keeps experiment code terse).
    pub fn with_n(mut self, n: usize) -> Self {
        self.n = n;
        self
    }
    pub fn with_d_in(mut self, d: usize) -> Self {
        self.d_in = d;
        self
    }
    pub fn with_sr(mut self, sr: f64) -> Self {
        self.spectral_radius = sr;
        self
    }
    pub fn with_leak(mut self, lr: f64) -> Self {
        self.leak_rate = lr;
        self
    }
    pub fn with_input_scaling(mut self, s: f64) -> Self {
        self.input_scaling = s;
        self
    }
    pub fn with_connectivity(mut self, c: f64) -> Self {
        self.connectivity = c;
        self
    }
    pub fn with_seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }

    /// Validate ranges (panics early with a readable message).
    pub fn validate(&self) {
        assert!(self.n > 0, "N must be positive");
        assert!(self.d_in > 0, "D_in must be positive");
        assert!(
            self.leak_rate > 0.0 && self.leak_rate <= 1.0,
            "leak rate must be in (0, 1]"
        );
        assert!(self.spectral_radius >= 0.0);
        assert!((0.0..=1.0).contains(&self.connectivity));
        assert!((0.0..=1.0).contains(&self.input_connectivity));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_chains() {
        let c = EsnConfig::default()
            .with_n(300)
            .with_sr(1.0)
            .with_leak(0.5)
            .with_seed(7);
        assert_eq!(c.n, 300);
        assert_eq!(c.spectral_radius, 1.0);
        assert_eq!(c.leak_rate, 0.5);
        assert_eq!(c.seed, 7);
        c.validate();
    }

    #[test]
    #[should_panic(expected = "leak rate")]
    fn rejects_zero_leak() {
        EsnConfig::default().with_leak(0.0).validate();
    }
}
