//! The §3 contribution: diagonalized linear ESN. `O(N)` per step.
//!
//! State is kept in the slot form (one complex component per real
//! eigenvalue or conjugate pair) as two split planes `(re, im)`; feature
//! rows are emitted in the real Q-basis layout of Appendix A
//! (`n_real` reals, then interleaved `(Re, Im)` per pair) — the "memory
//! view" expressed as explicit layout. The step is exactly Corollary 2:
//!
//! ```text
//! s(t) = s(t−1) ⊙ Λ + u(t)·[W_in]_P
//! ```

use anyhow::{Context, Result};

use crate::linalg::{eig, CLu, Lu, Mat};
use crate::num::c64;
use crate::readout::Readout;
use crate::rng::Pcg64;
use crate::spectral::eigvecs::{random_eigvecs, SlotBasis};
use crate::spectral::{spectrum_from_eigenvalues, Spectrum};

use super::{EsnConfig, StandardEsn};

/// Diagonalized linear ESN (EWT / EET / DPG all share this engine).
#[derive(Clone, Debug)]
pub struct DiagonalEsn {
    /// Slot-form spectrum (leak + spectral-radius already applied).
    pub spec: Spectrum,
    /// `[D_in × slots]` planes of `[W_in]_P` (leak + input scaling applied).
    pub win_re: Mat,
    pub win_im: Mat,
    /// Real Q-basis (n×n) when available (EWT/EET from a standard ESN, or
    /// DPG with explicit eigenvectors) — needed for the generalized
    /// Tikhonov term `QᵀQ` and for mapping readouts between bases.
    pub q: Option<Mat>,
    /// Optional `[D_out × slots]` planes of `[W_fb]_P` (Eq. 1 feedback in
    /// the eigenbasis — Theorem 1 transforms it like `W_in`).
    pub wfb_re: Option<Mat>,
    pub wfb_im: Option<Mat>,
    pub d_in: usize,
}

impl DiagonalEsn {
    // ------------------------------------------------------------------
    // constructors
    // ------------------------------------------------------------------

    /// EWT/EET path (Theorem 1): diagonalize an existing standard ESN.
    /// One-time `O(N³)`; fails if `W` is numerically non-diagonalizable
    /// (the caller can fall back to the standard engine).
    pub fn from_standard(esn: &StandardEsn) -> Result<Self> {
        let w = esn.w_dense();
        let e = eig(&w);
        let n = w.rows();

        // residual gate: a defective W yields useless eigenvectors
        let scale = w.frobenius().max(1e-300);
        if e.max_residual > 1e-6 * scale.max(1.0) * (n as f64) {
            anyhow::bail!(
                "W numerically non-diagonalizable (residual {:.3e})",
                e.max_residual
            );
        }

        // slot ordering: reals first, one member per conjugate pair
        let spec = spectrum_from_eigenvalues(&e.values, 1e-9);
        let perm = slot_permutation(&e.values, 1e-9);
        debug_assert_eq!(perm.len(), spec.slots());

        // slot basis columns from the eigensolver's P
        let slots = spec.slots();
        let mut cols = crate::linalg::CMat::zeros(n, slots);
        for (j, &src) in perm.iter().enumerate() {
            let mut v = e.p.col(src);
            if j >= spec.n_real && spec.lam[j].im > 0.0 {
                // ensure the stored member matches the im>0 eigenvalue
                if e.values[src].im < 0.0 {
                    for z in v.iter_mut() {
                        *z = z.conj();
                    }
                }
            }
            cols.set_col(j, &v);
        }
        let basis = SlotBasis {
            cols,
            n_real: spec.n_real,
        };
        let q = basis.q_basis();
        // conditioning check on Q (Fig 7's collapse shows up here)
        let lu = Lu::factor(&q);
        if lu.is_singular() {
            anyhow::bail!("eigenbasis Q is singular — eigenspectrum collapsed");
        }

        let (win_re, win_im) = project_input(&esn.w_in, &basis);
        let (wfb_re, wfb_im) = match &esn.w_fb {
            Some(w_fb) => {
                let (re, im) = project_input(w_fb, &basis);
                (Some(re), Some(im))
            }
            None => (None, None),
        };
        Ok(Self {
            spec,
            win_re,
            win_im,
            q: Some(q),
            wfb_re,
            wfb_im,
            d_in: esn.config.d_in,
        })
    }

    /// DPG path (§4.4): spectrum from a generator + eigenvectors from
    /// Algorithm 2 + a fresh `W_in`, never materializing `W`.
    /// The leak (Eq. 4) and input scaling are applied here.
    pub fn from_dpg(spec: Spectrum, config: &EsnConfig, rng: &mut Pcg64) -> Self {
        use crate::rng::Distributions;
        config.validate();
        let spec = spec.apply_leak(config.leak_rate);
        let basis = random_eigvecs(&spec, rng);
        let n = spec.n;
        let mut w_in = Mat::from_fn(config.d_in, n, |_, _| {
            if rng.bernoulli(config.input_connectivity) {
                rng.uniform(-1.0, 1.0)
            } else {
                0.0
            }
        });
        w_in.scale(config.input_scaling * config.leak_rate);
        let (win_re, win_im) = project_input(&w_in, &basis);
        Self {
            spec,
            win_re,
            win_im,
            q: Some(basis.q_basis()),
            wfb_re: None,
            wfb_im: None,
            d_in: config.d_in,
        }
    }

    /// Raw parts (runtime integration, tests).
    pub fn from_parts(spec: Spectrum, win_re: Mat, win_im: Mat, q: Option<Mat>) -> Self {
        assert_eq!(win_re.cols(), spec.slots());
        assert_eq!(win_im.cols(), spec.slots());
        assert_eq!(win_re.rows(), win_im.rows());
        let d_in = win_re.rows();
        Self {
            spec,
            win_re,
            win_im,
            q,
            wfb_re: None,
            wfb_im: None,
            d_in,
        }
    }

    // ------------------------------------------------------------------
    // dynamics
    // ------------------------------------------------------------------

    pub fn n(&self) -> usize {
        self.spec.n
    }

    /// One O(N) step on split planes. `s_re/s_im` have `slots()` entries.
    #[inline]
    pub fn step(&self, s_re: &mut [f64], s_im: &mut [f64], u: &[f64]) {
        let lam = &self.spec.lam;
        let slots = self.spec.slots();
        debug_assert_eq!(s_re.len(), slots);
        // s ← s ⊙ λ
        for j in 0..slots {
            let l = lam[j];
            let (re, im) = (s_re[j], s_im[j]);
            s_re[j] = re * l.re - im * l.im;
            s_im[j] = re * l.im + im * l.re;
        }
        // s += u · [W_in]_P
        for (d, &ud) in u.iter().enumerate() {
            if ud == 0.0 {
                continue;
            }
            let wr = self.win_re.row(d);
            let wi = self.win_im.row(d);
            for j in 0..slots {
                s_re[j] += ud * wr[j];
                s_im[j] += ud * wi[j];
            }
        }
    }

    /// Eq.-1 step with output feedback: `s ← s⊙Λ + u·[W_in]_P +
    /// y_prev·[W_fb]_P` (Theorem 1 (ii) in full).
    pub fn step_fb(&self, s_re: &mut [f64], s_im: &mut [f64], u: &[f64], y_prev: &[f64]) {
        self.step(s_re, s_im, u);
        if let (Some(fr), Some(fi)) = (&self.wfb_re, &self.wfb_im) {
            let slots = self.spec.slots();
            for (d, &yd) in y_prev.iter().enumerate() {
                if yd == 0.0 {
                    continue;
                }
                let wr = fr.row(d);
                let wi = fi.row(d);
                for j in 0..slots {
                    s_re[j] += yd * wr[j];
                    s_im[j] += yd * wi[j];
                }
            }
        }
    }

    /// Teacher-forced feedback run (mirrors
    /// [`StandardEsn::run_teacher_forced`]): `y(−1) = 0`.
    pub fn run_teacher_forced(&self, u: &Mat, y_teacher: &Mat) -> Mat {
        assert_eq!(u.rows(), y_teacher.rows());
        let t_len = u.rows();
        let slots = self.spec.slots();
        let mut s_re = vec![0.0; slots];
        let mut s_im = vec![0.0; slots];
        let mut feats = Mat::zeros(t_len, self.n());
        let zero = vec![0.0; y_teacher.cols()];
        for t in 0..t_len {
            let y_prev: &[f64] = if t == 0 { &zero } else { y_teacher.row(t - 1) };
            self.step_fb(&mut s_re, &mut s_im, u.row(t), y_prev);
            self.write_features(&s_re, &s_im, feats.row_mut(t));
        }
        feats
    }

    /// Run over `[T × D_in]` inputs → `[T × N]` real Q-basis features.
    pub fn run(&self, u: &Mat) -> Mat {
        assert_eq!(u.cols(), self.d_in);
        let t_len = u.rows();
        let slots = self.spec.slots();
        let mut s_re = vec![0.0; slots];
        let mut s_im = vec![0.0; slots];
        let mut feats = Mat::zeros(t_len, self.n());
        for t in 0..t_len {
            self.step(&mut s_re, &mut s_im, u.row(t));
            self.write_features(&s_re, &s_im, feats.row_mut(t));
        }
        feats
    }

    /// Fused streaming readout: run and fold `y = f·W_out + b` each step —
    /// `O(N + N·D_out)` per step, no `[T × N]` trajectory materialized.
    /// Matches `readout.predict(self.run(u))` to rounding.
    pub fn run_readout(&self, u: &Mat, ro: &Readout) -> Mat {
        assert_eq!(u.cols(), self.d_in);
        self.run_readout_inner(u, None, ro)
    }

    /// Fused streaming readout over the Eq.-1 FEEDBACK path (teacher
    /// forcing, `y(−1) = 0`): the readout accumulates directly from the
    /// slot planes each step, so the generative/feedback serving loop
    /// never materializes features either.
    pub fn run_readout_teacher_forced(
        &self,
        u: &Mat,
        y_teacher: &Mat,
        ro: &Readout,
    ) -> Mat {
        assert_eq!(u.rows(), y_teacher.rows());
        self.run_readout_inner(u, Some(y_teacher), ro)
    }

    fn run_readout_inner(&self, u: &Mat, y_teacher: Option<&Mat>, ro: &Readout) -> Mat {
        assert_eq!(ro.w.rows(), self.n());
        let d_out = ro.w.cols();
        let t_len = u.rows();
        let slots = self.spec.slots();
        let mut s_re = vec![0.0; slots];
        let mut s_im = vec![0.0; slots];
        let mut feat = vec![0.0; self.n()];
        let mut y = Mat::zeros(t_len, d_out);
        let zero = vec![0.0; y_teacher.map_or(0, Mat::cols)];
        for t in 0..t_len {
            match y_teacher {
                None => self.step(&mut s_re, &mut s_im, u.row(t)),
                Some(teacher) => {
                    let y_prev: &[f64] =
                        if t == 0 { &zero } else { teacher.row(t - 1) };
                    self.step_fb(&mut s_re, &mut s_im, u.row(t), y_prev);
                }
            }
            self.write_features(&s_re, &s_im, &mut feat);
            let yr = y.row_mut(t);
            for k in 0..d_out {
                let mut acc = ro.b[k];
                for (j, &f) in feat.iter().enumerate() {
                    acc += f * ro.w[(j, k)];
                }
                yr[k] = acc;
            }
        }
        y
    }

    /// Q-basis gather: `[re(real slots) | (re,im) interleaved]`.
    #[inline]
    pub fn write_features(&self, s_re: &[f64], s_im: &[f64], out: &mut [f64]) {
        let nr = self.spec.n_real;
        out[..nr].copy_from_slice(&s_re[..nr]);
        let mut col = nr;
        for j in nr..self.spec.slots() {
            out[col] = s_re[j];
            out[col + 1] = s_im[j];
            col += 2;
        }
    }

    /// Split-plane export for the compiled HLO path / kernels:
    /// `(lam_re, lam_im, win_re, win_im)` with f32 downcast left to the
    /// runtime.
    pub fn kernel_operands(&self) -> (Vec<f64>, Vec<f64>, &Mat, &Mat) {
        let (lr, li) = self.spec.planes();
        (lr, li, &self.win_re, &self.win_im)
    }

    /// f32 split-plane export — the compiled HLO kernels' precision point
    /// and the operand set of the native f32 lane engine:
    /// `(lam_re, lam_im, win_re, win_im)` with the `[D_in × slots]` input
    /// planes flattened row-major. The downcast mirrors what the f32
    /// [`crate::reservoir::BatchEsn`] applies at construction, so the two
    /// paths see identical parameters.
    pub fn to_f32_planes(&self) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
        let (lr, li) = self.spec.planes();
        (
            lr.iter().map(|&x| x as f32).collect(),
            li.iter().map(|&x| x as f32).collect(),
            self.win_re.data().iter().map(|&x| x as f32).collect(),
            self.win_im.data().iter().map(|&x| x as f32).collect(),
        )
    }

    // ------------------------------------------------------------------
    // EWT readout transformation (Theorem 1 (i): [W_out]_Q = Q⁻¹ W_out)
    // ------------------------------------------------------------------

    /// Transform a readout trained on STANDARD states (`[N × D_out]`) into
    /// the Q-basis so it can be applied to this engine's features.
    pub fn transform_readout(&self, w_out: &Mat) -> Result<Mat> {
        let q = self
            .q
            .as_ref()
            .context("no Q basis stored (constructed from raw parts?)")?;
        Lu::factor(q)
            .solve_mat(w_out)
            .context("Q singular while transforming readout")
    }

    /// The generalized Tikhonov matrix `QᵀQ` of Theorem 1 (iv) /
    /// Appendix A Eq. 29.
    pub fn tikhonov_matrix(&self) -> Result<Mat> {
        let q = self
            .q
            .as_ref()
            .context("no Q basis stored")?;
        Ok(q.transpose().matmul(q))
    }

    /// Reconstruct the dense `W = Q·[W]_Q·Q⁻¹` (tests; O(N³)).
    pub fn reconstruct_w(&self) -> Result<Mat> {
        let q = self.q.as_ref().context("no Q basis stored")?;
        // Build the full complex P from slots is equivalent; here use
        // P-form directly: W = Re( P diag(λ) P⁻¹ ) with P from Q columns.
        let n = self.n();
        let nr = self.spec.n_real;
        let slots = self.spec.slots();
        let mut p = crate::linalg::CMat::zeros(n, n);
        let mut col = 0;
        for j in 0..nr {
            for i in 0..n {
                p[(i, col)] = c64::real(q[(i, j)]);
            }
            col += 1;
        }
        for j in nr..slots {
            let qr = 2 * (j - nr) + nr;
            for i in 0..n {
                let v = c64::new(q[(i, qr)], q[(i, qr + 1)]);
                p[(i, col)] = v;
                p[(i, col + 1)] = v.conj();
            }
            col += 2;
        }
        let full = self.spec.full();
        let mut pd = p.clone();
        for j in 0..n {
            for i in 0..n {
                let v = pd[(i, j)];
                pd[(i, j)] = v * full[j];
            }
        }
        let pinv = CLu::factor(&p).inverse()?;
        Ok(pd.matmul(&pinv).real_part())
    }
}

/// Map eigensolver output order → slot order: indices of the real
/// eigenvalues first, then the index of one member per conjugate pair.
fn slot_permutation(values: &[c64], tol: f64) -> Vec<usize> {
    let mut reals = Vec::new();
    let mut cpx = Vec::new();
    let mut i = 0;
    while i < values.len() {
        let z = values[i];
        if z.im.abs() <= tol * z.abs().max(1e-300) {
            reals.push(i);
            i += 1;
        } else {
            cpx.push(i); // im>0 member is first by the solver's convention
            i += 2;
        }
    }
    reals.extend(cpx);
    reals
}

/// `[W_in]_P = W_in · P` restricted to slot columns, as split planes.
fn project_input(w_in: &Mat, basis: &SlotBasis) -> (Mat, Mat) {
    let d_in = w_in.rows();
    let n = w_in.cols();
    let slots = basis.cols.cols();
    let mut re = Mat::zeros(d_in, slots);
    let mut im = Mat::zeros(d_in, slots);
    for d in 0..d_in {
        for j in 0..slots {
            let mut acc = c64::ZERO;
            for i in 0..n {
                acc += basis.cols[(i, j)] * w_in[(d, i)];
            }
            re[(d, j)] = acc.re;
            im[(d, j)] = acc.im;
        }
    }
    (re, im)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spectral::uniform::uniform_spectrum;

    fn dpg_esn(n: usize, seed: u64) -> DiagonalEsn {
        let config = EsnConfig::default().with_n(n).with_seed(seed);
        let mut rng = Pcg64::new(seed, 2);
        let spec = uniform_spectrum(n, config.spectral_radius, &mut rng);
        DiagonalEsn::from_dpg(spec, &config, &mut rng)
    }

    #[test]
    fn feature_rows_have_dimension_n() {
        let esn = dpg_esn(50, 1);
        let mut rng = Pcg64::seeded(9);
        let u = Mat::randn(20, 1, &mut rng);
        let feats = esn.run(&u);
        assert_eq!(feats.rows(), 20);
        assert_eq!(feats.cols(), 50);
    }

    #[test]
    fn ewt_states_match_standard_exactly() {
        // THE core claim (Theorem 1): standard states mapped through Q
        // equal the diagonal engine's features.
        let config = EsnConfig::default().with_n(24).with_sr(0.8).with_seed(3);
        let standard = StandardEsn::generate(config);
        let diag = DiagonalEsn::from_standard(&standard).unwrap();
        let mut rng = Pcg64::seeded(10);
        let u = Mat::randn(40, 1, &mut rng);

        let r = standard.run(&u); // [T × N] standard states
        let feats = diag.run(&u); // [T × N] Q-basis features
        let q = diag.q.clone().unwrap();
        let mapped = r.matmul(&q); // [r]_Q = r·Q
        let err = mapped.max_abs_diff(&feats);
        assert!(err < 1e-8, "EWT equivalence violated: {err}");
    }

    #[test]
    fn ewt_readout_transform_preserves_predictions() {
        let config = EsnConfig::default().with_n(16).with_sr(0.7).with_seed(5);
        let standard = StandardEsn::generate(config);
        let diag = DiagonalEsn::from_standard(&standard).unwrap();
        let mut rng = Pcg64::seeded(11);
        let u = Mat::randn(30, 1, &mut rng);
        let w_out = Mat::randn(16, 2, &mut rng); // any readout

        let y_standard = standard.run(&u).matmul(&w_out);
        let w_out_q = diag.transform_readout(&w_out).unwrap();
        let y_diag = diag.run(&u).matmul(&w_out_q);
        assert!(y_standard.max_abs_diff(&y_diag) < 1e-7);
    }

    #[test]
    fn reconstruct_w_roundtrip() {
        let config = EsnConfig::default().with_n(12).with_sr(0.9).with_seed(6);
        let standard = StandardEsn::generate(config);
        let diag = DiagonalEsn::from_standard(&standard).unwrap();
        let w_rec = diag.reconstruct_w().unwrap();
        let err = w_rec.max_abs_diff(&standard.w_dense());
        assert!(err < 1e-7, "W reconstruction error {err}");
    }

    #[test]
    fn dpg_reconstructed_w_has_requested_spectrum() {
        let esn = dpg_esn(14, 7);
        let w = esn.reconstruct_w().unwrap();
        let got = crate::linalg::eigenvalues(&w);
        let mut got_mods: Vec<f64> = got.iter().map(|z| z.abs()).collect();
        let mut want_mods: Vec<f64> =
            esn.spec.full().iter().map(|z| z.abs()).collect();
        got_mods.sort_by(|a, b| a.partial_cmp(b).unwrap());
        want_mods.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for (g, w) in got_mods.iter().zip(&want_mods) {
            assert!((g - w).abs() < 1e-7);
        }
    }

    #[test]
    fn dpg_run_equals_dense_run_of_reconstructed_w() {
        // DPG never materializes W — but if we do materialize it, the
        // standard engine over it must produce the same readout-visible
        // dynamics: r·Q == features.
        let esn = dpg_esn(10, 8);
        let w = esn.reconstruct_w().unwrap();
        // recover the real-basis W_in: [W_in]_P columns → real W_in via Q⁻¹
        // (features f = r·Q ⇒ r = f·Q⁻¹); simpler: drive both engines and
        // compare mapped states.
        let q = esn.q.clone().unwrap();
        let mut rng = Pcg64::seeded(12);
        let u = Mat::randn(25, 1, &mut rng);
        let feats = esn.run(&u);
        // standard engine needs W_in in the original basis: w_in = ?
        // [W_in]_Q = W_in·Q ⇒ W_in = [W_in]_Q·Q⁻¹, where [W_in]_Q comes
        // from the split planes in Q layout.
        let nr = esn.spec.n_real;
        let slots = esn.spec.slots();
        let mut win_q = Mat::zeros(1, esn.n());
        for j in 0..nr {
            win_q[(0, j)] = esn.win_re[(0, j)];
        }
        let mut col = nr;
        for j in nr..slots {
            win_q[(0, col)] = esn.win_re[(0, j)];
            win_q[(0, col + 1)] = esn.win_im[(0, j)];
            col += 2;
        }
        let qinv = Lu::factor(&q).inverse().unwrap();
        let w_in = win_q.matmul(&qinv);
        let standard = StandardEsn::from_parts(
            w,
            w_in,
            EsnConfig::default().with_n(10),
        );
        let mapped = standard.run(&u).matmul(&q);
        let err = mapped.max_abs_diff(&feats);
        assert!(err < 1e-7, "DPG/standard equivalence: {err}");
    }

    #[test]
    fn feedback_path_preserves_theorem1_equivalence() {
        // Eq. 1 WITH W_fb: standard teacher-forced states mapped through Q
        // must equal the diagonal engine's teacher-forced features.
        let config = EsnConfig::default().with_n(18).with_sr(0.7).with_seed(21);
        let mut rng = Pcg64::seeded(22);
        let w_fb = Mat::randn(1, 18, &mut rng);
        let standard = StandardEsn::generate(config).with_feedback(w_fb);
        let diag = DiagonalEsn::from_standard(&standard).unwrap();
        assert!(diag.wfb_re.is_some());

        let u = Mat::randn(35, 1, &mut rng);
        let y_teacher = Mat::randn(35, 1, &mut rng);
        let r = standard.run_teacher_forced(&u, &y_teacher);
        let feats = diag.run_teacher_forced(&u, &y_teacher);
        let q = diag.q.clone().unwrap();
        let mapped = r.matmul(&q);
        let err = mapped.max_abs_diff(&feats);
        assert!(err < 1e-8, "feedback EWT equivalence violated: {err}");
        // and feedback actually matters (differs from the no-feedback run)
        let no_fb = diag.run(&u);
        assert!(no_fb.max_abs_diff(&feats) > 1e-6);
    }

    #[test]
    fn step_zero_input_decays_with_radius_below_one() {
        let esn = dpg_esn(30, 9);
        let slots = esn.spec.slots();
        let mut s_re = vec![1.0; slots];
        let mut s_im = vec![0.5; slots];
        for _ in 0..500 {
            esn.step(&mut s_re, &mut s_im, &[0.0]);
        }
        let energy: f64 = s_re
            .iter()
            .zip(&s_im)
            .map(|(a, b)| a * a + b * b)
            .sum();
        assert!(energy < 1e-10, "energy={energy}");
    }

    #[test]
    fn f32_planes_are_the_downcast_kernel_operands() {
        let esn = dpg_esn(26, 11);
        let (lr, li, wr, wi) = esn.kernel_operands();
        let (lr32, li32, wr32, wi32) = esn.to_f32_planes();
        assert_eq!(lr32.len(), lr.len());
        assert_eq!(li32.len(), li.len());
        assert_eq!(wr32.len(), wr.rows() * wr.cols());
        assert_eq!(wi32.len(), wi.rows() * wi.cols());
        for (a, b) in lr.iter().zip(&lr32) {
            assert_eq!(*a as f32, *b);
        }
        for (a, b) in li.iter().zip(&li32) {
            assert_eq!(*a as f32, *b);
        }
        for (a, b) in wr.data().iter().zip(&wr32) {
            assert_eq!(*a as f32, *b);
        }
        for (a, b) in wi.data().iter().zip(&wi32) {
            assert_eq!(*a as f32, *b);
        }
    }

    #[test]
    fn tikhonov_matrix_spd() {
        let esn = dpg_esn(18, 10);
        let r = esn.tikhonov_matrix().unwrap();
        // symmetric
        assert!(r.max_abs_diff(&r.transpose()) < 1e-12);
        // positive definite (Cholesky succeeds)
        assert!(crate::linalg::Cholesky::factor(&r).is_ok());
    }
}
