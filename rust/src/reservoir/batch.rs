//! Batched multi-sequence engine: B independent reservoir states advanced
//! through ONE pass over Λ per step — precision-generic and SIMD-shaped.
//!
//! The diagonal update is memory-bound: each step streams `Λ` and
//! `[W_in]_Q` past the ALU to touch `N` state words. Serving one sequence
//! at a time pays that stream once per user; serving B users pays it once
//! per *step* while the per-lane arithmetic — the inner lane loop over a
//! contiguous block — autovectorizes across the batch.
//!
//! ## SoA split-plane layout
//!
//! The state lives in two structure-of-arrays planes, one complex
//! component per *slot* (a real eigenvalue or one member of a conjugate
//! pair, exactly [`DiagonalEsn`](super::DiagonalEsn)'s slot form):
//!
//! ```text
//! re[slot × B⁺]   im[slot × B⁺]      B⁺ = B padded up to Scalar::LANES
//! ```
//!
//! Slot `j`'s B lanes are contiguous at `re[j·B⁺ .. j·B⁺+B]` (likewise
//! `im`); real-eigenvalue slots never touch their `im` row. Lane counts
//! are padded to the cache-line width so every inner loop has an exact
//! SIMD-friendly trip count (padding lanes carry zeros and are never
//! observable). The element type is generic over [`Scalar`]: `f64` is the
//! bit-exact oracle, `f32` doubles lanes per cache line and SIMD width —
//! the compiled HLO kernels' precision point (see `rust/tests/precision.rs`
//! for the error budget).
//!
//! Per lane the arithmetic is EXPRESSION-IDENTICAL to [`QBasisEsn::step`]'s
//! fused `d_in = 1` path, so at `f64` a batched sweep is bit-identical to
//! B independent sequential runs — equivalence is exact, not approximate
//! (tested below and in `rust/tests/equivalence.rs`). At every precision,
//! lane results are independent of batch size and lane position (tested in
//! `rust/tests/precision.rs`).
//!
//! The fused readout ([`BatchEsn::run_readout`]) folds `y = f·W_out + b`
//! into the sweep: the request path does `O(N + N·D_out)` work per step
//! per lane with zero `[T × N]` trajectory materialization. The masked
//! step ([`BatchEsn::step_masked`] / [`BatchEsn::sweep_streams`]) lets the
//! server coalesce per-connection streaming states of different lengths
//! into the same sweep: frozen lanes keep their exact bits through a
//! branchless per-lane select (so a loaded hub vectorizes like the
//! unmasked path), active lanes advance.
//!
//! All public APIs stay `f64` at the boundary (inputs, readouts, gathered
//! lane states); `f32 → f64` widening is exact, so gather/scatter
//! round-trips are lossless at both precisions.

use crate::linalg::Mat;
use crate::num::Scalar;
use crate::readout::Readout;

use super::QBasisEsn;

/// Lane-block kernels. The default build uses the chunked/unrolled form:
/// fixed `Scalar::LANES`-wide blocks the autovectorizer maps to full-width
/// SIMD (lane blocks are padded, so the remainder loops are dead in
/// practice). Build with `--features plain-kernel` to A/B against the
/// naive scalar loops — both forms compute the identical expression per
/// element, so results are bit-for-bit the same.
///
/// The `*_masked` variants are branchless selects (`mask ? new : old` per
/// lane): the updated value is computed for every lane and kept only
/// where the mask is on, so the loaded-hub case (most lanes active)
/// vectorizes like the unmasked path. Frozen lanes keep their exact bits
/// — the select keeps the stored value, never a recomputation.
mod kernels {
    use crate::num::Scalar;

    /// `s[b] = s[b]·lam + u[b]·w` — fused Λ-rotate + input-add, real slot.
    #[cfg(not(feature = "plain-kernel"))]
    #[inline(always)]
    pub fn fused_real<S: Scalar>(s: &mut [S], u: &[S], lam: S, w: S) {
        debug_assert_eq!(s.len(), u.len());
        let mut sc = s.chunks_exact_mut(S::LANES);
        let mut uc = u.chunks_exact(S::LANES);
        for (sv, uv) in (&mut sc).zip(&mut uc) {
            for k in 0..S::LANES {
                sv[k] = sv[k] * lam + uv[k] * w;
            }
        }
        for (sb, &ub) in sc.into_remainder().iter_mut().zip(uc.remainder()) {
            *sb = *sb * lam + ub * w;
        }
    }

    #[cfg(feature = "plain-kernel")]
    #[inline(always)]
    pub fn fused_real<S: Scalar>(s: &mut [S], u: &[S], lam: S, w: S) {
        debug_assert_eq!(s.len(), u.len());
        for (sb, &ub) in s.iter_mut().zip(u) {
            *sb = *sb * lam + ub * w;
        }
    }

    /// Fused 2×2 rotation-scaling + input-add for a conjugate-pair slot:
    /// `re' = re·a − im·b + u·w0`, `im' = re·b + im·a + u·w1`.
    #[cfg(not(feature = "plain-kernel"))]
    #[inline(always)]
    pub fn fused_pair<S: Scalar>(
        re: &mut [S],
        im: &mut [S],
        u: &[S],
        a: S,
        b: S,
        w0: S,
        w1: S,
    ) {
        debug_assert_eq!(re.len(), im.len());
        debug_assert_eq!(re.len(), u.len());
        let mut rc = re.chunks_exact_mut(S::LANES);
        let mut ic = im.chunks_exact_mut(S::LANES);
        let mut uc = u.chunks_exact(S::LANES);
        for ((rv, iv), uv) in (&mut rc).zip(&mut ic).zip(&mut uc) {
            for k in 0..S::LANES {
                let (r0, i0) = (rv[k], iv[k]);
                rv[k] = r0 * a - i0 * b + uv[k] * w0;
                iv[k] = r0 * b + i0 * a + uv[k] * w1;
            }
        }
        for ((rb, ib), &ub) in rc
            .into_remainder()
            .iter_mut()
            .zip(ic.into_remainder().iter_mut())
            .zip(uc.remainder())
        {
            let (r0, i0) = (*rb, *ib);
            *rb = r0 * a - i0 * b + ub * w0;
            *ib = r0 * b + i0 * a + ub * w1;
        }
    }

    #[cfg(feature = "plain-kernel")]
    #[inline(always)]
    pub fn fused_pair<S: Scalar>(
        re: &mut [S],
        im: &mut [S],
        u: &[S],
        a: S,
        b: S,
        w0: S,
        w1: S,
    ) {
        debug_assert_eq!(re.len(), im.len());
        debug_assert_eq!(re.len(), u.len());
        for ((rb, ib), &ub) in re.iter_mut().zip(im.iter_mut()).zip(u) {
            let (r0, i0) = (*rb, *ib);
            *rb = r0 * a - i0 * b + ub * w0;
            *ib = r0 * b + i0 * a + ub * w1;
        }
    }

    /// Masked [`fused_real`]: `s[b] = m[b] ? s[b]·lam + u[b]·w : s[b]`.
    ///
    /// Branchless select form — the new value is computed for EVERY lane
    /// and discarded where the mask is off, so a loaded hub (most lanes
    /// active) vectorizes like the unmasked path instead of branching per
    /// lane. Frozen lanes keep their exact bits: the select keeps the old
    /// value itself, never a recomputation of it.
    #[cfg(not(feature = "plain-kernel"))]
    #[inline(always)]
    pub fn fused_real_masked<S: Scalar>(
        s: &mut [S],
        u: &[S],
        m: &[bool],
        lam: S,
        w: S,
    ) {
        debug_assert_eq!(s.len(), u.len());
        debug_assert_eq!(s.len(), m.len());
        let mut sc = s.chunks_exact_mut(S::LANES);
        let mut uc = u.chunks_exact(S::LANES);
        let mut mc = m.chunks_exact(S::LANES);
        for ((sv, uv), mv) in (&mut sc).zip(&mut uc).zip(&mut mc) {
            for k in 0..S::LANES {
                let new = sv[k] * lam + uv[k] * w;
                sv[k] = if mv[k] { new } else { sv[k] };
            }
        }
        for ((sb, &ub), &mb) in sc
            .into_remainder()
            .iter_mut()
            .zip(uc.remainder())
            .zip(mc.remainder())
        {
            let new = *sb * lam + ub * w;
            *sb = if mb { new } else { *sb };
        }
    }

    #[cfg(feature = "plain-kernel")]
    #[inline(always)]
    pub fn fused_real_masked<S: Scalar>(
        s: &mut [S],
        u: &[S],
        m: &[bool],
        lam: S,
        w: S,
    ) {
        debug_assert_eq!(s.len(), u.len());
        debug_assert_eq!(s.len(), m.len());
        for ((sb, &ub), &mb) in s.iter_mut().zip(u).zip(m) {
            let new = *sb * lam + ub * w;
            *sb = if mb { new } else { *sb };
        }
    }

    /// Masked [`fused_pair`]: select form of the 2×2 rotation-scaling +
    /// input-add (same bit-exactness contract as [`fused_real_masked`]).
    #[cfg(not(feature = "plain-kernel"))]
    #[inline(always)]
    pub fn fused_pair_masked<S: Scalar>(
        re: &mut [S],
        im: &mut [S],
        u: &[S],
        m: &[bool],
        a: S,
        b: S,
        w0: S,
        w1: S,
    ) {
        debug_assert_eq!(re.len(), im.len());
        debug_assert_eq!(re.len(), u.len());
        debug_assert_eq!(re.len(), m.len());
        let mut rc = re.chunks_exact_mut(S::LANES);
        let mut ic = im.chunks_exact_mut(S::LANES);
        let mut uc = u.chunks_exact(S::LANES);
        let mut mc = m.chunks_exact(S::LANES);
        for (((rv, iv), uv), mv) in
            (&mut rc).zip(&mut ic).zip(&mut uc).zip(&mut mc)
        {
            for k in 0..S::LANES {
                let (r0, i0) = (rv[k], iv[k]);
                let nr = r0 * a - i0 * b + uv[k] * w0;
                let ni = r0 * b + i0 * a + uv[k] * w1;
                rv[k] = if mv[k] { nr } else { r0 };
                iv[k] = if mv[k] { ni } else { i0 };
            }
        }
        for (((rb, ib), &ub), &mb) in rc
            .into_remainder()
            .iter_mut()
            .zip(ic.into_remainder().iter_mut())
            .zip(uc.remainder())
            .zip(mc.remainder())
        {
            let (r0, i0) = (*rb, *ib);
            let nr = r0 * a - i0 * b + ub * w0;
            let ni = r0 * b + i0 * a + ub * w1;
            *rb = if mb { nr } else { r0 };
            *ib = if mb { ni } else { i0 };
        }
    }

    #[cfg(feature = "plain-kernel")]
    #[inline(always)]
    pub fn fused_pair_masked<S: Scalar>(
        re: &mut [S],
        im: &mut [S],
        u: &[S],
        m: &[bool],
        a: S,
        b: S,
        w0: S,
        w1: S,
    ) {
        debug_assert_eq!(re.len(), im.len());
        debug_assert_eq!(re.len(), u.len());
        debug_assert_eq!(re.len(), m.len());
        for (((rb, ib), &ub), &mb) in
            re.iter_mut().zip(im.iter_mut()).zip(u).zip(m)
        {
            let (r0, i0) = (*rb, *ib);
            let nr = r0 * a - i0 * b + ub * w0;
            let ni = r0 * b + i0 * a + ub * w1;
            *rb = if mb { nr } else { r0 };
            *ib = if mb { ni } else { i0 };
        }
    }

    /// `s[b] *= lam` — rotation pass, real slot (general `d_in` path).
    #[inline(always)]
    pub fn scale<S: Scalar>(s: &mut [S], lam: S) {
        for sb in s.iter_mut() {
            *sb *= lam;
        }
    }

    /// 2×2 rotation-scaling without input (general `d_in` path).
    #[inline(always)]
    pub fn rot_pair<S: Scalar>(re: &mut [S], im: &mut [S], a: S, b: S) {
        debug_assert_eq!(re.len(), im.len());
        for (rb, ib) in re.iter_mut().zip(im.iter_mut()) {
            let (r0, i0) = (*rb, *ib);
            *rb = r0 * a - i0 * b;
            *ib = r0 * b + i0 * a;
        }
    }

    /// Masked [`scale`]: `s[b] = m[b] ? s[b]·lam : s[b]` (select form).
    #[inline(always)]
    pub fn scale_masked<S: Scalar>(s: &mut [S], m: &[bool], lam: S) {
        debug_assert_eq!(s.len(), m.len());
        for (sb, &mb) in s.iter_mut().zip(m) {
            let new = *sb * lam;
            *sb = if mb { new } else { *sb };
        }
    }

    /// Masked [`rot_pair`]: select form of the 2×2 rotation-scaling.
    #[inline(always)]
    pub fn rot_pair_masked<S: Scalar>(
        re: &mut [S],
        im: &mut [S],
        m: &[bool],
        a: S,
        b: S,
    ) {
        debug_assert_eq!(re.len(), im.len());
        debug_assert_eq!(re.len(), m.len());
        for ((rb, ib), &mb) in re.iter_mut().zip(im.iter_mut()).zip(m) {
            let (r0, i0) = (*rb, *ib);
            let nr = r0 * a - i0 * b;
            let ni = r0 * b + i0 * a;
            *rb = if mb { nr } else { r0 };
            *ib = if mb { ni } else { i0 };
        }
    }

    /// Masked [`axpy`]: `acc[b] = m[b] ? acc[b] + x[b]·w : acc[b]`.
    #[inline(always)]
    pub fn axpy_masked<S: Scalar>(acc: &mut [S], x: &[S], m: &[bool], w: S) {
        debug_assert_eq!(acc.len(), x.len());
        debug_assert_eq!(acc.len(), m.len());
        for ((ab, &xb), &mb) in acc.iter_mut().zip(x).zip(m) {
            let new = *ab + xb * w;
            *ab = if mb { new } else { *ab };
        }
    }

    /// `acc[b] += x[b]·w` — input accumulation / readout fold.
    #[cfg(not(feature = "plain-kernel"))]
    #[inline(always)]
    pub fn axpy<S: Scalar>(acc: &mut [S], x: &[S], w: S) {
        debug_assert_eq!(acc.len(), x.len());
        let mut ac = acc.chunks_exact_mut(S::LANES);
        let mut xc = x.chunks_exact(S::LANES);
        for (av, xv) in (&mut ac).zip(&mut xc) {
            for k in 0..S::LANES {
                av[k] += xv[k] * w;
            }
        }
        for (ab, &xb) in ac.into_remainder().iter_mut().zip(xc.remainder()) {
            *ab += xb * w;
        }
    }

    #[cfg(feature = "plain-kernel")]
    #[inline(always)]
    pub fn axpy<S: Scalar>(acc: &mut [S], x: &[S], w: S) {
        debug_assert_eq!(acc.len(), x.len());
        for (ab, &xb) in acc.iter_mut().zip(x) {
            *ab += xb * w;
        }
    }
}

/// B independent SoA split-plane reservoir states sharing one `(Λ,
/// [W_in]_Q)` parameter set at precision `S` (`f64` oracle by default).
#[derive(Clone, Debug)]
pub struct BatchEsn<S: Scalar = f64> {
    engine: QBasisEsn,
    batch: usize,
    /// `batch` rounded up to `S::LANES` — the stride of one slot's lane
    /// block in the planes.
    bpad: usize,
    n_real: usize,
    /// `n_real + n_pairs` — rows of each plane.
    slots: usize,
    d_in: usize,
    /// Per-slot eigenvalue planes (`lam_im[j] = 0` for real slots).
    lam_re: Vec<S>,
    lam_im: Vec<S>,
    /// `[d_in × slots]` input-weight planes (`win_im` zero on real slots).
    win_re: Vec<S>,
    win_im: Vec<S>,
    /// State planes `[slots × bpad]`; padding lanes stay zero.
    re: Vec<S>,
    im: Vec<S>,
    /// Padded per-step input scratch `[d_in × bpad]` (padding stays zero).
    u_pad: Vec<S>,
    /// Padded per-step activity mask `[bpad]` for the branchless masked
    /// kernels (padding lanes stay `false`, so they keep their zeros).
    mask_pad: Vec<bool>,
}

impl BatchEsn<f64> {
    /// Build a `batch`-lane engine at the oracle precision (`f64`) around
    /// (a clone of) `engine`'s parameters. All lanes start at zero.
    pub fn new(engine: QBasisEsn, batch: usize) -> Self {
        Self::with_precision(engine, batch)
    }
}

/// A readout downcast to lane precision `S` once: feature-ordered
/// `[N × D_out]` weights plus bias. Cache one next to a persistent
/// engine (as the server hub does) so per-round sweeps stay
/// allocation-free; at `f64` the cast is the identity copy.
#[derive(Clone, Debug)]
pub struct LaneReadout<S: Scalar> {
    /// Feature-ordered `[N × D_out]`, row-major like [`Readout::w`]'s data.
    w: Vec<S>,
    b: Vec<S>,
    n: usize,
    d_out: usize,
}

impl<S: Scalar> LaneReadout<S> {
    pub fn new(ro: &Readout) -> Self {
        Self {
            w: ro.w.data().iter().map(|&x| S::from_f64(x)).collect(),
            b: ro.b.iter().map(|&x| S::from_f64(x)).collect(),
            n: ro.w.rows(),
            d_out: ro.w.cols(),
        }
    }

    pub fn d_out(&self) -> usize {
        self.d_out
    }
}

impl<S: Scalar> BatchEsn<S> {
    /// Build a `batch`-lane engine at precision `S`, downcasting
    /// `engine`'s parameters once at construction.
    pub fn with_precision(engine: QBasisEsn, batch: usize) -> Self {
        assert!(batch >= 1, "batch must be ≥ 1");
        let nr = engine.n_real;
        let n_pairs = engine.lam_cpx.len() / 2;
        let slots = nr + n_pairs;
        let d_in = engine.d_in();
        let bpad = (batch + S::LANES - 1) / S::LANES * S::LANES;

        let mut lam_re = vec![S::ZERO; slots];
        let mut lam_im = vec![S::ZERO; slots];
        for j in 0..nr {
            lam_re[j] = S::from_f64(engine.lam_real[j]);
        }
        for k in 0..n_pairs {
            lam_re[nr + k] = S::from_f64(engine.lam_cpx[2 * k]);
            lam_im[nr + k] = S::from_f64(engine.lam_cpx[2 * k + 1]);
        }
        let mut win_re = vec![S::ZERO; d_in * slots];
        let mut win_im = vec![S::ZERO; d_in * slots];
        for d in 0..d_in {
            let row = engine.win_q.row(d);
            for j in 0..nr {
                win_re[d * slots + j] = S::from_f64(row[j]);
            }
            for k in 0..n_pairs {
                win_re[d * slots + nr + k] = S::from_f64(row[nr + 2 * k]);
                win_im[d * slots + nr + k] = S::from_f64(row[nr + 2 * k + 1]);
            }
        }
        Self {
            engine,
            batch,
            bpad,
            n_real: nr,
            slots,
            d_in,
            lam_re,
            lam_im,
            win_re,
            win_im,
            re: vec![S::ZERO; slots * bpad],
            im: vec![S::ZERO; slots * bpad],
            u_pad: vec![S::ZERO; d_in * bpad],
            mask_pad: vec![false; bpad],
        }
    }

    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Fault every plane's pages in from the CALLING thread. `vec![ZERO;
    /// n]` goes through `alloc_zeroed`, so the planes arrive as untouched
    /// copy-on-write zero pages — and Linux's default first-touch NUMA
    /// policy homes each page on the node of the thread that first
    /// WRITES it, which for lazily-faulted planes would be whichever
    /// thread ran the first sweep. A pinned sweeper calls this right
    /// after construction so every state/parameter plane is stamped onto
    /// its own core's node. One volatile rewrite of the resident value
    /// per page (plus the last element), so contents are untouched and
    /// the pass costs one page fault per page — the faults construction
    /// deferred.
    pub fn first_touch(&mut self) {
        fn touch<T: Copy>(v: &mut [T]) {
            if v.is_empty() {
                return;
            }
            let stride = (4096 / std::mem::size_of::<T>()).max(1);
            let mut i = 0;
            while i < v.len() {
                // SAFETY: i < v.len(); volatile keeps the write from
                // being elided as a no-op store of the value just read
                unsafe {
                    let p = v.as_mut_ptr().add(i);
                    std::ptr::write_volatile(p, std::ptr::read_volatile(p));
                }
                i += stride;
            }
            let last = v.len() - 1;
            unsafe {
                let p = v.as_mut_ptr().add(last);
                std::ptr::write_volatile(p, std::ptr::read_volatile(p));
            }
        }
        touch(&mut self.lam_re);
        touch(&mut self.lam_im);
        touch(&mut self.win_re);
        touch(&mut self.win_im);
        touch(&mut self.re);
        touch(&mut self.im);
        touch(&mut self.u_pad);
        touch(&mut self.mask_pad);
    }

    pub fn n(&self) -> usize {
        self.engine.n()
    }

    pub fn engine(&self) -> &QBasisEsn {
        &self.engine
    }

    /// Engine precision name ("f64"/"f32") — for metrics and bench rows.
    pub fn precision(&self) -> &'static str {
        S::NAME
    }

    /// Raw SoA state planes `(re, im)`, each `[slots × bpad]` with slot
    /// `j`'s lanes at `j·bpad..j·bpad+batch` (padding lanes are zero).
    pub fn planes(&self) -> (&[S], &[S]) {
        (&self.re, &self.im)
    }

    /// Resident bytes of this engine's parameter, state, and scratch
    /// planes — the marginal cost one more engine adds to a shard. The
    /// multi-tenant registry sizes per-model hubs with this (DESIGN.md
    /// §13): parameter planes scale with `N`, state planes with
    /// `N × bpad`, so a thousand single-lane tenants cost far less than
    /// a thousand full-width hubs would.
    pub fn plane_bytes(&self) -> usize {
        let s = std::mem::size_of::<S>();
        (self.lam_re.len()
            + self.lam_im.len()
            + self.win_re.len()
            + self.win_im.len()
            + self.re.len()
            + self.im.len()
            + self.u_pad.len())
            * s
            + self.mask_pad.len()
    }

    /// Zero every lane.
    pub fn reset(&mut self) {
        self.re.fill(S::ZERO);
        self.im.fill(S::ZERO);
    }

    /// Zero one lane (new connection adopting a recycled slot).
    pub fn reset_lane(&mut self, b: usize) {
        assert!(b < self.batch);
        let bp = self.bpad;
        for j in 0..self.slots {
            self.re[j * bp + b] = S::ZERO;
            self.im[j * bp + b] = S::ZERO;
        }
    }

    /// Gather lane `b`'s state into `out` (length `N`, Q-basis feature
    /// layout — the same row [`QBasisEsn::run`] would emit). The widening
    /// to `f64` is exact at every precision, so
    /// [`Self::set_lane_state`] ∘ `lane_state` round-trips bit-for-bit.
    pub fn lane_state(&self, b: usize, out: &mut [f64]) {
        assert!(b < self.batch);
        assert_eq!(out.len(), self.engine.n());
        let bp = self.bpad;
        let nr = self.n_real;
        for (j, o) in out[..nr].iter_mut().enumerate() {
            *o = self.re[j * bp + b].to_f64();
        }
        let mut col = nr;
        for j in nr..self.slots {
            out[col] = self.re[j * bp + b].to_f64();
            out[col + 1] = self.im[j * bp + b].to_f64();
            col += 2;
        }
    }

    /// Scatter a sequential state (length `N`, Q-basis layout) into lane
    /// `b` — adopting an existing per-connection streaming state.
    pub fn set_lane_state(&mut self, b: usize, s: &[f64]) {
        assert!(b < self.batch);
        assert_eq!(s.len(), self.engine.n());
        let bp = self.bpad;
        let nr = self.n_real;
        for (j, &v) in s[..nr].iter().enumerate() {
            self.re[j * bp + b] = S::from_f64(v);
        }
        let mut col = nr;
        for j in nr..self.slots {
            self.re[j * bp + b] = S::from_f64(s[col]);
            self.im[j * bp + b] = S::from_f64(s[col + 1]);
            col += 2;
        }
    }

    /// One step for ALL lanes. `u` is lane-major `[D_in × B]`:
    /// `u[d·B + b]` is input dimension `d` of lane `b`.
    #[inline]
    pub fn step(&mut self, u: &[f64]) {
        self.step_inner(u, None);
    }

    /// One step advancing only lanes with `active[b] == true`; frozen
    /// lanes keep their state bit-for-bit (neither the `Λ` rotation nor
    /// the input add is applied).
    #[inline]
    pub fn step_masked(&mut self, u: &[f64], active: &[bool]) {
        assert_eq!(active.len(), self.batch);
        self.step_inner(u, Some(active));
    }

    fn step_inner(&mut self, u: &[f64], active: Option<&[bool]>) {
        let bsz = self.batch;
        let bp = self.bpad;
        let nr = self.n_real;
        let slots = self.slots;
        let d_in = self.d_in;
        debug_assert_eq!(u.len(), d_in * bsz);
        let Self {
            re,
            im,
            u_pad,
            mask_pad,
            lam_re,
            lam_im,
            win_re,
            win_im,
            ..
        } = self;
        // stage the inputs into the padded scratch (padding stays zero)
        for d in 0..d_in {
            let dst = &mut u_pad[d * bp..d * bp + bsz];
            for (p, &v) in dst.iter_mut().zip(&u[d * bsz..(d + 1) * bsz]) {
                *p = S::from_f64(v);
            }
        }
        // stage the mask into the padded scratch (padding stays false, so
        // padding lanes select their old zeros). The masked kernels are
        // branchless — `mask ? new : old` per lane — so a loaded hub
        // vectorizes like the unmasked path; frozen lanes keep their exact
        // bits because the select keeps the stored value itself.
        if let Some(mask) = active {
            mask_pad[..bsz].copy_from_slice(mask);
        }
        if d_in == 1 {
            // fused single-input path — per lane this is exactly
            // `QBasisEsn::step`'s d_in = 1 expression, so f64 lanes are
            // bit-identical to sequential runs
            match active {
                None => {
                    for j in 0..nr {
                        kernels::fused_real(
                            &mut re[j * bp..(j + 1) * bp],
                            &u_pad[..bp],
                            lam_re[j],
                            win_re[j],
                        );
                    }
                    for j in nr..slots {
                        kernels::fused_pair(
                            &mut re[j * bp..(j + 1) * bp],
                            &mut im[j * bp..(j + 1) * bp],
                            &u_pad[..bp],
                            lam_re[j],
                            lam_im[j],
                            win_re[j],
                            win_im[j],
                        );
                    }
                }
                Some(_) => {
                    for j in 0..nr {
                        kernels::fused_real_masked(
                            &mut re[j * bp..(j + 1) * bp],
                            &u_pad[..bp],
                            &mask_pad[..bp],
                            lam_re[j],
                            win_re[j],
                        );
                    }
                    for j in nr..slots {
                        kernels::fused_pair_masked(
                            &mut re[j * bp..(j + 1) * bp],
                            &mut im[j * bp..(j + 1) * bp],
                            &u_pad[..bp],
                            &mask_pad[..bp],
                            lam_re[j],
                            lam_im[j],
                            win_re[j],
                            win_im[j],
                        );
                    }
                }
            }
            return;
        }
        // general path: Λ rotation pass, then one accumulation pass per
        // input dimension (mirrors QBasisEsn::step's general path)
        match active {
            None => {
                for j in 0..nr {
                    kernels::scale(&mut re[j * bp..(j + 1) * bp], lam_re[j]);
                }
                for j in nr..slots {
                    kernels::rot_pair(
                        &mut re[j * bp..(j + 1) * bp],
                        &mut im[j * bp..(j + 1) * bp],
                        lam_re[j],
                        lam_im[j],
                    );
                }
            }
            Some(_) => {
                for j in 0..nr {
                    kernels::scale_masked(
                        &mut re[j * bp..(j + 1) * bp],
                        &mask_pad[..bp],
                        lam_re[j],
                    );
                }
                for j in nr..slots {
                    kernels::rot_pair_masked(
                        &mut re[j * bp..(j + 1) * bp],
                        &mut im[j * bp..(j + 1) * bp],
                        &mask_pad[..bp],
                        lam_re[j],
                        lam_im[j],
                    );
                }
            }
        }
        for d in 0..d_in {
            let ud = &u_pad[d * bp..(d + 1) * bp];
            match active {
                None => {
                    for j in 0..nr {
                        kernels::axpy(
                            &mut re[j * bp..(j + 1) * bp],
                            ud,
                            win_re[d * slots + j],
                        );
                    }
                    for j in nr..slots {
                        kernels::axpy(
                            &mut re[j * bp..(j + 1) * bp],
                            ud,
                            win_re[d * slots + j],
                        );
                        kernels::axpy(
                            &mut im[j * bp..(j + 1) * bp],
                            ud,
                            win_im[d * slots + j],
                        );
                    }
                }
                Some(_) => {
                    for j in 0..nr {
                        kernels::axpy_masked(
                            &mut re[j * bp..(j + 1) * bp],
                            ud,
                            &mask_pad[..bp],
                            win_re[d * slots + j],
                        );
                    }
                    for j in nr..slots {
                        kernels::axpy_masked(
                            &mut re[j * bp..(j + 1) * bp],
                            ud,
                            &mask_pad[..bp],
                            win_re[d * slots + j],
                        );
                        kernels::axpy_masked(
                            &mut im[j * bp..(j + 1) * bp],
                            ud,
                            &mask_pad[..bp],
                            win_im[d * slots + j],
                        );
                    }
                }
            }
        }
    }

    /// Advance all lanes through a `[T × B]` input matrix (one column per
    /// lane, `D_in = 1`) without recording anything — the raw batched
    /// sweep, for benchmarking and warm-up.
    pub fn sweep(&mut self, u: &Mat) {
        assert_eq!(self.d_in, 1, "sweep requires D_in = 1");
        assert_eq!(u.cols(), self.batch);
        for t in 0..u.rows() {
            self.step(u.row(t));
        }
    }

    /// Run all lanes over a `[T × B]` input (`D_in = 1`) and materialize
    /// each lane's `[T × N]` trajectory — the equivalence-testing path;
    /// serving should use [`Self::run_readout`] instead.
    pub fn run(&mut self, u: &Mat) -> Vec<Mat> {
        assert_eq!(self.d_in, 1, "run requires D_in = 1");
        assert_eq!(u.cols(), self.batch);
        let t_len = u.rows();
        let bsz = self.batch;
        let n = self.engine.n();
        let mut outs = vec![Mat::zeros(t_len, n); bsz];
        for t in 0..t_len {
            self.step(u.row(t));
            for (b, out) in outs.iter_mut().enumerate() {
                self.lane_state(b, out.row_mut(t));
            }
        }
        outs
    }

    /// The fused batched serving path: advance all lanes over a `[T × B]`
    /// input (`D_in = 1`) and fold the readout each step. Returns
    /// `[T × (B·D_out)]` with lane-major grouping: lane `b`'s output `k`
    /// at time `t` is `y[(t, b·D_out + k)]`.
    ///
    /// The readout is downcast to `S` once per call ([`Self::run_readout_cast`]
    /// skips even that); per lane, both the step and the
    /// `bias-then-ascending-feature` accumulation order match
    /// [`QBasisEsn::run_readout`] exactly, so f64 batched serving is
    /// bit-identical to one-at-a-time serving.
    pub fn run_readout(&mut self, u: &Mat, ro: &Readout) -> Mat {
        self.run_readout_cast(u, &LaneReadout::new(ro))
    }

    /// [`Self::run_readout`] with a pre-cast readout — the allocation-free
    /// form for callers that serve many rounds with one readout.
    pub fn run_readout_cast(&mut self, u: &Mat, ro: &LaneReadout<S>) -> Mat {
        assert_eq!(self.d_in, 1, "run_readout requires D_in = 1");
        assert_eq!(u.cols(), self.batch);
        assert_eq!(ro.n, self.engine.n());
        let d_out = ro.d_out;
        let t_len = u.rows();
        let bsz = self.batch;
        let bp = self.bpad;
        let nr = self.n_real;
        let slots = self.slots;
        let w_s = &ro.w;
        let b_s = &ro.b;
        let mut y = Mat::zeros(t_len, bsz * d_out);
        // per-output-dim lane accumulators, padded like the state planes
        let mut acc = vec![S::ZERO; d_out * bp];
        for t in 0..t_len {
            self.step(u.row(t));
            for k in 0..d_out {
                acc[k * bp..(k + 1) * bp].fill(b_s[k]);
            }
            for k in 0..d_out {
                let a = &mut acc[k * bp..(k + 1) * bp];
                for j in 0..nr {
                    kernels::axpy(
                        a,
                        &self.re[j * bp..(j + 1) * bp],
                        w_s[j * d_out + k],
                    );
                }
                let mut col = nr;
                for j in nr..slots {
                    kernels::axpy(
                        a,
                        &self.re[j * bp..(j + 1) * bp],
                        w_s[col * d_out + k],
                    );
                    kernels::axpy(
                        a,
                        &self.im[j * bp..(j + 1) * bp],
                        w_s[(col + 1) * d_out + k],
                    );
                    col += 2;
                }
            }
            let yr = y.row_mut(t);
            for b in 0..bsz {
                for k in 0..d_out {
                    yr[b * d_out + k] = acc[k * bp + b].to_f64();
                }
            }
        }
        y
    }

    /// Coalesced streaming sweep (`D_in = 1`, `D_out = 1`): each request
    /// pairs a lane with its pending input slice; lengths may differ.
    /// Lanes advance together — one pass over Λ per time step — and a
    /// lane freezes (bit-exactly) once its input is exhausted; lanes with
    /// no request never move. Returns one fused-readout output vector per
    /// request, identical to stepping that lane alone.
    ///
    /// A lane must appear at most once per call (states are stateful;
    /// callers serialize per-lane requests).
    pub fn sweep_streams(
        &mut self,
        reqs: &[(usize, &[f64])],
        ro: &Readout,
    ) -> Vec<Vec<f64>> {
        self.sweep_streams_cast(reqs, &LaneReadout::new(ro))
    }

    /// [`Self::sweep_streams`] with a pre-cast readout — the
    /// allocation-free form for the per-round streaming hub.
    pub fn sweep_streams_cast(
        &mut self,
        reqs: &[(usize, &[f64])],
        ro: &LaneReadout<S>,
    ) -> Vec<Vec<f64>> {
        assert_eq!(self.d_in, 1, "sweep_streams requires D_in = 1");
        assert_eq!(ro.d_out, 1, "sweep_streams requires D_out = 1");
        assert_eq!(ro.n, self.engine.n());
        let bsz = self.batch;
        debug_assert!(
            {
                let mut seen = vec![false; bsz];
                reqs.iter().all(|&(lane, _)| {
                    let fresh = !seen[lane];
                    seen[lane] = true;
                    fresh
                })
            },
            "duplicate lane in one sweep"
        );
        let bp = self.bpad;
        let nr = self.n_real;
        let slots = self.slots;
        let w_s = &ro.w;
        let b0 = ro.b[0];
        let max_len = reqs.iter().map(|(_, s)| s.len()).max().unwrap_or(0);
        let mut outs: Vec<Vec<f64>> = reqs
            .iter()
            .map(|(_, s)| Vec::with_capacity(s.len()))
            .collect();
        let mut u = vec![0.0f64; bsz];
        let mut active = vec![false; bsz];
        for t in 0..max_len {
            for &(lane, input) in reqs {
                assert!(lane < bsz);
                active[lane] = t < input.len();
                u[lane] = if t < input.len() { input[t] } else { 0.0 };
            }
            self.step_masked(&u, &active);
            for (i, &(lane, input)) in reqs.iter().enumerate() {
                if t < input.len() {
                    // bias-first then ascending feature index: the
                    // sequential streaming path's exact accumulation order
                    let mut acc = b0;
                    for j in 0..nr {
                        acc += self.re[j * bp + lane] * w_s[j];
                    }
                    let mut col = nr;
                    for j in nr..slots {
                        acc += self.re[j * bp + lane] * w_s[col];
                        acc += self.im[j * bp + lane] * w_s[col + 1];
                        col += 2;
                    }
                    outs[i].push(acc.to_f64());
                }
            }
        }
        outs
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::reservoir::{DiagonalEsn, EsnConfig, QBasisEsn};
    use crate::rng::Pcg64;
    use crate::spectral::uniform::uniform_spectrum;

    fn qbasis(n: usize, d_in: usize, seed: u64) -> QBasisEsn {
        let config = EsnConfig::default()
            .with_n(n)
            .with_d_in(d_in)
            .with_seed(seed);
        let mut rng = Pcg64::new(seed, 150);
        let spec = uniform_spectrum(n, 0.9, &mut rng);
        let diag = DiagonalEsn::from_dpg(spec, &config, &mut rng);
        QBasisEsn::from_diagonal(&diag)
    }

    fn column(u: &Mat, b: usize) -> Mat {
        let col: Vec<f64> = (0..u.rows()).map(|t| u[(t, b)]).collect();
        Mat::from_rows(u.rows(), 1, &col)
    }

    #[test]
    fn batched_states_bit_identical_to_independent_runs() {
        let q = qbasis(30, 1, 1);
        let mut rng = Pcg64::seeded(2);
        let b = 5;
        let u = Mat::randn(40, b, &mut rng);
        let mut batch = BatchEsn::new(q.clone(), b);
        let lanes = batch.run(&u);
        for lane in 0..b {
            let single = q.run(&column(&u, lane));
            assert_eq!(
                lanes[lane].max_abs_diff(&single),
                0.0,
                "lane {lane} diverged from its sequential run"
            );
        }
    }

    #[test]
    fn plane_bytes_tracks_width_and_precision() {
        let q = qbasis(30, 1, 9);
        // one slot-block of lanes: the smallest engine
        let one = BatchEsn::new(q.clone(), 1).plane_bytes();
        assert!(one > 0);
        // same padded width ⇒ same planes ⇒ same bytes
        assert_eq!(BatchEsn::new(q.clone(), 8).plane_bytes(), one);
        // a wider engine grows only its state/scratch planes
        let wide = BatchEsn::new(q.clone(), 64).plane_bytes();
        assert!(wide > one);
        // f32 lanes halve every scalar plane, so the engine must shrink
        let one_f32 = BatchEsn::<f32>::with_precision(q, 1).plane_bytes();
        assert!(one_f32 < one);
    }

    #[test]
    fn batched_fused_readout_matches_sequential_serving() {
        let q = qbasis(24, 1, 3);
        let mut rng = Pcg64::seeded(4);
        let b = 4;
        let u = Mat::randn(30, b, &mut rng);
        let ro = Readout {
            w: Mat::randn(24, 2, &mut rng),
            b: vec![0.4, -0.2],
        };
        let mut batch = BatchEsn::new(q.clone(), b);
        let y = batch.run_readout(&u, &ro);
        for lane in 0..b {
            let want = q.run_readout(&column(&u, lane), &ro);
            for t in 0..30 {
                for k in 0..2 {
                    let got = y[(t, lane * 2 + k)];
                    assert_eq!(
                        got,
                        want[(t, k)],
                        "lane {lane} t={t} k={k}"
                    );
                }
            }
        }
    }

    #[test]
    fn masked_step_freezes_inactive_lanes() {
        let q = qbasis(16, 1, 5);
        let mut rng = Pcg64::seeded(6);
        let b = 3;
        let mut batch = BatchEsn::new(q, b);
        // drive all lanes a bit
        for _ in 0..10 {
            let u: Vec<f64> = (0..b).map(|_| {
                use crate::rng::Distributions;
                rng.normal()
            }).collect();
            batch.step(&u);
        }
        let mut frozen = vec![0.0; batch.n()];
        batch.lane_state(1, &mut frozen);
        // advance lanes 0 and 2 only
        let active = [true, false, true];
        for _ in 0..7 {
            batch.step_masked(&[0.3, 99.0, -0.1], &active);
        }
        let mut after = vec![0.0; batch.n()];
        batch.lane_state(1, &mut after);
        assert_eq!(frozen, after, "masked lane must not move");
        // and an active lane did move
        let mut moved = vec![0.0; batch.n()];
        batch.lane_state(0, &mut moved);
        assert!(moved.iter().zip(&frozen).any(|(a, b)| a != b));
    }

    #[test]
    fn sweep_streams_matches_per_lane_streaming() {
        let q = qbasis(20, 1, 7);
        let mut rng = Pcg64::seeded(8);
        let ro = Readout {
            w: Mat::randn(20, 1, &mut rng),
            b: vec![0.25],
        };
        let b = 4;
        let mut batch = BatchEsn::new(q.clone(), b);
        // uneven request lengths on lanes 0, 2, 3 (lane 1 idle)
        let in0: Vec<f64> = (0..9).map(|t| (t as f64 * 0.3).sin()).collect();
        let in2: Vec<f64> = (0..4).map(|t| (t as f64 * 0.7).cos()).collect();
        let in3: Vec<f64> = (0..13).map(|t| 0.1 * t as f64).collect();
        let outs = batch.sweep_streams(
            &[(0, &in0), (2, &in2), (3, &in3)],
            &ro,
        );
        // reference: each lane streamed alone through the fused engine
        for (input, out) in [(&in0, &outs[0]), (&in2, &outs[1]), (&in3, &outs[2])] {
            let u = Mat::from_rows(input.len(), 1, input);
            let want = q.run_readout(&u, &ro);
            assert_eq!(out.len(), input.len());
            for (t, got) in out.iter().enumerate() {
                assert_eq!(*got, want[(t, 0)], "t={t}");
            }
        }
        // lane 1 never moved
        let mut idle = vec![1.0; batch.n()];
        batch.lane_state(1, &mut idle);
        assert!(idle.iter().all(|v| *v == 0.0));
        // a SECOND round continues lane 2 from its persistent state
        let in2b: Vec<f64> = (0..6).map(|t| (t as f64 * 0.7 + 2.8).cos()).collect();
        let outs2 = batch.sweep_streams(&[(2, &in2b)], &ro);
        let full: Vec<f64> = in2.iter().chain(&in2b).copied().collect();
        let want = q.run_readout(&Mat::from_rows(full.len(), 1, &full), &ro);
        for (t, got) in outs2[0].iter().enumerate() {
            assert_eq!(*got, want[(in2.len() + t, 0)]);
        }
    }

    #[test]
    fn multi_input_general_path_close_to_sequential() {
        // d_in > 1 uses the two-pass general path; QBasisEsn skips
        // exact-zero inputs there, so equivalence is to rounding (and in
        // practice exact when no input is 0.0)
        let q = qbasis(18, 3, 9);
        let mut rng = Pcg64::seeded(10);
        let b = 3;
        let t_len = 20;
        // lane-major inputs [T][d_in × B]
        let per_lane: Vec<Mat> =
            (0..b).map(|_| Mat::randn(t_len, 3, &mut rng)).collect();
        let mut batch = BatchEsn::new(q.clone(), b);
        let mut lane_out = vec![Mat::zeros(t_len, 18); b];
        let mut u = vec![0.0; 3 * b];
        for t in 0..t_len {
            for (lane, ul) in per_lane.iter().enumerate() {
                for d in 0..3 {
                    u[d * b + lane] = ul[(t, d)];
                }
            }
            batch.step(&u);
            for (lane, out) in lane_out.iter_mut().enumerate() {
                batch.lane_state(lane, out.row_mut(t));
            }
        }
        for lane in 0..b {
            let want = q.run(&per_lane[lane]);
            let err = lane_out[lane].max_abs_diff(&want);
            assert!(err < 1e-12, "lane {lane} err={err}");
        }
    }

    #[test]
    fn reset_and_lane_state_roundtrip() {
        let q = qbasis(12, 1, 11);
        let mut batch = BatchEsn::new(q, 3);
        batch.step(&[1.0, 2.0, 3.0]);
        let mut s = vec![0.0; batch.n()];
        batch.lane_state(2, &mut s);
        assert!(s.iter().any(|v| *v != 0.0));
        batch.reset_lane(2);
        let mut z = vec![1.0; batch.n()];
        batch.lane_state(2, &mut z);
        assert!(z.iter().all(|v| *v == 0.0));
        // other lanes untouched
        let mut s0 = vec![0.0; batch.n()];
        batch.lane_state(0, &mut s0);
        assert!(s0.iter().any(|v| *v != 0.0));
        // scatter/gather roundtrip
        batch.set_lane_state(2, &s);
        let mut back = vec![0.0; batch.n()];
        batch.lane_state(2, &mut back);
        assert_eq!(back, s);
    }

    #[test]
    fn soa_lane_state_roundtrip_exact_both_precisions() {
        // the interleaved→SoA refactor is exactly where a stride bug would
        // hide: gather(lane) → scatter(other engine, other lane) → gather
        // must be bit-for-bit at BOTH precisions (f32→f64 widening is
        // exact, and re-narrowing a widened f32 is the identity)
        fn drive<S: Scalar>(e: &mut BatchEsn<S>, seed: u64) {
            use crate::rng::Distributions;
            let mut rng = Pcg64::seeded(seed);
            for _ in 0..17 {
                let u: Vec<f64> =
                    (0..e.batch()).map(|_| rng.normal()).collect();
                e.step(&u);
            }
        }
        fn roundtrip<S: Scalar, T: Scalar>(q: &QBasisEsn) {
            let n = q.n();
            let mut a = BatchEsn::<S>::with_precision(q.clone(), 5);
            drive(&mut a, 21);
            let mut got = vec![0.0; n];
            a.lane_state(3, &mut got);
            assert!(got.iter().any(|v| *v != 0.0));
            // scatter into a DIFFERENT lane of a DIFFERENT batch size
            let mut b = BatchEsn::<T>::with_precision(q.clone(), 9);
            drive(&mut b, 22); // non-zero background in every lane
            b.set_lane_state(7, &got);
            let mut back = vec![0.0; n];
            b.lane_state(7, &mut back);
            // T = S (or wider): the round-trip must be exact
            assert_eq!(back, got);
            // neighbours untouched by the scatter: still finite, and lane 0
            // unchanged vs a fresh drive
            let mut other = vec![0.0; n];
            b.lane_state(6, &mut other);
            assert!(other.iter().all(|v| v.is_finite()));
        }
        let q = qbasis(23, 1, 13); // odd N: both real slots and pairs
        roundtrip::<f64, f64>(&q);
        roundtrip::<f32, f32>(&q);
        roundtrip::<f32, f64>(&q); // widening adoption is also exact
    }

    #[test]
    fn f32_engine_tracks_f64_oracle_on_short_runs() {
        // coarse smoke check here; the real error-budget harness lives in
        // rust/tests/precision.rs
        let q = qbasis(40, 1, 15);
        let mut rng = Pcg64::seeded(16);
        let b = 4;
        let u = Mat::randn(50, b, &mut rng);
        let ro = Readout {
            w: Mat::randn(40, 1, &mut rng),
            b: vec![0.3],
        };
        let mut e64 = BatchEsn::new(q.clone(), b);
        let mut e32 = BatchEsn::<f32>::with_precision(q, b);
        let y64 = e64.run_readout(&u, &ro);
        let y32 = e32.run_readout(&u, &ro);
        let scale = y64.data().iter().fold(1.0f64, |m, x| m.max(x.abs()));
        for t in 0..50 {
            for lane in 0..b {
                let d = (y64[(t, lane)] - y32[(t, lane)]).abs();
                assert!(
                    d < 1e-3 * scale,
                    "t={t} lane={lane} d={d} scale={scale}"
                );
            }
        }
    }

    #[test]
    fn fully_active_masked_step_bit_identical_to_unmasked() {
        // the branchless select form must compute the exact unmasked
        // expression when every lane is on — at both precisions and on
        // both the fused (d_in = 1) and general (d_in > 1) paths
        fn check<S: Scalar>(d_in: usize, seed: u64) {
            use crate::rng::Distributions;
            let q = qbasis(19, d_in, seed);
            let b = 5;
            let mut masked = BatchEsn::<S>::with_precision(q.clone(), b);
            let mut plain = BatchEsn::<S>::with_precision(q, b);
            let all_on = vec![true; b];
            let mut rng = Pcg64::seeded(seed ^ 0xabc);
            for _ in 0..23 {
                let u: Vec<f64> =
                    (0..d_in * b).map(|_| rng.normal()).collect();
                masked.step_masked(&u, &all_on);
                plain.step(&u);
            }
            let (mre, mim) = masked.planes();
            let (pre, pim) = plain.planes();
            assert_eq!(mre, pre, "re planes diverged (d_in={d_in})");
            assert_eq!(mim, pim, "im planes diverged (d_in={d_in})");
        }
        check::<f64>(1, 31);
        check::<f32>(1, 32);
        check::<f64>(3, 33);
        check::<f32>(3, 34);
    }

    #[test]
    fn masked_general_path_freezes_and_advances_exactly() {
        // d_in > 1 masked path (scale/rot/axpy selects): frozen lanes are
        // bit-frozen, active lanes exactly match a solo engine
        use crate::rng::Distributions;
        let d_in = 2;
        let q = qbasis(15, d_in, 41);
        let b = 3;
        let mut batch = BatchEsn::new(q.clone(), b);
        let mut solo = BatchEsn::new(q, 1);
        let mut rng = Pcg64::seeded(42);
        // warm all lanes with shared inputs (lane-major [d × B])
        for _ in 0..5 {
            let per_lane: Vec<f64> = (0..d_in).map(|_| rng.normal()).collect();
            let mut u = vec![0.0; d_in * b];
            for d in 0..d_in {
                for lane in 0..b {
                    u[d * b + lane] = per_lane[d];
                }
            }
            batch.step(&u);
            solo.step(&per_lane);
        }
        let mut frozen = vec![0.0; batch.n()];
        batch.lane_state(1, &mut frozen);
        // advance lanes 0 and 2 only, same fresh inputs for both
        let active = [true, false, true];
        for _ in 0..9 {
            let per_lane: Vec<f64> = (0..d_in).map(|_| rng.normal()).collect();
            let mut u = vec![0.0; d_in * b];
            for d in 0..d_in {
                for lane in 0..b {
                    u[d * b + lane] = per_lane[d];
                }
            }
            batch.step_masked(&u, &active);
            solo.step(&per_lane);
        }
        let mut after = vec![0.0; batch.n()];
        batch.lane_state(1, &mut after);
        assert_eq!(frozen, after, "frozen lane moved on the general path");
        let mut moved = vec![0.0; batch.n()];
        batch.lane_state(0, &mut moved);
        let mut want = vec![0.0; batch.n()];
        solo.lane_state(0, &mut want);
        assert_eq!(moved, want, "active lane diverged from solo engine");
    }

    #[test]
    fn padding_lanes_stay_zero_and_unobservable() {
        // batch = 3 pads to a full lane block; the pad region must remain
        // exactly zero through fused, masked, and general-path steps
        let q = qbasis(14, 1, 17);
        let mut e = BatchEsn::<f32>::with_precision(q, 3);
        e.step(&[1.0, -2.0, 0.5]);
        e.step_masked(&[0.1, 0.2, 0.3], &[true, false, true]);
        let (re, im) = e.planes();
        let bpad = <f32 as Scalar>::LANES; // batch = 3 pads to one block
        assert_eq!(re.len() % bpad, 0);
        for (j, chunk) in re.chunks_exact(bpad).enumerate() {
            for (b, v) in chunk.iter().enumerate().skip(3) {
                assert_eq!(*v, 0.0, "re pad lane {b} of slot {j} moved");
            }
        }
        for (j, chunk) in im.chunks_exact(bpad).enumerate() {
            for (b, v) in chunk.iter().enumerate().skip(3) {
                assert_eq!(*v, 0.0, "im pad lane {b} of slot {j} moved");
            }
        }
    }
}
