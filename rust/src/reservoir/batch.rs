//! Batched multi-sequence engine: B independent reservoir states advanced
//! through ONE pass over Λ per step.
//!
//! The diagonal update is memory-bound: each step streams `Λ` and
//! `[W_in]_Q` past the ALU to touch `N` state words. Serving one sequence
//! at a time pays that stream once per user; serving B users pays it once
//! per *step* while the per-lane arithmetic — the inner `for b in 0..B`
//! loop over a contiguous lane block — autovectorizes across the batch.
//!
//! Layout: interleaved Q-layout `[N × B]`, lane-major — buffer position
//! `j` (Appendix-A feature order: reals first, then `(Re, Im)` pairs)
//! holds its B lanes contiguously at `state[j·B .. (j+1)·B]`. Per lane the
//! arithmetic is EXPRESSION-IDENTICAL to [`QBasisEsn::step`]'s fused
//! `d_in = 1` path, so a batched sweep is bit-identical to B independent
//! sequential runs — equivalence is exact, not approximate (tested below
//! and in `rust/tests/equivalence.rs`).
//!
//! The fused readout ([`BatchEsn::run_readout`]) folds `y = f·W_out + b`
//! into the sweep: the request path does `O(N + N·D_out)` work per step
//! per lane with zero `[T × N]` trajectory materialization. The masked
//! step ([`BatchEsn::step_masked`] / [`BatchEsn::sweep_streams`]) lets the
//! server coalesce per-connection streaming states of different lengths
//! into the same sweep: frozen lanes are skipped, active lanes advance.

use crate::linalg::Mat;
use crate::readout::Readout;

use super::QBasisEsn;

/// B independent interleaved-layout reservoir states sharing one `(Λ,
/// [W_in]_Q)` parameter set.
#[derive(Clone, Debug)]
pub struct BatchEsn {
    engine: QBasisEsn,
    batch: usize,
    /// Lane-major state: entry `(j, b)` lives at `state[j·batch + b]`.
    state: Vec<f64>,
}

impl BatchEsn {
    /// Build a `batch`-lane engine around (a clone of) `engine`'s
    /// parameters. All lanes start at the zero state.
    pub fn new(engine: QBasisEsn, batch: usize) -> Self {
        assert!(batch >= 1, "batch must be ≥ 1");
        let n = engine.n();
        Self {
            engine,
            batch,
            state: vec![0.0; n * batch],
        }
    }

    pub fn batch(&self) -> usize {
        self.batch
    }

    pub fn n(&self) -> usize {
        self.engine.n()
    }

    pub fn engine(&self) -> &QBasisEsn {
        &self.engine
    }

    /// Raw lane-major state (layout `[N × B]`, see module docs).
    pub fn states(&self) -> &[f64] {
        &self.state
    }

    /// Zero every lane.
    pub fn reset(&mut self) {
        self.state.fill(0.0);
    }

    /// Zero one lane (new connection adopting a recycled slot).
    pub fn reset_lane(&mut self, b: usize) {
        assert!(b < self.batch);
        let bsz = self.batch;
        for j in 0..self.engine.n() {
            self.state[j * bsz + b] = 0.0;
        }
    }

    /// Gather lane `b`'s state into `out` (length `N`, Q-basis feature
    /// layout — the same row [`QBasisEsn::run`] would emit).
    pub fn lane_state(&self, b: usize, out: &mut [f64]) {
        assert!(b < self.batch);
        assert_eq!(out.len(), self.engine.n());
        let bsz = self.batch;
        for (j, o) in out.iter_mut().enumerate() {
            *o = self.state[j * bsz + b];
        }
    }

    /// Scatter a sequential state (length `N`, Q-basis layout) into lane
    /// `b` — adopting an existing per-connection streaming state.
    pub fn set_lane_state(&mut self, b: usize, s: &[f64]) {
        assert!(b < self.batch);
        assert_eq!(s.len(), self.engine.n());
        let bsz = self.batch;
        for (j, &v) in s.iter().enumerate() {
            self.state[j * bsz + b] = v;
        }
    }

    /// One step for ALL lanes. `u` is lane-major `[D_in × B]`:
    /// `u[d·B + b]` is input dimension `d` of lane `b`.
    #[inline]
    pub fn step(&mut self, u: &[f64]) {
        self.step_inner(u, None);
    }

    /// One step advancing only lanes with `active[b] == true`; frozen
    /// lanes keep their state bit-for-bit (neither the `Λ` rotation nor
    /// the input add is applied).
    #[inline]
    pub fn step_masked(&mut self, u: &[f64], active: &[bool]) {
        assert_eq!(active.len(), self.batch);
        self.step_inner(u, Some(active));
    }

    fn step_inner(&mut self, u: &[f64], active: Option<&[bool]>) {
        let bsz = self.batch;
        let e = &self.engine;
        let d_in = e.d_in();
        debug_assert_eq!(u.len(), d_in * bsz);
        let nr = e.n_real;
        if d_in == 1 {
            // fused single-input path — per lane this is exactly
            // `QBasisEsn::step`'s d_in = 1 expression, so lanes are
            // bit-identical to sequential runs
            let row = e.win_q.row(0);
            // real block
            for j in 0..nr {
                let lam = e.lam_real[j];
                let w = row[j];
                let s = &mut self.state[j * bsz..(j + 1) * bsz];
                match active {
                    None => {
                        for (sb, &ub) in s.iter_mut().zip(&u[..bsz]) {
                            *sb = *sb * lam + ub * w;
                        }
                    }
                    Some(mask) => {
                        for b in 0..bsz {
                            if mask[b] {
                                s[b] = s[b] * lam + u[b] * w;
                            }
                        }
                    }
                }
            }
            // complex pairs: buffer columns (nr + 2k, nr + 2k + 1)
            let n_pairs = e.lam_cpx.len() / 2;
            for k in 0..n_pairs {
                let a = e.lam_cpx[2 * k];
                let bb = e.lam_cpx[2 * k + 1];
                let w0 = row[nr + 2 * k];
                let w1 = row[nr + 2 * k + 1];
                let base = (nr + 2 * k) * bsz;
                let (res, ims) =
                    self.state[base..base + 2 * bsz].split_at_mut(bsz);
                match active {
                    None => {
                        for b in 0..bsz {
                            let (re, im) = (res[b], ims[b]);
                            let ub = u[b];
                            res[b] = re * a - im * bb + ub * w0;
                            ims[b] = re * bb + im * a + ub * w1;
                        }
                    }
                    Some(mask) => {
                        for b in 0..bsz {
                            if mask[b] {
                                let (re, im) = (res[b], ims[b]);
                                let ub = u[b];
                                res[b] = re * a - im * bb + ub * w0;
                                ims[b] = re * bb + im * a + ub * w1;
                            }
                        }
                    }
                }
            }
            return;
        }
        // general path: Λ rotation pass, then one accumulation pass per
        // input dimension (mirrors QBasisEsn::step's general path)
        for j in 0..nr {
            let lam = e.lam_real[j];
            let s = &mut self.state[j * bsz..(j + 1) * bsz];
            for b in 0..bsz {
                if active.map_or(true, |m| m[b]) {
                    s[b] *= lam;
                }
            }
        }
        let n_pairs = e.lam_cpx.len() / 2;
        for k in 0..n_pairs {
            let a = e.lam_cpx[2 * k];
            let bb = e.lam_cpx[2 * k + 1];
            let base = (nr + 2 * k) * bsz;
            let (res, ims) = self.state[base..base + 2 * bsz].split_at_mut(bsz);
            for b in 0..bsz {
                if active.map_or(true, |m| m[b]) {
                    let (re, im) = (res[b], ims[b]);
                    res[b] = re * a - im * bb;
                    ims[b] = re * bb + im * a;
                }
            }
        }
        let n = e.n();
        for d in 0..d_in {
            let row = e.win_q.row(d);
            let ud = &u[d * bsz..(d + 1) * bsz];
            for (j, &w) in row.iter().enumerate().take(n) {
                let s = &mut self.state[j * bsz..(j + 1) * bsz];
                for b in 0..bsz {
                    if active.map_or(true, |m| m[b]) {
                        s[b] += ud[b] * w;
                    }
                }
            }
        }
    }

    /// Advance all lanes through a `[T × B]` input matrix (one column per
    /// lane, `D_in = 1`) without recording anything — the raw batched
    /// sweep, for benchmarking and warm-up.
    pub fn sweep(&mut self, u: &Mat) {
        assert_eq!(self.engine.d_in(), 1, "sweep requires D_in = 1");
        assert_eq!(u.cols(), self.batch);
        for t in 0..u.rows() {
            self.step(u.row(t));
        }
    }

    /// Run all lanes over a `[T × B]` input (`D_in = 1`) and materialize
    /// each lane's `[T × N]` trajectory — the equivalence-testing path;
    /// serving should use [`Self::run_readout`] instead.
    pub fn run(&mut self, u: &Mat) -> Vec<Mat> {
        assert_eq!(self.engine.d_in(), 1, "run requires D_in = 1");
        assert_eq!(u.cols(), self.batch);
        let t_len = u.rows();
        let bsz = self.batch;
        let n = self.engine.n();
        let mut outs = vec![Mat::zeros(t_len, n); bsz];
        for t in 0..t_len {
            self.step(u.row(t));
            for (b, out) in outs.iter_mut().enumerate() {
                let row = out.row_mut(t);
                for (j, r) in row.iter_mut().enumerate() {
                    *r = self.state[j * bsz + b];
                }
            }
        }
        outs
    }

    /// The fused batched serving path: advance all lanes over a `[T × B]`
    /// input (`D_in = 1`) and fold the readout each step. Returns
    /// `[T × (B·D_out)]` with lane-major grouping: lane `b`'s output `k`
    /// at time `t` is `y[(t, b·D_out + k)]`.
    ///
    /// Per lane, both the step and the `bias-then-ascending-j`
    /// accumulation order match [`QBasisEsn::run_readout`] exactly, so
    /// batched serving is bit-identical to one-at-a-time serving.
    pub fn run_readout(&mut self, u: &Mat, ro: &Readout) -> Mat {
        assert_eq!(self.engine.d_in(), 1, "run_readout requires D_in = 1");
        assert_eq!(u.cols(), self.batch);
        assert_eq!(ro.w.rows(), self.engine.n());
        let d_out = ro.w.cols();
        let t_len = u.rows();
        let bsz = self.batch;
        let n = self.engine.n();
        let mut y = Mat::zeros(t_len, bsz * d_out);
        for t in 0..t_len {
            self.step(u.row(t));
            let yr = y.row_mut(t);
            for k in 0..d_out {
                let bias = ro.b[k];
                for b in 0..bsz {
                    yr[b * d_out + k] = bias;
                }
            }
            for j in 0..n {
                let s = &self.state[j * bsz..(j + 1) * bsz];
                for k in 0..d_out {
                    let wjk = ro.w[(j, k)];
                    if d_out == 1 {
                        // contiguous lane accumulation (the serving case)
                        for (yb, &sb) in yr.iter_mut().zip(s) {
                            *yb += sb * wjk;
                        }
                    } else {
                        for b in 0..bsz {
                            yr[b * d_out + k] += s[b] * wjk;
                        }
                    }
                }
            }
        }
        y
    }

    /// Coalesced streaming sweep (`D_in = 1`, `D_out = 1`): each request
    /// pairs a lane with its pending input slice; lengths may differ.
    /// Lanes advance together — one pass over Λ per time step — and a
    /// lane freezes (bit-exactly) once its input is exhausted; lanes with
    /// no request never move. Returns one fused-readout output vector per
    /// request, identical to stepping that lane alone.
    ///
    /// A lane must appear at most once per call (states are stateful;
    /// callers serialize per-lane requests).
    pub fn sweep_streams(
        &mut self,
        reqs: &[(usize, &[f64])],
        ro: &Readout,
    ) -> Vec<Vec<f64>> {
        assert_eq!(self.engine.d_in(), 1, "sweep_streams requires D_in = 1");
        assert_eq!(ro.w.cols(), 1, "sweep_streams requires D_out = 1");
        assert_eq!(ro.w.rows(), self.engine.n());
        let bsz = self.batch;
        debug_assert!(
            {
                let mut seen = vec![false; bsz];
                reqs.iter().all(|&(lane, _)| {
                    let fresh = !seen[lane];
                    seen[lane] = true;
                    fresh
                })
            },
            "duplicate lane in one sweep"
        );
        let n = self.engine.n();
        let max_len = reqs.iter().map(|(_, s)| s.len()).max().unwrap_or(0);
        let mut outs: Vec<Vec<f64>> = reqs
            .iter()
            .map(|(_, s)| Vec::with_capacity(s.len()))
            .collect();
        let mut u = vec![0.0; bsz];
        let mut active = vec![false; bsz];
        for t in 0..max_len {
            for &(lane, input) in reqs {
                assert!(lane < bsz);
                active[lane] = t < input.len();
                u[lane] = if t < input.len() { input[t] } else { 0.0 };
            }
            self.step_masked(&u, &active);
            for (i, &(lane, input)) in reqs.iter().enumerate() {
                if t < input.len() {
                    // bias-first then ascending-j: the sequential
                    // streaming path's exact accumulation order
                    let mut acc = ro.b[0];
                    for j in 0..n {
                        acc += self.state[j * bsz + lane] * ro.w[(j, 0)];
                    }
                    outs[i].push(acc);
                }
            }
        }
        outs
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::reservoir::{DiagonalEsn, EsnConfig, QBasisEsn};
    use crate::rng::Pcg64;
    use crate::spectral::uniform::uniform_spectrum;

    fn qbasis(n: usize, d_in: usize, seed: u64) -> QBasisEsn {
        let config = EsnConfig::default()
            .with_n(n)
            .with_d_in(d_in)
            .with_seed(seed);
        let mut rng = Pcg64::new(seed, 150);
        let spec = uniform_spectrum(n, 0.9, &mut rng);
        let diag = DiagonalEsn::from_dpg(spec, &config, &mut rng);
        QBasisEsn::from_diagonal(&diag)
    }

    fn column(u: &Mat, b: usize) -> Mat {
        let col: Vec<f64> = (0..u.rows()).map(|t| u[(t, b)]).collect();
        Mat::from_rows(u.rows(), 1, &col)
    }

    #[test]
    fn batched_states_bit_identical_to_independent_runs() {
        let q = qbasis(30, 1, 1);
        let mut rng = Pcg64::seeded(2);
        let b = 5;
        let u = Mat::randn(40, b, &mut rng);
        let mut batch = BatchEsn::new(q.clone(), b);
        let lanes = batch.run(&u);
        for lane in 0..b {
            let single = q.run(&column(&u, lane));
            assert_eq!(
                lanes[lane].max_abs_diff(&single),
                0.0,
                "lane {lane} diverged from its sequential run"
            );
        }
    }

    #[test]
    fn batched_fused_readout_matches_sequential_serving() {
        let q = qbasis(24, 1, 3);
        let mut rng = Pcg64::seeded(4);
        let b = 4;
        let u = Mat::randn(30, b, &mut rng);
        let ro = Readout {
            w: Mat::randn(24, 2, &mut rng),
            b: vec![0.4, -0.2],
        };
        let mut batch = BatchEsn::new(q.clone(), b);
        let y = batch.run_readout(&u, &ro);
        for lane in 0..b {
            let want = q.run_readout(&column(&u, lane), &ro);
            for t in 0..30 {
                for k in 0..2 {
                    let got = y[(t, lane * 2 + k)];
                    assert_eq!(
                        got,
                        want[(t, k)],
                        "lane {lane} t={t} k={k}"
                    );
                }
            }
        }
    }

    #[test]
    fn masked_step_freezes_inactive_lanes() {
        let q = qbasis(16, 1, 5);
        let mut rng = Pcg64::seeded(6);
        let b = 3;
        let mut batch = BatchEsn::new(q, b);
        // drive all lanes a bit
        for _ in 0..10 {
            let u: Vec<f64> = (0..b).map(|_| {
                use crate::rng::Distributions;
                rng.normal()
            }).collect();
            batch.step(&u);
        }
        let mut frozen = vec![0.0; batch.n()];
        batch.lane_state(1, &mut frozen);
        // advance lanes 0 and 2 only
        let active = [true, false, true];
        for _ in 0..7 {
            batch.step_masked(&[0.3, 99.0, -0.1], &active);
        }
        let mut after = vec![0.0; batch.n()];
        batch.lane_state(1, &mut after);
        assert_eq!(frozen, after, "masked lane must not move");
        // and an active lane did move
        let mut moved = vec![0.0; batch.n()];
        batch.lane_state(0, &mut moved);
        assert!(moved.iter().zip(&frozen).any(|(a, b)| a != b));
    }

    #[test]
    fn sweep_streams_matches_per_lane_streaming() {
        let q = qbasis(20, 1, 7);
        let mut rng = Pcg64::seeded(8);
        let ro = Readout {
            w: Mat::randn(20, 1, &mut rng),
            b: vec![0.25],
        };
        let b = 4;
        let mut batch = BatchEsn::new(q.clone(), b);
        // uneven request lengths on lanes 0, 2, 3 (lane 1 idle)
        let in0: Vec<f64> = (0..9).map(|t| (t as f64 * 0.3).sin()).collect();
        let in2: Vec<f64> = (0..4).map(|t| (t as f64 * 0.7).cos()).collect();
        let in3: Vec<f64> = (0..13).map(|t| 0.1 * t as f64).collect();
        let outs = batch.sweep_streams(
            &[(0, &in0), (2, &in2), (3, &in3)],
            &ro,
        );
        // reference: each lane streamed alone through the fused engine
        for (input, out) in [(&in0, &outs[0]), (&in2, &outs[1]), (&in3, &outs[2])] {
            let u = Mat::from_rows(input.len(), 1, input);
            let want = q.run_readout(&u, &ro);
            assert_eq!(out.len(), input.len());
            for (t, got) in out.iter().enumerate() {
                assert_eq!(*got, want[(t, 0)], "t={t}");
            }
        }
        // lane 1 never moved
        let mut idle = vec![1.0; batch.n()];
        batch.lane_state(1, &mut idle);
        assert!(idle.iter().all(|v| *v == 0.0));
        // a SECOND round continues lane 2 from its persistent state
        let in2b: Vec<f64> = (0..6).map(|t| (t as f64 * 0.7 + 2.8).cos()).collect();
        let outs2 = batch.sweep_streams(&[(2, &in2b)], &ro);
        let full: Vec<f64> = in2.iter().chain(&in2b).copied().collect();
        let want = q.run_readout(&Mat::from_rows(full.len(), 1, &full), &ro);
        for (t, got) in outs2[0].iter().enumerate() {
            assert_eq!(*got, want[(in2.len() + t, 0)]);
        }
    }

    #[test]
    fn multi_input_general_path_close_to_sequential() {
        // d_in > 1 uses the two-pass general path; QBasisEsn skips
        // exact-zero inputs there, so equivalence is to rounding (and in
        // practice exact when no input is 0.0)
        let q = qbasis(18, 3, 9);
        let mut rng = Pcg64::seeded(10);
        let b = 3;
        let t_len = 20;
        // lane-major inputs [T][d_in × B]
        let per_lane: Vec<Mat> =
            (0..b).map(|_| Mat::randn(t_len, 3, &mut rng)).collect();
        let mut batch = BatchEsn::new(q.clone(), b);
        let mut lane_out = vec![Mat::zeros(t_len, 18); b];
        let mut u = vec![0.0; 3 * b];
        for t in 0..t_len {
            for (lane, ul) in per_lane.iter().enumerate() {
                for d in 0..3 {
                    u[d * b + lane] = ul[(t, d)];
                }
            }
            batch.step(&u);
            for (lane, out) in lane_out.iter_mut().enumerate() {
                batch.lane_state(lane, out.row_mut(t));
            }
        }
        for lane in 0..b {
            let want = q.run(&per_lane[lane]);
            let err = lane_out[lane].max_abs_diff(&want);
            assert!(err < 1e-12, "lane {lane} err={err}");
        }
    }

    #[test]
    fn reset_and_lane_state_roundtrip() {
        let q = qbasis(12, 1, 11);
        let mut batch = BatchEsn::new(q, 3);
        batch.step(&[1.0, 2.0, 3.0]);
        let mut s = vec![0.0; batch.n()];
        batch.lane_state(2, &mut s);
        assert!(s.iter().any(|v| *v != 0.0));
        batch.reset_lane(2);
        let mut z = vec![1.0; batch.n()];
        batch.lane_state(2, &mut z);
        assert!(z.iter().all(|v| *v == 0.0));
        // other lanes untouched
        let mut s0 = vec![0.0; batch.n()];
        batch.lane_state(0, &mut s0);
        assert!(s0.iter().any(|v| *v != 0.0));
        // scatter/gather roundtrip
        batch.set_lane_state(2, &s);
        let mut back = vec![0.0; batch.n()];
        batch.lane_state(2, &mut back);
        assert_eq!(back, s);
    }
}
