//! Appendix A — the "memory view" engine: the reservoir state lives in ONE
//! contiguous real buffer of length `N` laid out as
//!
//! ```text
//! [ x₁ … x_{n_r} | Re μ₁ Im μ₁ | Re μ₂ Im μ₂ | … ]
//! ```
//!
//! and the update walks it in place: the real block gets `x ← x·λ`, each
//! complex pair gets the 2×2 rotation-scaling `(re,im) ← (re·a − im·b,
//! re·b + im·a)` — the paper's `view(ℂ)` pointer cast expressed as slice
//! arithmetic (same memory, no copies, no gather step). The buffer IS the
//! readout feature row, so `run` writes trajectories directly.
//!
//! This is the optimized native hot path; `DiagonalEsn` (split planes +
//! gather) remains as the reference and the kernel-layout twin. The two
//! are equivalent (tested below) — the difference is memory traffic:
//! one interleaved stream instead of two planes plus a feature gather.

use crate::linalg::Mat;
use crate::readout::Readout;
use crate::spectral::Spectrum;

/// Interleaved-layout diagonal reservoir (Appendix A).
#[derive(Clone, Debug)]
pub struct QBasisEsn {
    /// Number of real-eigenvalue components (prefix of the buffer).
    /// (`pub(crate)`: shared with the batched engine in [`super::BatchEsn`].)
    pub(crate) n_real: usize,
    /// Real eigenvalues (length `n_real`).
    pub(crate) lam_real: Vec<f64>,
    /// Complex eigenvalues as interleaved `(re, im)` pairs (length `n−n_real`).
    pub(crate) lam_cpx: Vec<f64>,
    /// `[W_in]_Q` rows in buffer layout: `d_in × n` (real block then
    /// interleaved pairs) — accumulated in the real domain, as in the paper.
    pub(crate) win_q: Mat,
    n: usize,
    d_in: usize,
}

impl QBasisEsn {
    /// Build from the slot-form parts of a [`DiagonalEsn`]
    /// (`win_re/win_im`: `d_in × slots` planes of `[W_in]_P`).
    pub fn from_slot_form(spec: &Spectrum, win_re: &Mat, win_im: &Mat) -> Self {
        let n = spec.n;
        let nr = spec.n_real;
        let slots = spec.slots();
        let d_in = win_re.rows();

        let lam_real: Vec<f64> = spec.lam[..nr].iter().map(|z| z.re).collect();
        let mut lam_cpx = Vec::with_capacity(n - nr);
        for z in &spec.lam[nr..] {
            lam_cpx.push(z.re);
            lam_cpx.push(z.im);
        }
        // [W_in]_Q row layout == feature layout: real slots keep their re
        // part (im ≡ 0 for real eigenvalues), complex slots interleave.
        let mut win_q = Mat::zeros(d_in, n);
        for d in 0..d_in {
            for j in 0..nr {
                win_q[(d, j)] = win_re[(d, j)];
            }
            let mut col = nr;
            for j in nr..slots {
                win_q[(d, col)] = win_re[(d, j)];
                win_q[(d, col + 1)] = win_im[(d, j)];
                col += 2;
            }
        }
        Self {
            n_real: nr,
            lam_real,
            lam_cpx,
            win_q,
            n,
            d_in,
        }
    }

    /// Build directly from a [`super::DiagonalEsn`].
    pub fn from_diagonal(esn: &super::DiagonalEsn) -> Self {
        Self::from_slot_form(&esn.spec, &esn.win_re, &esn.win_im)
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn d_in(&self) -> usize {
        self.d_in
    }

    /// One in-place step on the interleaved buffer (Appendix A's
    /// "Reservoir Update Step"):
    ///   `[r]_Q^real ← [r]_Q^real ⊙ Λ_real`
    ///   `[r]_Q^cpx  ← [r]_Q^cpx  ⊙ Λ_cpx`   (complex view)
    ///   `[r]_Q      ← [r]_Q + u(t)·[W_in]_Q`
    #[inline]
    pub fn step(&self, state: &mut [f64], u: &[f64]) {
        debug_assert_eq!(state.len(), self.n);
        debug_assert_eq!(u.len(), self.d_in);
        if self.d_in == 1 {
            // fused single-input path: one pass over the state buffer
            // (perf pass: avoids re-streaming `state` for the input add)
            let ud = u[0];
            let row = self.win_q.row(0);
            let nr = self.n_real;
            let (real, cpx) = state.split_at_mut(nr);
            for j in 0..nr {
                real[j] = real[j] * self.lam_real[j] + ud * row[j];
            }
            let wrow = &row[nr..];
            for ((pair, lam), w) in cpx
                .chunks_exact_mut(2)
                .zip(self.lam_cpx.chunks_exact(2))
                .zip(wrow.chunks_exact(2))
            {
                let (re, im) = (pair[0], pair[1]);
                let (a, b) = (lam[0], lam[1]);
                pair[0] = re * a - im * b + ud * w[0];
                pair[1] = re * b + im * a + ud * w[1];
            }
            return;
        }
        // general path
        let (real, cpx) = state.split_at_mut(self.n_real);
        for (x, &l) in real.iter_mut().zip(&self.lam_real) {
            *x *= l;
        }
        // complex block: pairs (re, im) × pairs (a, b)
        for (pair, lam) in cpx.chunks_exact_mut(2).zip(self.lam_cpx.chunks_exact(2)) {
            let (re, im) = (pair[0], pair[1]);
            let (a, b) = (lam[0], lam[1]);
            pair[0] = re * a - im * b;
            pair[1] = re * b + im * a;
        }
        // input accumulation in the real domain
        for (d, &ud) in u.iter().enumerate() {
            if ud == 0.0 {
                continue;
            }
            let row = self.win_q.row(d);
            for j in 0..self.n {
                state[j] += ud * row[j];
            }
        }
    }

    /// Run a `[T × D_in]` sequence → `[T × N]` Q-basis features. Row `t`
    /// is literally the state buffer after step `t` (no gather).
    pub fn run(&self, u: &Mat) -> Mat {
        assert_eq!(u.cols(), self.d_in);
        let t_len = u.rows();
        let mut state = vec![0.0; self.n];
        let mut out = Mat::zeros(t_len, self.n);
        for t in 0..t_len {
            self.step(&mut state, u.row(t));
            out.row_mut(t).copy_from_slice(&state);
        }
        out
    }

    /// Free-running generative rollout (`D_in = D_out = 1`): teacher-force
    /// through `warmup`, then feed each prediction back as the next input
    /// for `horizon` steps — the closed-loop forecasting mode of ESNs
    /// (the output-feedback `W_fb` path of Eq. 1 with `W_fb = W_in·W_out`
    /// folded through the readout).
    pub fn generate(
        &self,
        warmup: &[f64],
        horizon: usize,
        w: &Mat,
        b: f64,
    ) -> Vec<f64> {
        assert_eq!(self.d_in, 1, "generative mode requires D_in = 1");
        assert_eq!(w.cols(), 1, "generative mode requires D_out = 1");
        let mut state = vec![0.0; self.n];
        let mut last = 0.0;
        for &u in warmup {
            self.step(&mut state, &[u]);
            last = b + (0..self.n).map(|j| state[j] * w[(j, 0)]).sum::<f64>();
        }
        let mut out = Vec::with_capacity(horizon);
        for _ in 0..horizon {
            out.push(last);
            self.step(&mut state, &[last]);
            last = b + (0..self.n).map(|j| state[j] * w[(j, 0)]).sum::<f64>();
        }
        out
    }

    /// Run and fold the readout on the fly (serving hot path — never
    /// materializes the trajectory): returns `[T × D_out]` predictions for
    /// `y = feat·W_out + b`, `O(N + N·D_out)` work per step.
    ///
    /// Accumulation order (bias first, then ascending `j`) is the contract
    /// shared with [`super::BatchEsn::run_readout`] and the server's
    /// streaming path, so all three produce bit-identical outputs.
    pub fn run_readout(&self, u: &Mat, ro: &Readout) -> Mat {
        assert_eq!(ro.w.rows(), self.n);
        let d_out = ro.w.cols();
        let t_len = u.rows();
        let mut state = vec![0.0; self.n];
        let mut y = Mat::zeros(t_len, d_out);
        for t in 0..t_len {
            self.step(&mut state, u.row(t));
            let yr = y.row_mut(t);
            for k in 0..d_out {
                let mut acc = ro.b[k];
                for (j, &s) in state.iter().enumerate() {
                    acc += s * ro.w[(j, k)];
                }
                yr[k] = acc;
            }
        }
        y
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::reservoir::{DiagonalEsn, EsnConfig};
    use crate::rng::Pcg64;
    use crate::spectral::uniform::uniform_spectrum;

    fn setup(n: usize, d_in: usize, seed: u64) -> (DiagonalEsn, QBasisEsn) {
        let config = EsnConfig::default()
            .with_n(n)
            .with_d_in(d_in)
            .with_seed(seed);
        let mut rng = Pcg64::new(seed, 150);
        let spec = uniform_spectrum(n, 0.9, &mut rng);
        let diag = DiagonalEsn::from_dpg(spec, &config, &mut rng);
        let q = QBasisEsn::from_diagonal(&diag);
        (diag, q)
    }

    #[test]
    fn memory_view_equals_split_plane_engine() {
        let (diag, q) = setup(30, 2, 1);
        let mut rng = Pcg64::seeded(2);
        let u = Mat::randn(50, 2, &mut rng);
        let a = diag.run(&u);
        let b = q.run(&u);
        assert!(
            a.max_abs_diff(&b) < 1e-12,
            "Appendix-A engine diverges: {}",
            a.max_abs_diff(&b)
        );
    }

    #[test]
    fn run_readout_matches_run_then_matmul() {
        let (_, q) = setup(20, 1, 3);
        let mut rng = Pcg64::seeded(4);
        let u = Mat::randn(25, 1, &mut rng);
        let ro = Readout {
            w: Mat::randn(20, 2, &mut rng),
            b: vec![0.3, -0.1],
        };
        let fused = q.run_readout(&u, &ro);
        let feats = q.run(&u);
        let mut want = feats.matmul(&ro.w);
        for t in 0..25 {
            for k in 0..2 {
                want[(t, k)] += ro.b[k];
            }
        }
        assert!(fused.max_abs_diff(&want) < 1e-12);
    }

    #[test]
    fn generative_rollout_tracks_sine() {
        // train on a pure sine, free-run: the rollout must stay close for
        // a couple of periods
        use crate::readout::{fit, Regularizer};
        let (_, q) = setup(60, 1, 7);
        let t_total = 700;
        let series: Vec<f64> =
            (0..=t_total).map(|t| (0.2 * t as f64).sin()).collect();
        let u = Mat::from_rows(t_total, 1, &series[..t_total]);
        let feats = q.run(&u);
        let x = crate::tasks::mso::slice_rows(&feats, 100..600);
        let y = Mat::from_rows(500, 1, &series[101..601]);
        let ro = fit(&x, &y, 1e-10, true, Regularizer::Identity).unwrap();
        let rollout = q.generate(&series[..600], 60, &ro.w, ro.b[0]);
        for (i, pred) in rollout.iter().enumerate() {
            let want = (0.2 * (600 + i) as f64).sin();
            assert!(
                (pred - want).abs() < 0.05,
                "step {i}: {pred} vs {want}"
            );
        }
    }

    #[test]
    fn odd_layouts_all_real_or_all_complex() {
        // all-real spectrum (n_real == n)
        use crate::num::c64;
        use crate::spectral::Spectrum;
        let spec = Spectrum::new(
            4,
            4,
            vec![
                c64::real(0.5),
                c64::real(-0.3),
                c64::real(0.9),
                c64::real(0.1),
            ],
        );
        let win_re = Mat::from_rows(1, 4, &[1.0, 2.0, 3.0, 4.0]);
        let win_im = Mat::zeros(1, 4);
        let q = QBasisEsn::from_slot_form(&spec, &win_re, &win_im);
        let mut state = vec![0.0; 4];
        q.step(&mut state, &[1.0]);
        assert_eq!(state, vec![1.0, 2.0, 3.0, 4.0]);
        q.step(&mut state, &[0.0]);
        assert_eq!(state, vec![0.5, -0.6, 2.7, 0.4]);

        // all-complex spectrum (n_real == 0)
        let spec = Spectrum::new(4, 0, vec![c64::new(0.0, 1.0), c64::new(0.5, 0.5)]);
        let win_re = Mat::from_rows(1, 2, &[1.0, 0.0]);
        let win_im = Mat::from_rows(1, 2, &[0.0, 1.0]);
        let q = QBasisEsn::from_slot_form(&spec, &win_re, &win_im);
        let mut state = vec![0.0; 4];
        q.step(&mut state, &[1.0]);
        assert_eq!(state, vec![1.0, 0.0, 0.0, 1.0]);
        // second step: pair1 (1,0)·(0,1) = (0,1); pair2 (0,1)·(0.5,0.5) = (−0.5,0.5)
        q.step(&mut state, &[0.0]);
        assert_eq!(state, vec![0.0, 1.0, -0.5, 0.5]);
    }
}
