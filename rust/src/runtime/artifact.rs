//! Artifact manifest parsing (`artifacts/manifest.json`, written by
//! `python/compile/aot.py`).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::util::json::{parse, Json};

/// One AOT-compiled graph.
#[derive(Clone, Debug, PartialEq)]
pub struct Artifact {
    /// Graph kind (`diag_states`, `ridge_stats`, …).
    pub kind: String,
    /// Concrete lowering dimensions (`T`, `slots`, `d_in`, …).
    pub dims: BTreeMap<String, usize>,
    /// File name within the artifact directory.
    pub file: String,
}

/// The manifest: all artifacts plus the interchange format tag.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub format: String,
    pub artifacts: Vec<Artifact>,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {path:?}"))?;
        Self::parse_str(&text)
    }

    pub fn parse_str(text: &str) -> Result<Self> {
        let v = parse(text)?;
        let format = v
            .get("format")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("manifest missing 'format'"))?
            .to_string();
        if format != "hlo-text" {
            anyhow::bail!("unsupported artifact format {format:?}");
        }
        let arts = v
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing 'artifacts'"))?;
        let mut artifacts = Vec::with_capacity(arts.len());
        for a in arts {
            let kind = a
                .get("kind")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("artifact missing 'kind'"))?
                .to_string();
            let file = a
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("artifact missing 'file'"))?
                .to_string();
            let mut dims = BTreeMap::new();
            if let Some(Json::Obj(m)) = a.get("dims") {
                for (k, v) in m {
                    dims.insert(
                        k.clone(),
                        v.as_usize()
                            .ok_or_else(|| anyhow!("dim {k} not a number"))?,
                    );
                }
            }
            artifacts.push(Artifact { kind, dims, file });
        }
        Ok(Self { format, artifacts })
    }

    /// Find an artifact matching kind and ALL given dims.
    pub fn find(&self, kind: &str, dims: &[(&str, usize)]) -> Option<&Artifact> {
        self.artifacts.iter().find(|a| {
            a.kind == kind
                && dims
                    .iter()
                    .all(|(k, v)| a.dims.get(*k).copied() == Some(*v))
        })
    }

    /// All artifacts of a kind (e.g. to list available shapes).
    pub fn of_kind(&self, kind: &str) -> Vec<&Artifact> {
        self.artifacts.iter().filter(|a| a.kind == kind).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format": "hlo-text",
      "artifacts": [
        {"kind": "diag_states", "dims": {"T": 1000, "d_in": 1, "slots": 100},
         "file": "diag_states__T1000_d_in1_slots100.hlo.txt"},
        {"kind": "diag_states", "dims": {"T": 32, "d_in": 2, "slots": 16},
         "file": "diag_states__T32_d_in2_slots16.hlo.txt"},
        {"kind": "ridge_stats", "dims": {"T": 300, "n_feat": 101, "d_out": 1},
         "file": "ridge_stats__T300_n_feat101_d_out1.hlo.txt"}
      ]
    }"#;

    #[test]
    fn parses_and_finds() {
        let m = Manifest::parse_str(SAMPLE).unwrap();
        assert_eq!(m.artifacts.len(), 3);
        let a = m.find("diag_states", &[("T", 1000), ("slots", 100)]).unwrap();
        assert_eq!(a.file, "diag_states__T1000_d_in1_slots100.hlo.txt");
        assert!(m.find("diag_states", &[("T", 999)]).is_none());
        assert_eq!(m.of_kind("diag_states").len(), 2);
    }

    #[test]
    fn rejects_wrong_format() {
        let bad = SAMPLE.replace("hlo-text", "proto");
        assert!(Manifest::parse_str(&bad).is_err());
    }
}
