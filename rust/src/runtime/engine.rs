//! High-level bridge: run a [`DiagonalEsn`](crate::reservoir::DiagonalEsn)
//! through the compiled `diag_states` HLO artifact (the L1/L2 stack) and
//! return the same real Q-basis feature matrix the native engine produces.
//!
//! Artifacts are lowered with a fixed slot count `S`; reservoirs whose
//! actual slot count is smaller are zero-padded (λ = 0, input weights = 0 —
//! dead slots produce identically-zero states and are dropped in the
//! feature gather). This is what lets ONE artifact serve every DPG seed of
//! a given reservoir size (each seed has a different real/complex split).

use anyhow::{anyhow, Result};

use crate::linalg::Mat;
use crate::reservoir::DiagonalEsn;

use super::{Runtime, Tensor};

/// Executes diagonal reservoirs through compiled HLO.
pub struct DiagRuntime {
    rt: Runtime,
}

impl DiagRuntime {
    pub fn new(rt: Runtime) -> Self {
        Self { rt }
    }

    pub fn open_default() -> Result<Self> {
        Ok(Self::new(Runtime::open(Runtime::default_dir())?))
    }

    pub fn runtime_mut(&mut self) -> &mut Runtime {
        &mut self.rt
    }

    /// Pick an artifact slot capacity `S ≥ slots` for the given kind/T/d_in.
    fn pick_slots(&self, kind: &str, t_len: usize, d_in: usize, slots: usize) -> Result<usize> {
        self.rt
            .manifest()
            .of_kind(kind)
            .iter()
            .filter_map(|a| {
                let s = *a.dims.get("slots")?;
                (a.dims.get("T") == Some(&t_len)
                    && a.dims.get("d_in") == Some(&d_in)
                    && s >= slots)
                    .then_some(s)
            })
            .min()
            .ok_or_else(|| {
                anyhow!(
                    "no {kind} artifact for T={t_len}, d_in={d_in}, slots≥{slots} \
                     (run `make artifacts`)"
                )
            })
    }

    /// Run the reservoir over `[T × D_in]` inputs through the compiled
    /// graph (`assoc = true` uses the Appendix-B parallel-prefix artifact).
    /// Returns `[T × N]` Q-basis features, matching
    /// [`DiagonalEsn::run`] up to f32 precision.
    pub fn run(&mut self, esn: &DiagonalEsn, u: &Mat, assoc: bool) -> Result<Mat> {
        let kind = if assoc { "diag_states_assoc" } else { "diag_states" };
        let t_len = u.rows();
        let d_in = esn.d_in;
        let slots = esn.spec.slots();
        let cap = self.pick_slots(kind, t_len, d_in, slots)?;

        // operands, zero-padded to `cap` slots
        let (lam_re, lam_im, win_re, win_im) = esn.kernel_operands();
        let mut lr = vec![0.0f64; cap];
        let mut li = vec![0.0f64; cap];
        lr[..slots].copy_from_slice(&lam_re);
        li[..slots].copy_from_slice(&lam_im);
        let mut wr = vec![0.0f64; d_in * cap];
        let mut wi = vec![0.0f64; d_in * cap];
        for d in 0..d_in {
            for j in 0..slots {
                wr[d * cap + j] = win_re[(d, j)];
                wi[d * cap + j] = win_im[(d, j)];
            }
        }
        let inputs = [
            Tensor::from_f64(vec![t_len as i64, d_in as i64], u.data()),
            Tensor::from_f64(vec![cap as i64], &lr),
            Tensor::from_f64(vec![cap as i64], &li),
            Tensor::from_f64(vec![d_in as i64, cap as i64], &wr),
            Tensor::from_f64(vec![d_in as i64, cap as i64], &wi),
        ];

        let exe = self.rt.load(kind, &[("T", t_len), ("d_in", d_in), ("slots", cap)])?;
        let outs = exe.run(&inputs)?;
        anyhow::ensure!(outs.len() == 2, "expected (s_re, s_im), got {}", outs.len());
        let (s_re, s_im) = (&outs[0], &outs[1]);

        // Q-basis gather from the padded planes
        let nr = esn.spec.n_real;
        let n = esn.n();
        let mut feats = Mat::zeros(t_len, n);
        for t in 0..t_len {
            let row = feats.row_mut(t);
            let base = t * cap;
            for j in 0..nr {
                row[j] = s_re[base + j] as f64;
            }
            let mut col = nr;
            for j in nr..slots {
                row[col] = s_re[base + j] as f64;
                row[col + 1] = s_im[base + j] as f64;
                col += 2;
            }
        }
        Ok(feats)
    }

    /// Gram statistics `(XᵀX, XᵀY)` through the compiled `ridge_stats`
    /// graph. `x: [T × F]`, `y: [T × D]` — shapes must match an artifact.
    pub fn ridge_stats(&mut self, x: &Mat, y: &Mat) -> Result<(Mat, Mat)> {
        let t_len = x.rows();
        let f = x.cols();
        let d = y.cols();
        let exe = self.rt.load(
            "ridge_stats",
            &[("T", t_len), ("n_feat", f), ("d_out", d)],
        )?;
        let inputs = [
            Tensor::from_f64(vec![t_len as i64, f as i64], x.data()),
            Tensor::from_f64(vec![t_len as i64, d as i64], y.data()),
        ];
        let outs = exe.run(&inputs)?;
        anyhow::ensure!(outs.len() == 2, "expected (XtX, XtY)");
        let xtx = Mat::from_fn(f, f, |i, j| outs[0][i * f + j] as f64);
        let xty = Mat::from_fn(f, d, |i, j| outs[1][i * d + j] as f64);
        Ok((xtx, xty))
    }

    /// Apply a readout through the compiled `readout_apply` graph.
    pub fn readout_apply(&mut self, x: &Mat, w: &Mat) -> Result<Mat> {
        let t_len = x.rows();
        let f = x.cols();
        let d = w.cols();
        let exe = self.rt.load(
            "readout_apply",
            &[("T", t_len), ("n_feat", f), ("d_out", d)],
        )?;
        let inputs = [
            Tensor::from_f64(vec![t_len as i64, f as i64], x.data()),
            Tensor::from_f64(vec![f as i64, d as i64], w.data()),
        ];
        let outs = exe.run(&inputs)?;
        anyhow::ensure!(outs.len() == 1, "expected (y,)");
        Ok(Mat::from_fn(t_len, d, |i, j| outs[0][i * d + j] as f64))
    }

    /// Run the DENSE baseline graph (`dense_states`): `[T × D_in]` inputs,
    /// explicit `W [N × N]`, `W_in [D_in × N]` → `[T × N]` states. Used by
    /// the fig2 HLO-path comparison and integration tests.
    pub fn dense_states(&mut self, u: &Mat, w: &Mat, w_in: &Mat) -> Result<Mat> {
        let t_len = u.rows();
        let d_in = u.cols();
        let n = w.rows();
        let exe = self
            .rt
            .load("dense_states", &[("T", t_len), ("d_in", d_in), ("n", n)])?;
        let inputs = [
            Tensor::from_f64(vec![t_len as i64, d_in as i64], u.data()),
            Tensor::from_f64(vec![n as i64, n as i64], w.data()),
            Tensor::from_f64(vec![d_in as i64, n as i64], w_in.data()),
        ];
        let outs = exe.run(&inputs)?;
        anyhow::ensure!(outs.len() == 1, "expected (states,)");
        Ok(Mat::from_fn(t_len, n, |i, j| outs[0][i * n + j] as f64))
    }
}
