//! PJRT runtime: load the AOT-compiled HLO-text artifacts produced by
//! `python/compile/aot.py` and execute them on the request path.
//!
//! This is the only place the `xla` crate is touched. Interchange format is
//! HLO **text** (see aot.py — the image's xla_extension 0.5.1 rejects
//! jax ≥ 0.5 serialized protos); `HloModuleProto::from_text_file` reassigns
//! instruction ids, `XlaComputation::from_proto` + `PjRtClient::compile`
//! produce a reusable executable. All artifact graphs return tuples.

mod artifact;
mod engine;

pub use artifact::{Artifact, Manifest};
pub use engine::DiagRuntime;

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

/// A loaded-and-compiled artifact cache over a PJRT CPU client.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    compiled: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Open an artifact directory (must contain `manifest.json`).
    pub fn open<P: AsRef<Path>>(dir: P) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir.join("manifest.json"))
            .with_context(|| format!("loading manifest from {dir:?}"))?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT CPU client: {e}"))?;
        Ok(Self {
            client,
            dir,
            manifest,
            compiled: HashMap::new(),
        })
    }

    /// Default artifact directory (`$CARGO_MANIFEST_DIR/artifacts` or
    /// `./artifacts`).
    pub fn default_dir() -> PathBuf {
        let cand = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if cand.exists() {
            cand
        } else {
            PathBuf::from("artifacts")
        }
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Find an artifact by kind + dims, compile it (cached), and return a
    /// handle for execution.
    pub fn load(&mut self, kind: &str, dims: &[(&str, usize)]) -> Result<Executable<'_>> {
        let art = self
            .manifest
            .find(kind, dims)
            .ok_or_else(|| anyhow!("no artifact {kind} with dims {dims:?} in manifest"))?;
        let key = art.file.clone();
        if !self.compiled.contains_key(&key) {
            let path = self.dir.join(&art.file);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow!("parsing {path:?}: {e}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {path:?}: {e}"))?;
            self.compiled.insert(key.clone(), exe);
        }
        Ok(Executable {
            exe: &self.compiled[&key],
        })
    }
}

/// A compiled computation ready to run.
pub struct Executable<'a> {
    exe: &'a xla::PjRtLoadedExecutable,
}

/// An input tensor: shape + f32 row-major data.
pub struct Tensor {
    pub dims: Vec<i64>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(dims: Vec<i64>, data: Vec<f32>) -> Self {
        let n: i64 = dims.iter().product();
        assert_eq!(n as usize, data.len(), "tensor shape/data mismatch");
        Self { dims, data }
    }

    /// From an f64 slice (the native engines are f64; the HLO graphs f32).
    pub fn from_f64(dims: Vec<i64>, data: &[f64]) -> Self {
        Self::new(dims, data.iter().map(|&x| x as f32).collect())
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let lit = xla::Literal::vec1(&self.data);
        lit.reshape(&self.dims)
            .map_err(|e| anyhow!("reshape to {:?}: {e}", self.dims))
    }
}

impl Executable<'_> {
    /// Execute with the given inputs; returns each tuple element as a flat
    /// f32 vector (row-major).
    pub fn run(&self, inputs: &[Tensor]) -> Result<Vec<Vec<f32>>> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("execute: {e}"))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e}"))?;
        let parts = out
            .to_tuple()
            .map_err(|e| anyhow!("untuple result: {e}"))?;
        parts
            .into_iter()
            .map(|lit| lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e}")))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        Runtime::default_dir()
    }

    #[test]
    fn manifest_opens_when_artifacts_built() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let rt = Runtime::open(&dir).unwrap();
        assert!(!rt.manifest().artifacts.is_empty());
    }

    #[test]
    fn tensor_shape_validation() {
        let t = Tensor::new(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.dims, vec![2, 3]);
    }

    #[test]
    #[should_panic(expected = "mismatch")]
    fn tensor_rejects_bad_shape() {
        Tensor::new(vec![2, 3], vec![0.0; 5]);
    }
}
