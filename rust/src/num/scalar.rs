//! Precision-generic scalar abstraction for the lane engines.
//!
//! The diagonalized step is memory-bound element-wise arithmetic
//! (Corollary 2): throughput is set by how many lanes fit a cache line
//! and a SIMD register, not by FLOPs. [`Scalar`] abstracts the element
//! type of the batched hot path so [`crate::reservoir::BatchEsn`] can run
//! at `f64` (the bit-exact oracle precision) or `f32` (the compiled HLO
//! kernels' precision point — 2× lanes per cache line, 2× SIMD width).
//!
//! The trait is **sealed**: exactly `f64` and `f32` implement it. Engines
//! own the precision decision at construction; all public APIs stay
//! `f64`-in / `f64`-out at the boundary (`f32 → f64` widening is exact,
//! so round-trips through a wider boundary are lossless).

mod sealed {
    pub trait Sealed {}
    impl Sealed for f64 {}
    impl Sealed for f32 {}
}

/// Element type of a lane engine: `f64` or `f32` (sealed).
///
/// `LANES` is the number of elements per 64-byte cache line — the unit
/// the chunked kernels block on, and the width lane counts are padded to
/// so inner loops have exact SIMD-friendly trip counts.
///
/// `Div` and [`Scalar::sqrt`] exist for the precision-generic SOLVE path
/// (`linalg::CholeskyPrec`, `readout::GramAcc::solve_scaled`): the lane
/// engines themselves never divide, but training end-to-end at `S` needs
/// the normal-equation factorization to run at `S` too.
pub trait Scalar:
    sealed::Sealed
    + Copy
    + Send
    + Sync
    + 'static
    + PartialEq
    + PartialOrd
    + core::fmt::Debug
    + core::fmt::Display
    + core::ops::Add<Output = Self>
    + core::ops::Sub<Output = Self>
    + core::ops::Mul<Output = Self>
    + core::ops::Div<Output = Self>
    + core::ops::AddAssign
    + core::ops::MulAssign
{
    const ZERO: Self;
    const ONE: Self;
    /// Elements per 64-byte cache line (= pad/chunk width of lane blocks).
    const LANES: usize;
    /// Display name ("f64"/"f32") for metrics and bench rows.
    const NAME: &'static str;

    /// Narrowing (f32) or identity (f64) conversion from the f64 boundary.
    fn from_f64(x: f64) -> Self;
    /// Exact widening back to the f64 boundary.
    fn to_f64(self) -> f64;
    fn abs(self) -> Self;
    fn is_finite(self) -> bool;
    /// IEEE square root at `S` (Cholesky pivots).
    fn sqrt(self) -> Self;
}

impl Scalar for f64 {
    const ZERO: Self = 0.0;
    const ONE: Self = 1.0;
    const LANES: usize = 8; // 64 B / 8 B
    const NAME: &'static str = "f64";

    #[inline(always)]
    fn from_f64(x: f64) -> Self {
        x
    }
    #[inline(always)]
    fn to_f64(self) -> f64 {
        self
    }
    #[inline(always)]
    fn abs(self) -> Self {
        f64::abs(self)
    }
    #[inline(always)]
    fn is_finite(self) -> bool {
        f64::is_finite(self)
    }
    #[inline(always)]
    fn sqrt(self) -> Self {
        f64::sqrt(self)
    }
}

impl Scalar for f32 {
    const ZERO: Self = 0.0;
    const ONE: Self = 1.0;
    const LANES: usize = 16; // 64 B / 4 B
    const NAME: &'static str = "f32";

    #[inline(always)]
    fn from_f64(x: f64) -> Self {
        x as f32
    }
    #[inline(always)]
    fn to_f64(self) -> f64 {
        self as f64
    }
    #[inline(always)]
    fn abs(self) -> Self {
        f32::abs(self)
    }
    #[inline(always)]
    fn is_finite(self) -> bool {
        f32::is_finite(self)
    }
    #[inline(always)]
    fn sqrt(self) -> Self {
        f32::sqrt(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lane_widths_fill_a_cache_line() {
        assert_eq!(<f64 as Scalar>::LANES * core::mem::size_of::<f64>(), 64);
        assert_eq!(<f32 as Scalar>::LANES * core::mem::size_of::<f32>(), 64);
    }

    #[test]
    fn f64_conversions_are_identity() {
        for x in [0.0, -1.5, 1e300, f64::MIN_POSITIVE] {
            assert_eq!(<f64 as Scalar>::from_f64(x).to_f64(), x);
        }
    }

    #[test]
    fn f32_widening_roundtrip_is_exact() {
        // narrow → widen → narrow is the identity on the narrowed value
        for x in [0.0f64, 0.1, -273.15, 1e-30] {
            let narrowed = <f32 as Scalar>::from_f64(x);
            let widened = narrowed.to_f64();
            assert_eq!(<f32 as Scalar>::from_f64(widened), narrowed);
        }
    }
}
