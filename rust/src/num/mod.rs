//! Scalar numeric types: split-free complex arithmetic ([`c64`]) and the
//! precision-generic [`Scalar`] element trait (`f64`/`f32`, sealed) that
//! the batched lane engines are generic over.

mod complex;
mod scalar;

pub use complex::c64;
pub use scalar::Scalar;
