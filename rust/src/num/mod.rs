//! Scalar numeric types: split-free complex arithmetic ([`c64`]).

mod complex;

pub use complex::c64;
