//! Complex double-precision scalar (no `num-complex` in the offline
//! registry). Field and method names follow the usual conventions so the
//! math modules read like their textbook sources.

use std::fmt;
use std::ops::{Add, AddAssign, Div, DivAssign, Mul, MulAssign, Neg, Sub, SubAssign};

/// Complex number with `f64` components.
#[allow(non_camel_case_types)]
#[derive(Clone, Copy, PartialEq, Default)]
pub struct c64 {
    pub re: f64,
    pub im: f64,
}

impl c64 {
    pub const ZERO: c64 = c64 { re: 0.0, im: 0.0 };
    pub const ONE: c64 = c64 { re: 1.0, im: 0.0 };
    pub const I: c64 = c64 { re: 0.0, im: 1.0 };

    #[inline]
    pub const fn new(re: f64, im: f64) -> Self {
        Self { re, im }
    }

    #[inline]
    pub const fn real(re: f64) -> Self {
        Self { re, im: 0.0 }
    }

    /// From polar form `m·e^{iθ}`.
    #[inline]
    pub fn from_polar(modulus: f64, angle: f64) -> Self {
        Self::new(modulus * angle.cos(), modulus * angle.sin())
    }

    #[inline]
    pub fn conj(self) -> Self {
        Self::new(self.re, -self.im)
    }

    /// Squared modulus `re² + im²`.
    #[inline]
    pub fn norm_sqr(self) -> f64 {
        self.re * self.re + self.im * self.im
    }

    /// Modulus `|z|` (hypot: overflow-safe).
    #[inline]
    pub fn abs(self) -> f64 {
        self.re.hypot(self.im)
    }

    /// Principal argument in `(-π, π]`.
    #[inline]
    pub fn arg(self) -> f64 {
        self.im.atan2(self.re)
    }

    /// Multiplicative inverse (scaled to avoid overflow for large |z|).
    #[inline]
    pub fn inv(self) -> Self {
        let d = self.norm_sqr();
        Self::new(self.re / d, -self.im / d)
    }

    #[inline]
    pub fn scale(self, s: f64) -> Self {
        Self::new(self.re * s, self.im * s)
    }

    /// Complex square root (principal branch).
    pub fn sqrt(self) -> Self {
        let m = self.abs();
        let re = ((m + self.re) * 0.5).max(0.0).sqrt();
        let im_mag = ((m - self.re) * 0.5).max(0.0).sqrt();
        Self::new(re, if self.im >= 0.0 { im_mag } else { -im_mag })
    }

    /// Integer power by repeated squaring.
    pub fn powi(self, mut k: u32) -> Self {
        let mut base = self;
        let mut acc = c64::ONE;
        while k > 0 {
            if k & 1 == 1 {
                acc *= base;
            }
            base *= base;
            k >>= 1;
        }
        acc
    }

    pub fn is_finite(self) -> bool {
        self.re.is_finite() && self.im.is_finite()
    }
}

impl fmt::Debug for c64 {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.im >= 0.0 {
            write!(f, "{:.6}+{:.6}i", self.re, self.im)
        } else {
            write!(f, "{:.6}-{:.6}i", self.re, -self.im)
        }
    }
}

impl fmt::Display for c64 {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Debug::fmt(self, f)
    }
}

impl From<f64> for c64 {
    fn from(x: f64) -> Self {
        Self::real(x)
    }
}

impl Add for c64 {
    type Output = c64;
    #[inline]
    fn add(self, o: c64) -> c64 {
        c64::new(self.re + o.re, self.im + o.im)
    }
}

impl Sub for c64 {
    type Output = c64;
    #[inline]
    fn sub(self, o: c64) -> c64 {
        c64::new(self.re - o.re, self.im - o.im)
    }
}

impl Mul for c64 {
    type Output = c64;
    #[inline]
    fn mul(self, o: c64) -> c64 {
        c64::new(
            self.re * o.re - self.im * o.im,
            self.re * o.im + self.im * o.re,
        )
    }
}

impl Div for c64 {
    type Output = c64;
    #[inline]
    fn div(self, o: c64) -> c64 {
        // Smith's algorithm: avoids overflow/underflow of naive norm_sqr.
        if o.re.abs() >= o.im.abs() {
            let r = o.im / o.re;
            let d = o.re + o.im * r;
            c64::new((self.re + self.im * r) / d, (self.im - self.re * r) / d)
        } else {
            let r = o.re / o.im;
            let d = o.re * r + o.im;
            c64::new((self.re * r + self.im) / d, (self.im * r - self.re) / d)
        }
    }
}

impl Neg for c64 {
    type Output = c64;
    #[inline]
    fn neg(self) -> c64 {
        c64::new(-self.re, -self.im)
    }
}

impl Mul<f64> for c64 {
    type Output = c64;
    #[inline]
    fn mul(self, s: f64) -> c64 {
        self.scale(s)
    }
}

impl AddAssign for c64 {
    #[inline]
    fn add_assign(&mut self, o: c64) {
        *self = *self + o;
    }
}
impl SubAssign for c64 {
    #[inline]
    fn sub_assign(&mut self, o: c64) {
        *self = *self - o;
    }
}
impl MulAssign for c64 {
    #[inline]
    fn mul_assign(&mut self, o: c64) {
        *self = *self * o;
    }
}
impl DivAssign for c64 {
    #[inline]
    fn div_assign(&mut self, o: c64) {
        *self = *self / o;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(a: c64, b: c64) -> bool {
        (a - b).abs() < 1e-12
    }

    #[test]
    fn field_identities() {
        let z = c64::new(3.0, -4.0);
        assert!(close(z * c64::ONE, z));
        assert!(close(z + c64::ZERO, z));
        assert!(close(z * z.inv(), c64::ONE));
        assert!(close(z / z, c64::ONE));
    }

    #[test]
    fn abs_and_conj() {
        let z = c64::new(3.0, 4.0);
        assert!((z.abs() - 5.0).abs() < 1e-15);
        assert!((z * z.conj()).im.abs() < 1e-15);
        assert!(((z * z.conj()).re - 25.0).abs() < 1e-12);
    }

    #[test]
    fn polar_roundtrip() {
        let z = c64::from_polar(2.0, 1.1);
        assert!((z.abs() - 2.0).abs() < 1e-12);
        assert!((z.arg() - 1.1).abs() < 1e-12);
    }

    #[test]
    fn sqrt_squares_back() {
        for &(re, im) in &[(4.0, 0.0), (-4.0, 0.0), (1.0, 1.0), (-3.0, -7.0)] {
            let z = c64::new(re, im);
            let r = z.sqrt();
            assert!(close(r * r, z), "sqrt({z:?}) = {r:?}");
        }
    }

    #[test]
    fn powi_matches_repeated_mul() {
        let z = c64::new(0.9, 0.3);
        let mut acc = c64::ONE;
        for k in 0..16u32 {
            assert!(close(z.powi(k), acc));
            acc *= z;
        }
    }

    #[test]
    fn division_extreme_magnitudes() {
        let a = c64::new(1e150, 1e150);
        let b = c64::new(1e150, -1e150);
        let q = a / b;
        assert!(q.is_finite());
        assert!(close(q * b, a));
    }
}
