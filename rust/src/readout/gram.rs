//! Streaming, precision-generic Gram accumulation — the training twin of
//! the SoA lane engines.
//!
//! [`super::GramStats::new`] is monolithic: it wants the whole `[T × F]`
//! feature matrix in memory before it can start. [`GramAcc<S>`] computes
//! the identical statistics from a STREAM of `(feature row, target row)`
//! pairs — chunks from the time-parallel scan, rows arriving one at a
//! time over a `train` wire op — without the caller ever assembling the
//! feature matrix, and at either precision of the sealed
//! [`Scalar`](crate::num::Scalar) trait (`f64` training is the exact
//! oracle; `f32` halves the accumulator traffic and doubles SIMD width,
//! matching the f32 state scan end-to-end).
//!
//! ## Exactness contract (f64)
//!
//! The Gram triangle uses the same rank-2 (two-rows-per-pass) update as
//! `GramStats::new`. Row **pairing survives chunk boundaries**: an odd
//! trailing row of one `push_rows` call is carried and paired with the
//! first row of the next call, so feeding the same rows through ANY
//! sequence of `push_row`/`push_rows` calls is **bit-identical** to one
//! monolithic `GramStats::new` over the concatenated rows (property-
//! tested here and in `rust/tests/precision.rs`).
//!
//! [`GramAcc::merge`] is the deterministic parallel reduction: it
//! flushes both sides' pending rows first (row pairing never crosses a
//! merge boundary — each merged accumulator is a self-contained row
//! stream) and element-wise adds the statistics. Merging the same
//! per-stream accumulators in the same order always produces the same
//! bits, whatever chunking built each side — which is what makes the
//! fused multi-sequence trainer
//! ([`crate::reservoir::parallel::run_parallel_batch_train`])
//! bit-reproducible against its materialize-then-`GramStats::new`
//! reference.
//!
//! ## Solving
//!
//! [`GramAcc::finish`] widens into a [`GramStats`] (exact at both
//! precisions — `S → f64` is lossless) for the legacy f64 sub-grid
//! sweep; [`GramAcc::solve_scaled`] solves the scaled ridge system
//! natively at `S` ([`CholeskyPrec`] with the same f64-widened
//! `Cholesky`/LU fallback as `GramStats::solve_scaled`), so f32 training
//! never round-trips through f64 arithmetic. At `f64`,
//! `solve_scaled` is bit-identical to `GramStats::solve_scaled` (tested).

use anyhow::{bail, Result};

use crate::linalg::{Cholesky, CholeskyPrec, Lu, Mat};
use crate::num::Scalar;

use super::{GramStats, Readout};

/// Precision-erased snapshot of a [`GramAcc`] — every accumulated
/// statistic widened to f64 (lossless for both `S = f64` and `S = f32`),
/// plus the pending unpaired carry row. This is the wire/lane-migration
/// form of a trainer: [`GramAcc::export_raw`] ∘ [`GramAcc::from_raw`]
/// round-trips the accumulator **bit-exactly** at either precision,
/// because narrowing an f64 that was widened from an `S` recovers the
/// original `S` bits.
///
/// Scratch buffers are intentionally absent — they carry no state between
/// rows.
#[derive(Clone, Debug, PartialEq)]
pub struct GramAccRaw {
    /// Feature dimension `F`.
    pub f: usize,
    /// Target dimension `D`.
    pub d: usize,
    /// `[F × F]` Gram, upper triangle populated (lower triangle zeros).
    pub g: Vec<f64>,
    /// `[F × D]` cross term `XᵀY`.
    pub b: Vec<f64>,
    /// `[F]` column sums.
    pub col_sums: Vec<f64>,
    /// `[D]` target sums.
    pub y_sums: Vec<f64>,
    /// Rows accumulated.
    pub rows: u64,
    /// Pending unpaired feature row, when one is staged (`Some` ↔ the
    /// accumulator's carry slot was full at snapshot time).
    pub carry: Option<Vec<f64>>,
}

/// Heap bytes a [`GramAcc`] with `f` features and `d` targets pins at
/// element size `elem` — the trainer-budget cost model (dominated by the
/// `F × F` Gram triangle; includes cross term, sums, carry, and scratch).
pub fn acc_cost_bytes(f: usize, d: usize, elem: usize) -> usize {
    (f * f + f * d + 3 * f + 2 * d) * elem
}

/// Streaming accumulator for the ridge normal-equation statistics
/// `XᵀX`, `XᵀY`, column/target sums, and the row count, at precision `S`.
#[derive(Clone, Debug)]
pub struct GramAcc<S: Scalar> {
    f: usize,
    d: usize,
    /// `[F × F]` Gram; only the upper triangle is accumulated (mirrored
    /// on `finish`/solve).
    g: Vec<S>,
    /// `[F × D]` cross term `XᵀY`.
    b: Vec<S>,
    col_sums: Vec<S>,
    y_sums: Vec<S>,
    t_len: usize,
    /// Pending unpaired feature row (the rank-2 update consumes rows two
    /// at a time; the carry keeps pairing aligned across chunk bounds).
    carry: Vec<S>,
    carry_full: bool,
    /// Narrowing scratch for the second row of a pair.
    row_scratch: Vec<S>,
    y_scratch: Vec<S>,
}

impl<S: Scalar> GramAcc<S> {
    /// Fresh accumulator for `f` features and `d` targets.
    pub fn new(f: usize, d: usize) -> Self {
        Self {
            f,
            d,
            g: vec![S::ZERO; f * f],
            b: vec![S::ZERO; f * d],
            col_sums: vec![S::ZERO; f],
            y_sums: vec![S::ZERO; d],
            t_len: 0,
            carry: vec![S::ZERO; f],
            carry_full: false,
            row_scratch: vec![S::ZERO; f],
            y_scratch: vec![S::ZERO; d],
        }
    }

    /// Feature dimension `F`.
    pub fn features(&self) -> usize {
        self.f
    }

    /// Target dimension `D`.
    pub fn targets(&self) -> usize {
        self.d
    }

    /// Rows accumulated so far.
    pub fn rows(&self) -> usize {
        self.t_len
    }

    /// Accumulate one `(features, targets)` row. Rows are narrowed to `S`
    /// per element at the boundary (identity at f64).
    pub fn push_row(&mut self, x: &[f64], y: &[f64]) {
        assert_eq!(x.len(), self.f, "feature row length mismatch");
        assert_eq!(y.len(), self.d, "target row length mismatch");
        let f = self.f;
        for (s, &v) in self.y_scratch.iter_mut().zip(y) {
            *s = S::from_f64(v);
        }
        // Gram triangle: rank-2 when a carry row is pending, otherwise
        // stash this row as the carry. The b / sum updates below always
        // run per row, in row order — exactly GramStats::new's order.
        if self.carry_full {
            for (s, &v) in self.row_scratch.iter_mut().zip(x) {
                *s = S::from_f64(v);
            }
            let (ra, rb) = (&self.carry, &self.row_scratch);
            for i in 0..f {
                let (xa, xb) = (ra[i], rb[i]);
                if xa == S::ZERO && xb == S::ZERO {
                    continue;
                }
                let gi = &mut self.g[i * f..(i + 1) * f];
                for j in i..f {
                    gi[j] += xa * ra[j] + xb * rb[j];
                }
            }
            self.carry_full = false;
            self.tail_row_updates(true);
        } else {
            for (s, &v) in self.carry.iter_mut().zip(x) {
                *s = S::from_f64(v);
            }
            self.carry_full = true;
            self.tail_row_updates(false);
        }
        self.t_len += 1;
    }

    /// Per-row `XᵀY` / column-sum / target-sum updates for the row most
    /// recently staged into `row_scratch` (`true`) or `carry` (`false`).
    fn tail_row_updates(&mut self, in_scratch: bool) {
        let f = self.f;
        let d = self.d;
        let row: &[S] = if in_scratch {
            &self.row_scratch
        } else {
            &self.carry
        };
        for i in 0..f {
            let xi = row[i];
            if xi == S::ZERO {
                continue;
            }
            let bi = &mut self.b[i * d..(i + 1) * d];
            for (bk, &yk) in bi.iter_mut().zip(&self.y_scratch) {
                *bk += xi * yk;
            }
        }
        for (cs, &xi) in self.col_sums.iter_mut().zip(row) {
            *cs += xi;
        }
        for (ys, &yk) in self.y_sums.iter_mut().zip(&self.y_scratch) {
            *ys += yk;
        }
    }

    /// Accumulate a `[T × F]` / `[T × D]` chunk row by row. Any chunking
    /// of the same row stream is bit-identical (the carry keeps the
    /// rank-2 pairing aligned across calls).
    pub fn push_rows(&mut self, x: &Mat, y: &Mat) {
        assert_eq!(x.rows(), y.rows(), "X/Y row mismatch");
        for t in 0..x.rows() {
            self.push_row(x.row(t), y.row(t));
        }
    }

    /// Apply the pending unpaired row to the Gram triangle (the same
    /// single-row update `GramStats::new` applies to an odd trailing
    /// row). Idempotent.
    fn flush_carry(&mut self) {
        if !self.carry_full {
            return;
        }
        let f = self.f;
        for i in 0..f {
            let xi = self.carry[i];
            if xi == S::ZERO {
                continue;
            }
            let gi = &mut self.g[i * f..(i + 1) * f];
            for j in i..f {
                gi[j] += xi * self.carry[j];
            }
        }
        self.carry_full = false;
    }

    /// Fold `other` into `self` — the deterministic parallel reduction.
    /// Both pending rows are flushed first: row pairing never crosses a
    /// merge boundary, so each merged accumulator is a self-contained row
    /// stream and the result depends only on the per-stream contents and
    /// the merge order, never on how each stream was chunked.
    pub fn merge(&mut self, mut other: Self) {
        assert_eq!(self.f, other.f, "feature dim mismatch in merge");
        assert_eq!(self.d, other.d, "target dim mismatch in merge");
        self.flush_carry();
        other.flush_carry();
        for (a, b) in self.g.iter_mut().zip(&other.g) {
            *a += *b;
        }
        for (a, b) in self.b.iter_mut().zip(&other.b) {
            *a += *b;
        }
        for (a, b) in self.col_sums.iter_mut().zip(&other.col_sums) {
            *a += *b;
        }
        for (a, b) in self.y_sums.iter_mut().zip(&other.y_sums) {
            *a += *b;
        }
        self.t_len += other.t_len;
    }

    /// Upper-triangle Gram with the pending row applied and the lower
    /// triangle mirrored — the full `[F × F]` matrix at `S`.
    fn g_full(&self) -> Vec<S> {
        let f = self.f;
        let mut g = self.g.clone();
        if self.carry_full {
            for i in 0..f {
                let xi = self.carry[i];
                if xi == S::ZERO {
                    continue;
                }
                let gi = &mut g[i * f..(i + 1) * f];
                for j in i..f {
                    gi[j] += xi * self.carry[j];
                }
            }
        }
        for i in 0..f {
            for j in 0..i {
                g[i * f + j] = g[j * f + i];
            }
        }
        g
    }

    /// Widen into a [`GramStats`] (exact: `S → f64` is lossless), for the
    /// legacy f64 `(input-scaling × α)` sub-grid sweep. Non-consuming —
    /// a serving-path trainer keeps accumulating after a snapshot.
    pub fn finish(&self) -> GramStats {
        let f = self.f;
        let d = self.d;
        let g_full = self.g_full();
        let mut g = Mat::zeros(f, f);
        for (dst, &v) in g.data_mut().iter_mut().zip(&g_full) {
            *dst = v.to_f64();
        }
        let mut b = Mat::zeros(f, d);
        for (dst, &v) in b.data_mut().iter_mut().zip(&self.b) {
            *dst = v.to_f64();
        }
        GramStats {
            g,
            b,
            col_sums: self.col_sums.iter().map(|v| v.to_f64()).collect(),
            y_sums: self.y_sums.iter().map(|v| v.to_f64()).collect(),
            t_len: self.t_len,
        }
    }

    /// Snapshot every accumulated statistic into the precision-erased
    /// [`GramAccRaw`] wire form. Non-consuming; `S → f64` widening is
    /// exact at both precisions, so `from_raw(export_raw())` is the
    /// bit-identity.
    pub fn export_raw(&self) -> GramAccRaw {
        GramAccRaw {
            f: self.f,
            d: self.d,
            g: self.g.iter().map(|v| v.to_f64()).collect(),
            b: self.b.iter().map(|v| v.to_f64()).collect(),
            col_sums: self.col_sums.iter().map(|v| v.to_f64()).collect(),
            y_sums: self.y_sums.iter().map(|v| v.to_f64()).collect(),
            rows: self.t_len as u64,
            carry: if self.carry_full {
                Some(self.carry.iter().map(|v| v.to_f64()).collect())
            } else {
                None
            },
        }
    }

    /// Rebuild an accumulator from its [`GramAccRaw`] snapshot. Values
    /// are narrowed to `S` per element — exact when the snapshot came
    /// from a `GramAcc<S>` of the same precision (the restore path), so
    /// the rebuilt trainer continues bit-identically to the original.
    /// Fails on dimension/length mismatches or non-finite input (a
    /// corrupt snapshot must never poison the sweeper).
    pub fn from_raw(raw: &GramAccRaw) -> Result<Self> {
        let (f, d) = (raw.f, raw.d);
        if raw.g.len() != f * f
            || raw.b.len() != f * d
            || raw.col_sums.len() != f
            || raw.y_sums.len() != d
            || raw.carry.as_ref().is_some_and(|c| c.len() != f)
        {
            bail!("trainer snapshot has inconsistent dimensions");
        }
        let mut all = raw
            .g
            .iter()
            .chain(&raw.b)
            .chain(&raw.col_sums)
            .chain(&raw.y_sums)
            .chain(raw.carry.iter().flatten());
        if all.any(|v| !v.is_finite()) {
            bail!("trainer snapshot contains non-finite values");
        }
        let narrow = |src: &[f64]| -> Vec<S> {
            src.iter().map(|&v| S::from_f64(v)).collect()
        };
        let mut acc = Self::new(f, d);
        acc.g = narrow(&raw.g);
        acc.b = narrow(&raw.b);
        acc.col_sums = narrow(&raw.col_sums);
        acc.y_sums = narrow(&raw.y_sums);
        acc.t_len = raw.rows as usize;
        if let Some(c) = &raw.carry {
            acc.carry = narrow(c);
            acc.carry_full = true;
        }
        Ok(acc)
    }

    /// Solve the ridge system for features scaled by `s`, with bias and
    /// plain `α·I` regularization, natively at `S` — the precision-true
    /// twin of [`GramStats::solve_scaled`] (bit-identical to it at f64).
    /// The returned [`Readout`] is f64 at the boundary (exact widening).
    ///
    /// Fallback: if the `S` Cholesky hits a non-positive pivot, the
    /// system is widened to f64 and retried through Cholesky then LU —
    /// the same ladder `GramStats::solve_scaled` uses.
    pub fn solve_scaled(&self, alpha: f64, s: f64) -> Result<Readout> {
        let f = self.f;
        let d = self.d;
        let ext = f + 1;
        let g_base = self.g_full();
        let s_s = S::from_f64(s);
        let alpha_s = S::from_f64(alpha);
        let s2 = s_s * s_s;
        let mut g = vec![S::ZERO; ext * ext];
        for i in 0..f {
            for j in 0..f {
                g[i * ext + j] = s2 * g_base[i * f + j];
            }
            g[i * ext + f] = s_s * self.col_sums[i];
            g[f * ext + i] = s_s * self.col_sums[i];
            g[i * ext + i] += alpha_s;
        }
        g[f * ext + f] = S::from_f64(self.t_len as f64 + alpha);
        let mut rhs = vec![S::ZERO; ext * d];
        for i in 0..f {
            for k in 0..d {
                rhs[i * d + k] = s_s * self.b[i * d + k];
            }
        }
        for k in 0..d {
            rhs[f * d + k] = self.y_sums[k];
        }

        let sol: Vec<f64> = match CholeskyPrec::<S>::factor_slice(&g, ext) {
            Ok(ch) => ch
                .solve_mat_slice(&rhs, d)
                .iter()
                .map(|v| v.to_f64())
                .collect(),
            Err(_) => {
                // widen and retry through the f64 ladder (identity at
                // S = f64, so this is exactly GramStats::solve_scaled's
                // Cholesky-then-LU fallback)
                let g64: Vec<f64> = g.iter().map(|v| v.to_f64()).collect();
                let rhs64: Vec<f64> = rhs.iter().map(|v| v.to_f64()).collect();
                let gm = Mat::from_rows(ext, ext, &g64);
                let rm = Mat::from_rows(ext, d, &rhs64);
                let out = match Cholesky::factor(&gm) {
                    Ok(ch) => ch.solve_mat(&rm),
                    Err(_) => Lu::factor(&gm).solve_mat(&rm)?,
                };
                out.data().to_vec()
            }
        };
        let mut w = Mat::zeros(f, d);
        for i in 0..f {
            for k in 0..d {
                w[(i, k)] = sol[i * d + k];
            }
        }
        Ok(Readout {
            w,
            b: (0..d).map(|k| sol[f * d + k]).collect(),
        })
    }
}

/// Plain-ridge fit with bias at precision `S` — `fit(x, y, α, bias=true,
/// Identity)`'s precision-generic twin, built on the streaming
/// accumulator (one `push_rows`, one native-`S` solve).
pub fn fit_prec<S: Scalar>(x: &Mat, y: &Mat, alpha: f64) -> Result<Readout> {
    let mut acc = GramAcc::<S>::new(x.cols(), y.cols());
    acc.push_rows(x, y);
    acc.solve_scaled(alpha, 1.0)
}

#[cfg(test)]
mod tests {
    use super::super::{fit, GramStats, Regularizer};
    use super::*;
    use crate::rng::Pcg64;

    fn problem(t_len: usize, f: usize, d: usize, seed: u64) -> (Mat, Mat) {
        let mut rng = Pcg64::seeded(seed);
        let x = Mat::randn(t_len, f, &mut rng);
        let w_true = Mat::randn(f, d, &mut rng);
        let y = x.matmul(&w_true);
        (x, y)
    }

    fn slice_rows(m: &Mat, lo: usize, hi: usize) -> Mat {
        let mut out = Mat::zeros(hi - lo, m.cols());
        for (r, t) in (lo..hi).enumerate() {
            out.row_mut(r).copy_from_slice(m.row(t));
        }
        out
    }

    /// Compare every private statistic bit-for-bit (child module of
    /// `readout`, so `GramStats` fields are visible).
    fn assert_stats_bit_identical(a: &GramStats, b: &GramStats) {
        assert_eq!(a.t_len, b.t_len);
        assert_eq!(a.g.data(), b.g.data(), "Gram matrices differ");
        assert_eq!(a.b.data(), b.b.data(), "XᵀY differs");
        assert_eq!(a.col_sums, b.col_sums, "column sums differ");
        assert_eq!(a.y_sums, b.y_sums, "target sums differ");
    }

    #[test]
    fn chunked_pushes_bit_identical_to_monolithic_gram_stats() {
        // odd total length AND odd chunk boundaries: the carry must keep
        // the rank-2 pairing aligned across every cut
        let (x, y) = problem(157, 9, 2, 1);
        let want = GramStats::new(&x, &y);
        for cuts in [
            vec![157],
            vec![1, 156],
            vec![3, 5, 149],
            vec![80, 77],
            vec![], // fully row-by-row via the remainder loop
        ] {
            let mut acc = GramAcc::<f64>::new(9, 2);
            let mut lo = 0;
            for &len in &cuts {
                acc.push_rows(&slice_rows(&x, lo, lo + len), &slice_rows(&y, lo, lo + len));
                lo += len;
            }
            // any remainder row by row (exercises push_row directly)
            for t in lo..157 {
                acc.push_row(x.row(t), y.row(t));
            }
            assert_stats_bit_identical(&acc.finish(), &want);
        }
    }

    #[test]
    fn merge_is_chunking_invariant_and_deterministic() {
        // two halves, each built with DIFFERENT chunkings, merged in the
        // same order → identical bits
        let (x, y) = problem(121, 7, 1, 2);
        let split = 59; // odd split: both halves carry odd rows
        let build = |lo: usize, hi: usize, step: usize| {
            let mut acc = GramAcc::<f64>::new(7, 1);
            let mut t = lo;
            while t < hi {
                let e = (t + step).min(hi);
                acc.push_rows(&slice_rows(&x, t, e), &slice_rows(&y, t, e));
                t = e;
            }
            acc
        };
        let mut a1 = build(0, split, 13);
        a1.merge(build(split, 121, 7));
        let mut a2 = build(0, split, split);
        a2.merge(build(split, 121, 121 - split));
        assert_stats_bit_identical(&a1.finish(), &a2.finish());
        // and the merged row count is the total
        assert_eq!(a1.rows(), 121);
    }

    #[test]
    fn f64_solve_scaled_bit_identical_to_gram_stats_solve() {
        let (x, y) = problem(140, 8, 2, 3);
        let stats = GramStats::new(&x, &y);
        let mut acc = GramAcc::<f64>::new(8, 2);
        acc.push_rows(&x, &y);
        for (alpha, s) in [(1e-8, 1.0), (0.5, 0.01), (1e-3, 3.0)] {
            let a = stats.solve_scaled(alpha, s).unwrap();
            let b = acc.solve_scaled(alpha, s).unwrap();
            assert_eq!(a.w.data(), b.w.data(), "alpha={alpha} s={s}");
            assert_eq!(a.b, b.b, "alpha={alpha} s={s}");
        }
    }

    #[test]
    fn finish_then_gram_stats_solve_matches_direct_fit() {
        let (x, y) = problem(150, 6, 1, 4);
        let mut acc = GramAcc::<f64>::new(6, 1);
        acc.push_rows(&x, &y);
        let via_acc = acc.finish().solve_scaled(0.01, 1.0).unwrap();
        let direct = fit(&x, &y, 0.01, true, Regularizer::Identity).unwrap();
        assert!(via_acc.w.max_abs_diff(&direct.w) < 1e-8);
        assert!((via_acc.b[0] - direct.b[0]).abs() < 1e-8);
    }

    #[test]
    fn fit_prec_f32_close_to_f64_fit_on_benign_problem() {
        let (x, y) = problem(200, 10, 1, 5);
        let a = fit_prec::<f64>(&x, &y, 1e-2).unwrap();
        let b = fit_prec::<f32>(&x, &y, 1e-2).unwrap();
        let scale = a.w.data().iter().fold(1.0f64, |m, v| m.max(v.abs()));
        assert!(
            a.w.max_abs_diff(&b.w) < 1e-2 * scale,
            "f32 fit drifted: {}",
            a.w.max_abs_diff(&b.w)
        );
        // and the f32 path genuinely ran at f32
        assert!(a.w.max_abs_diff(&b.w) > 0.0, "f32 fit suspiciously exact");
    }

    #[test]
    fn export_import_round_trips_bit_exactly_and_continues_identically() {
        // both precisions, both carry parities: the restored trainer must
        // hold identical bits AND keep producing identical bits when fed
        // the remaining rows — the checkpoint/restore failover contract
        fn check<S: Scalar>(rows_before: usize) {
            let (x, y) = problem(90, 6, 1, 7);
            let mut acc = GramAcc::<S>::new(6, 1);
            for t in 0..rows_before {
                acc.push_row(x.row(t), y.row(t));
            }
            let raw = acc.export_raw();
            assert_eq!(raw.rows, rows_before as u64);
            assert_eq!(raw.carry.is_some(), rows_before % 2 == 1);
            let mut restored = GramAcc::<S>::from_raw(&raw).unwrap();
            // identical bits now…
            assert_eq!(restored.export_raw(), raw);
            // …and identical bits after both keep accumulating
            for t in rows_before..90 {
                acc.push_row(x.row(t), y.row(t));
                restored.push_row(x.row(t), y.row(t));
            }
            assert_eq!(acc.export_raw(), restored.export_raw());
            let a = acc.solve_scaled(1e-6, 1.0).unwrap();
            let b = restored.solve_scaled(1e-6, 1.0).unwrap();
            assert_eq!(a.w.data(), b.w.data());
            assert_eq!(a.b, b.b);
        }
        check::<f64>(40); // even: no carry pending
        check::<f64>(41); // odd: carry row crosses the snapshot
        check::<f32>(40);
        check::<f32>(41);
    }

    #[test]
    fn from_raw_rejects_corrupt_snapshots() {
        let mut acc = GramAcc::<f64>::new(4, 1);
        acc.push_row(&[1.0, 2.0, 3.0, 4.0], &[0.5]);
        let good = acc.export_raw();
        let mut bad = good.clone();
        bad.g.pop();
        assert!(GramAcc::<f64>::from_raw(&bad).is_err());
        let mut bad = good.clone();
        bad.col_sums[0] = f64::NAN;
        assert!(GramAcc::<f64>::from_raw(&bad).is_err());
        let mut bad = good.clone();
        bad.carry = Some(vec![0.0; 3]); // wrong carry length
        assert!(GramAcc::<f64>::from_raw(&bad).is_err());
        assert!(GramAcc::<f64>::from_raw(&good).is_ok());
    }

    #[test]
    fn acc_cost_bytes_matches_allocation_shape() {
        // the budget model must count every buffer `new` allocates
        let (f, d) = (30, 1);
        let elems = f * f + f * d + 3 * f + 2 * d;
        assert_eq!(acc_cost_bytes(f, d, 8), elems * 8);
        assert!(acc_cost_bytes(f, d, 4) < acc_cost_bytes(f, d, 8));
    }

    #[test]
    fn snapshot_keeps_accumulating_after_finish() {
        // the serving-path contract: commit (a solve) must not stop the
        // online trainer — finish/solve are non-consuming snapshots
        let (x, y) = problem(60, 5, 1, 6);
        let mut acc = GramAcc::<f64>::new(5, 1);
        acc.push_rows(&slice_rows(&x, 0, 31), &slice_rows(&y, 0, 31));
        let early = acc.finish();
        assert_eq!(early.t_len, 31);
        acc.push_rows(&slice_rows(&x, 31, 60), &slice_rows(&y, 31, 60));
        let full_stream = acc.finish();
        assert_stats_bit_identical(&full_stream, &GramStats::new(&x, &y));
    }
}
