//! Readout training: ridge regression (Eq. 9) and the generalized Tikhonov
//! form of Theorem 1 (iv) (Eq. 14 / Appendix A Eq. 29) that makes training
//! *in the eigenbasis* exactly equivalent to training in the original one.
//!
//! `fit` solves `(XᵀX + α·R)·W = XᵀY` with `R = I` (plain ridge) or
//! `R = diag(I_bias, QᵀQ)` (generalized). Cholesky first, LU fallback
//! (`R` can be near-semidefinite when the eigenbasis degenerates).

pub mod gram;
pub mod poly;

pub use gram::{acc_cost_bytes, fit_prec, GramAcc, GramAccRaw};

use anyhow::Result;

use crate::linalg::{Cholesky, Lu, Mat};

/// Regularizer choice for the feature block.
pub enum Regularizer<'a> {
    /// `α·I` — plain ridge (Eq. 9) / DPG default.
    Identity,
    /// `α·M` with `M = QᵀQ` (or `PᵀP`) — Theorem 1 (iv): ridge in the
    /// transformed basis equivalent to plain ridge in the original basis.
    Generalized(&'a Mat),
}

/// Trained readout: `y = x·w + b`.
#[derive(Clone, Debug)]
pub struct Readout {
    /// `[F × D_out]` weights over the feature block.
    pub w: Mat,
    /// `[D_out]` bias (zero when fitted without bias).
    pub b: Vec<f64>,
}

impl Readout {
    /// Apply to ONE feature row for output `k`: bias first, then
    /// ascending feature index — THE shared fused accumulation contract
    /// (every fused serving path and the server's streaming fallbacks
    /// accumulate in exactly this order, which is what makes them
    /// bit-identical to each other; see DESIGN.md §5).
    #[inline]
    pub fn apply_row(&self, feat: &[f64], k: usize) -> f64 {
        let mut y = self.b[k];
        for (j, &f) in feat.iter().enumerate() {
            y += f * self.w[(j, k)];
        }
        y
    }

    /// Apply to `[T × F]` features → `[T × D_out]` predictions.
    pub fn predict(&self, x: &Mat) -> Mat {
        let mut y = x.matmul(&self.w);
        if self.b.iter().any(|v| *v != 0.0) {
            for t in 0..y.rows() {
                for (d, &bd) in self.b.iter().enumerate() {
                    y[(t, d)] += bd;
                }
            }
        }
        y
    }
}

/// Ridge fit over features `x [T × F]` and targets `y [T × D]`.
///
/// With `bias = true` the model is `y = x·w + b`; the bias column is
/// regularized with plain `α` exactly as in Eq. 9 (the paper's `X(t)`
/// carries an explicit constant-1 feature).
pub fn fit(
    x: &Mat,
    y: &Mat,
    alpha: f64,
    bias: bool,
    reg: Regularizer<'_>,
) -> Result<Readout> {
    assert_eq!(x.rows(), y.rows(), "X/Y row mismatch");
    let t_len = x.rows();
    let f = x.cols();
    let d = y.cols();
    let ext = if bias { f + 1 } else { f };

    // G = X'ᵀX' (with the bias column folded analytically: sums)
    let mut g = Mat::zeros(ext, ext);
    let mut b = Mat::zeros(ext, d);

    // feature block XᵀX — the O(T·F²) hot spot (syrk-style, upper then
    // mirrored)
    for t in 0..t_len {
        let row = x.row(t);
        for i in 0..f {
            let xi = row[i];
            if xi == 0.0 {
                continue;
            }
            let gi = g.row_mut(i);
            for j in i..f {
                gi[j] += xi * row[j];
            }
        }
    }
    for i in 0..f {
        for j in 0..i {
            g[(i, j)] = g[(j, i)];
        }
    }
    // XᵀY
    for t in 0..t_len {
        let row = x.row(t);
        let yrow = y.row(t);
        for i in 0..f {
            let xi = row[i];
            if xi == 0.0 {
                continue;
            }
            for k in 0..d {
                b[(i, k)] += xi * yrow[k];
            }
        }
    }
    if bias {
        // bias column: sums of features, sums of targets, count
        for i in 0..f {
            let mut s = 0.0;
            for t in 0..t_len {
                s += x[(t, i)];
            }
            g[(i, f)] = s;
            g[(f, i)] = s;
        }
        g[(f, f)] = t_len as f64;
        for k in 0..d {
            let mut s = 0.0;
            for t in 0..t_len {
                s += y[(t, k)];
            }
            b[(f, k)] = s;
        }
    }

    // regularization
    match reg {
        Regularizer::Identity => {
            for i in 0..ext {
                g[(i, i)] += alpha;
            }
        }
        Regularizer::Generalized(m) => {
            assert_eq!(m.rows(), f, "Tikhonov matrix must match feature dim");
            for i in 0..f {
                for j in 0..f {
                    g[(i, j)] += alpha * m[(i, j)];
                }
            }
            if bias {
                g[(f, f)] += alpha;
            }
        }
    }

    // solve
    let sol = match Cholesky::factor(&g) {
        Ok(ch) => ch.solve_mat(&b),
        Err(_) => Lu::factor(&g).solve_mat(&b)?,
    };

    let mut w = Mat::zeros(f, d);
    for i in 0..f {
        for k in 0..d {
            w[(i, k)] = sol[(i, k)];
        }
    }
    let bvec = if bias {
        (0..d).map(|k| sol[(f, k)]).collect()
    } else {
        vec![0.0; d]
    };
    Ok(Readout { w, b: bvec })
}

/// Precomputed Gram statistics for sweep reuse (the paper's §5.1 trick:
/// states — and therefore `XᵀX`, `XᵀY` — are computed once per reservoir
/// and re-used across the whole (input-scaling × α) sub-grid).
///
/// [`GramStats::new`] is the monolithic materialize-first constructor;
/// the streaming, precision-generic twin is [`gram::GramAcc`] (chunked
/// push + parallel merge, bit-identical to this constructor at f64 —
/// the fused training scan and the online `train` wire op build their
/// statistics through it without ever assembling `[T × F]`).
///
/// For a feature scaling `s` (D_in = 1 linearity: `X(s·W_in) = s·X(W_in)`),
/// the scaled normal equations follow in closed form:
/// `G_ff → s²·G_ff`, `G_f,bias → s·G_f,bias`, `b_f → s·b_f`.
pub struct GramStats {
    /// Unscaled feature Gram `XᵀX` `[F × F]`.
    g: Mat,
    /// Unscaled `XᵀY` `[F × D]`.
    b: Mat,
    /// Column sums of X `[F]` (bias coupling).
    col_sums: Vec<f64>,
    /// Target sums `[D]`.
    y_sums: Vec<f64>,
    t_len: usize,
}

impl GramStats {
    /// Accumulate from `x [T × F]`, `y [T × D]`. The Gram triangle uses a
    /// rank-2 update (two time rows per pass) — halves the `G` write
    /// traffic on the grid-search hot path (perf pass, EXPERIMENTS.md
    /// §Perf).
    pub fn new(x: &Mat, y: &Mat) -> Self {
        assert_eq!(x.rows(), y.rows());
        let t_len = x.rows();
        let f = x.cols();
        let d = y.cols();
        let mut g = Mat::zeros(f, f);
        let mut b = Mat::zeros(f, d);
        let mut t = 0;
        while t + 2 <= t_len {
            // disjoint row borrows for the rank-2 update
            let (head, tail) = x.data().split_at((t + 1) * f);
            let ra = &head[t * f..];
            let rb = &tail[..f];
            for i in 0..f {
                let (xa, xb) = (ra[i], rb[i]);
                if xa == 0.0 && xb == 0.0 {
                    continue;
                }
                let gi = g.row_mut(i);
                for j in i..f {
                    gi[j] += xa * ra[j] + xb * rb[j];
                }
            }
            t += 2;
        }
        if t < t_len {
            let row = x.row(t);
            for i in 0..f {
                let xi = row[i];
                if xi == 0.0 {
                    continue;
                }
                let gi = g.row_mut(i);
                for j in i..f {
                    gi[j] += xi * row[j];
                }
            }
        }
        for t in 0..t_len {
            let row = x.row(t);
            let yrow = y.row(t);
            for i in 0..f {
                let xi = row[i];
                if xi == 0.0 {
                    continue;
                }
                for k in 0..d {
                    b[(i, k)] += xi * yrow[k];
                }
            }
        }
        for i in 0..f {
            for j in 0..i {
                g[(i, j)] = g[(j, i)];
            }
        }
        let col_sums = (0..f)
            .map(|i| (0..t_len).map(|t| x[(t, i)]).sum())
            .collect();
        let y_sums = (0..d)
            .map(|k| (0..t_len).map(|t| y[(t, k)]).sum())
            .collect();
        Self {
            g,
            b,
            col_sums,
            y_sums,
            t_len,
        }
    }

    /// Solve the ridge system for features scaled by `s`, with bias,
    /// plain `α·I` regularization. Returns a readout valid for `s·X`.
    pub fn solve_scaled(&self, alpha: f64, s: f64) -> Result<Readout> {
        let f = self.g.rows();
        let d = self.b.cols();
        let ext = f + 1;
        let s2 = s * s;
        let mut g = Mat::zeros(ext, ext);
        for i in 0..f {
            for j in 0..f {
                g[(i, j)] = s2 * self.g[(i, j)];
            }
            g[(i, f)] = s * self.col_sums[i];
            g[(f, i)] = s * self.col_sums[i];
            g[(i, i)] += alpha;
        }
        g[(f, f)] = self.t_len as f64 + alpha;
        let mut rhs = Mat::zeros(ext, d);
        for i in 0..f {
            for k in 0..d {
                rhs[(i, k)] = s * self.b[(i, k)];
            }
        }
        for k in 0..d {
            rhs[(f, k)] = self.y_sums[k];
        }
        let sol = match Cholesky::factor(&g) {
            Ok(ch) => ch.solve_mat(&rhs),
            Err(_) => Lu::factor(&g).solve_mat(&rhs)?,
        };
        let mut w = Mat::zeros(f, d);
        for i in 0..f {
            for k in 0..d {
                w[(i, k)] = sol[(i, k)];
            }
        }
        Ok(Readout {
            w,
            b: (0..d).map(|k| sol[(f, k)]).collect(),
        })
    }
}

/// Predict with features scaled by `s` without materializing `s·X`:
/// `y = s·(X·w) + b`.
pub fn predict_scaled(readout: &Readout, x: &Mat, s: f64) -> Mat {
    let mut y = x.matmul(&readout.w);
    for t in 0..y.rows() {
        for (d, &bd) in readout.b.iter().enumerate() {
            let v = y[(t, d)];
            y[(t, d)] = s * v + bd;
        }
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Distributions, Pcg64};

    fn make_linear_problem(
        t_len: usize,
        f: usize,
        d: usize,
        noise: f64,
        seed: u64,
    ) -> (Mat, Mat, Mat) {
        let mut rng = Pcg64::seeded(seed);
        let x = Mat::randn(t_len, f, &mut rng);
        let w_true = Mat::randn(f, d, &mut rng);
        let mut y = x.matmul(&w_true);
        for t in 0..t_len {
            for k in 0..d {
                y[(t, k)] += noise * rng.normal();
            }
        }
        (x, y, w_true)
    }

    #[test]
    fn recovers_true_weights_at_tiny_alpha() {
        let (x, y, w_true) = make_linear_problem(400, 10, 2, 0.0, 1);
        let r = fit(&x, &y, 1e-12, false, Regularizer::Identity).unwrap();
        assert!(r.w.max_abs_diff(&w_true) < 1e-6);
    }

    #[test]
    fn bias_recovered() {
        let (x, mut y, _) = make_linear_problem(300, 6, 1, 0.0, 2);
        for t in 0..300 {
            y[(t, 0)] += 3.5;
        }
        let r = fit(&x, &y, 1e-10, true, Regularizer::Identity).unwrap();
        assert!((r.b[0] - 3.5).abs() < 1e-6, "bias={}", r.b[0]);
    }

    #[test]
    fn ridge_shrinks_with_alpha() {
        let (x, y, _) = make_linear_problem(100, 8, 1, 0.1, 3);
        let small = fit(&x, &y, 1e-8, false, Regularizer::Identity).unwrap();
        let large = fit(&x, &y, 1e4, false, Regularizer::Identity).unwrap();
        assert!(large.w.frobenius() < 0.1 * small.w.frobenius());
    }

    #[test]
    fn normal_equations_optimality() {
        // residual gradient Xᵀ(XW − Y) + αW = 0
        let (x, y, _) = make_linear_problem(150, 7, 2, 0.2, 4);
        let alpha = 0.5;
        let r = fit(&x, &y, alpha, false, Regularizer::Identity).unwrap();
        let resid = {
            let mut p = x.matmul(&r.w);
            for t in 0..150 {
                for k in 0..2 {
                    p[(t, k)] -= y[(t, k)];
                }
            }
            p
        };
        let mut grad = x.transpose().matmul(&resid);
        for i in 0..7 {
            for k in 0..2 {
                grad[(i, k)] += alpha * r.w[(i, k)];
            }
        }
        assert!(grad.frobenius() < 1e-8, "gradient={}", grad.frobenius());
    }

    #[test]
    fn generalized_tikhonov_equals_transformed_plain_ridge() {
        // Theorem 1 (iv): fitting in a transformed basis with R = QᵀQ
        // equals fitting plain ridge in the original basis then
        // transforming the weights by Q⁻¹.
        let mut rng = Pcg64::seeded(5);
        let (x, y, _) = make_linear_problem(200, 9, 1, 0.05, 6);
        let q = Mat::randn(9, 9, &mut rng); // invertible w.p. 1
        let xq = x.matmul(&q); // transformed features  [X]_Q = X·Q ... wait: [X]_Q = X·Q
        let alpha = 0.3;

        let plain = fit(&x, &y, alpha, false, Regularizer::Identity).unwrap();
        let qtq = q.transpose().matmul(&q);
        let gen = fit(&xq, &y, alpha, false, Regularizer::Generalized(&qtq)).unwrap();

        // [W]_Q = Q⁻¹·W  ⇒ predictions agree; compare weights directly:
        let w_mapped = Lu::factor(&q).solve_mat(&plain.w).unwrap();
        assert!(
            w_mapped.max_abs_diff(&gen.w) < 1e-7,
            "err={}",
            w_mapped.max_abs_diff(&gen.w)
        );
    }

    #[test]
    fn predictions_match_under_basis_change_with_bias() {
        let mut rng = Pcg64::seeded(7);
        let (x, mut y, _) = make_linear_problem(120, 6, 1, 0.05, 8);
        for t in 0..120 {
            y[(t, 0)] += 1.0;
        }
        let q = Mat::randn(6, 6, &mut rng);
        let xq = x.matmul(&q);
        let alpha = 0.1;
        let plain = fit(&x, &y, alpha, true, Regularizer::Identity).unwrap();
        let qtq = q.transpose().matmul(&q);
        let gen = fit(&xq, &y, alpha, true, Regularizer::Generalized(&qtq)).unwrap();
        let yp = plain.predict(&x);
        let yg = gen.predict(&xq);
        assert!(yp.max_abs_diff(&yg) < 1e-7);
    }

    #[test]
    fn gram_stats_match_direct_fit() {
        let (x, y, _) = make_linear_problem(180, 8, 2, 0.1, 20);
        let stats = GramStats::new(&x, &y);
        let via_stats = stats.solve_scaled(0.01, 1.0).unwrap();
        let direct = fit(&x, &y, 0.01, true, Regularizer::Identity).unwrap();
        assert!(via_stats.w.max_abs_diff(&direct.w) < 1e-8);
        assert!((via_stats.b[0] - direct.b[0]).abs() < 1e-8);
    }

    #[test]
    fn gram_scaling_equals_materialized_scaling() {
        let (x, y, _) = make_linear_problem(150, 6, 1, 0.2, 21);
        let s = 0.01;
        let stats = GramStats::new(&x, &y);
        let fast = stats.solve_scaled(0.5, s).unwrap();
        let mut xs = x.clone();
        xs.scale(s);
        let slow = fit(&xs, &y, 0.5, true, Regularizer::Identity).unwrap();
        assert!(
            fast.w.max_abs_diff(&slow.w) < 1e-7,
            "err={}",
            fast.w.max_abs_diff(&slow.w)
        );
        // scaled prediction path agrees too
        let yp_fast = predict_scaled(&fast, &x, s);
        let yp_slow = slow.predict(&xs);
        assert!(yp_fast.max_abs_diff(&yp_slow) < 1e-8);
    }

    #[test]
    fn singular_gram_falls_back_to_lu_or_errors_cleanly() {
        // duplicate feature columns + alpha=0 → singular normal equations
        let mut rng = Pcg64::seeded(9);
        let base = Mat::randn(50, 3, &mut rng);
        let x = Mat::from_fn(50, 6, |t, j| base[(t, j % 3)]);
        let y = Mat::randn(50, 1, &mut rng);
        match fit(&x, &y, 0.0, false, Regularizer::Identity) {
            Ok(_) => {}  // LU may squeak through with pivoting noise
            Err(_) => {} // clean error also acceptable
        }
        // with alpha > 0 it must succeed
        assert!(fit(&x, &y, 1e-6, false, Regularizer::Identity).is_ok());
    }
}
