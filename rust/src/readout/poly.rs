//! Polynomial (nonlinear) readout — the paper's stated future-work
//! direction ("adapt the methods with non linear readout", citing Gonon &
//! Ortega 2019: a LINEAR reservoir + polynomial readout is a universal
//! approximator). The reservoir stays O(N) and diagonal; only the readout
//! features are expanded:
//!
//! ```text
//! φ(x) = [ x | x⊙x | x_i·x_{i+1} (adjacent pairs) ]     (3N−1 features)
//! ```
//!
//! The adjacent-pair products cover the Q-basis layout's (Re, Im) couples,
//! so |s|² = Re² + Im² and Re·Im — the natural quadratic invariants of
//! each eigen-mode — are all in the span. Training is still one ridge
//! solve (Eq. 9 on φ(X)).

use anyhow::Result;

use crate::linalg::Mat;

use super::{fit, Readout, Regularizer};

/// Quadratic feature expansion of a `[T × N]` state matrix → `[T × (3N−1)]`.
pub fn quadratic_features(x: &Mat) -> Mat {
    let t_len = x.rows();
    let n = x.cols();
    let out_cols = if n > 0 { 3 * n - 1 } else { 0 };
    let mut out = Mat::zeros(t_len, out_cols);
    for t in 0..t_len {
        let row = x.row(t);
        let orow = out.row_mut(t);
        orow[..n].copy_from_slice(row);
        for j in 0..n {
            orow[n + j] = row[j] * row[j];
        }
        for j in 0..n - 1 {
            orow[2 * n + j] = row[j] * row[j + 1];
        }
    }
    out
}

/// Trained polynomial readout: expansion + ridge weights.
pub struct PolyReadout {
    pub inner: Readout,
}

impl PolyReadout {
    /// Fit on states `x [T × N]`, targets `y [T × D]`.
    pub fn fit(x: &Mat, y: &Mat, alpha: f64) -> Result<Self> {
        let phi = quadratic_features(x);
        Ok(Self {
            inner: fit(&phi, y, alpha, true, Regularizer::Identity)?,
        })
    }

    /// Predict on raw states.
    pub fn predict(&self, x: &Mat) -> Mat {
        self.inner.predict(&quadratic_features(x))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::nrmse;
    use crate::readout::{fit, Regularizer};
    use crate::reservoir::{DiagonalEsn, EsnConfig};
    use crate::rng::{Distributions, Pcg64};
    use crate::spectral::uniform::uniform_spectrum;
    use crate::tasks::mso::slice_rows;
    use crate::tasks::narma::NarmaTask;

    #[test]
    fn expansion_shape_and_content() {
        let x = Mat::from_rows(2, 3, &[1.0, 2.0, 3.0, -1.0, 0.5, 2.0]);
        let phi = quadratic_features(&x);
        assert_eq!(phi.cols(), 8);
        // row 0: [1,2,3, 1,4,9, 2,6]
        assert_eq!(phi.row(0), &[1.0, 2.0, 3.0, 1.0, 4.0, 9.0, 2.0, 6.0]);
    }

    #[test]
    fn learns_exact_quadratic_function() {
        let mut rng = Pcg64::seeded(1);
        let x = Mat::randn(200, 4, &mut rng);
        // y = x0² + 2·x1·x2 − x3  (inside the feature span)
        let y = Mat::from_fn(200, 1, |t, _| {
            let r = x.row(t);
            r[0] * r[0] + 2.0 * r[1] * r[2] - r[3]
        });
        // note: x1·x2 is an adjacent pair ⇒ representable
        let ro = PolyReadout::fit(&x, &y, 1e-10).unwrap();
        let pred = ro.predict(&x);
        assert!(pred.max_abs_diff(&y) < 1e-6);
    }

    #[test]
    fn narma_improves_over_linear_readout() {
        // the Gonon–Ortega motivation made concrete: same LINEAR diagonal
        // reservoir, nonlinear readout → strictly better NARMA-10 fit
        let n = 100;
        let config = EsnConfig::default().with_n(n).with_sr(0.95).with_seed(2);
        let mut rng = Pcg64::new(2, 180);
        let spec = uniform_spectrum(n, 0.95, &mut rng);
        let esn = DiagonalEsn::from_dpg(spec, &config, &mut rng);
        let task = NarmaTask::new(2200, 2);
        let states = esn.run(&task.input_mat());
        let x_train = slice_rows(&states, 200..1400);
        let y_train = task.target_mat(200..1400);
        let x_test = slice_rows(&states, 1400..2200);
        let y_test = task.target_mat(1400..2200);

        let linear = fit(&x_train, &y_train, 1e-6, true, Regularizer::Identity).unwrap();
        let e_lin = nrmse(&linear.predict(&x_test), &y_test);
        let poly = PolyReadout::fit(&x_train, &y_train, 1e-6).unwrap();
        let e_poly = nrmse(&poly.predict(&x_test), &y_test);
        assert!(
            e_poly < 0.8 * e_lin,
            "poly {e_poly:.3} should clearly beat linear {e_lin:.3}"
        );
        let _ = rng.normal(); // keep Distributions import exercised
    }
}
