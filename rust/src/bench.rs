//! Timing harness (criterion is not in the offline registry). Used by the
//! `benches/` targets (`harness = false`) and the Fig-2 experiment driver.
//!
//! Protocol per benchmark: warm up for `warmup` iterations, then run
//! batches until `min_time` elapses (at least `min_samples` batches),
//! reporting per-iteration summary statistics.

use crate::util::json::Json;
use crate::util::stats::Summary;
use crate::util::Timer;

/// Configuration for a benchmark run.
#[derive(Clone, Copy, Debug)]
pub struct BenchConfig {
    /// Warm-up iterations (excluded from stats).
    pub warmup_iters: usize,
    /// Minimum total measured wall time in seconds.
    pub min_time_s: f64,
    /// Minimum number of recorded samples.
    pub min_samples: usize,
    /// Iterations folded into one sample (for very fast bodies).
    pub batch: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        Self {
            warmup_iters: 3,
            min_time_s: 0.25,
            min_samples: 10,
            batch: 1,
        }
    }
}

impl BenchConfig {
    /// Quick preset for CI-style runs.
    pub fn quick() -> Self {
        Self {
            warmup_iters: 1,
            min_time_s: 0.05,
            min_samples: 5,
            batch: 1,
        }
    }
}

/// Result of a benchmark: per-iteration seconds.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub per_iter: Summary,
    pub samples: Vec<f64>,
}

impl BenchResult {
    /// Machine-readable summary (per-iteration seconds). Raw samples are
    /// deliberately omitted — the JSON is a perf-trajectory artifact, not
    /// a trace.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("n", Json::Num(self.per_iter.n as f64)),
            ("mean_s", Json::Num(self.per_iter.mean)),
            ("std_s", Json::Num(self.per_iter.std)),
            ("min_s", Json::Num(self.per_iter.min)),
            ("median_s", Json::Num(self.per_iter.median)),
            ("max_s", Json::Num(self.per_iter.max)),
        ])
    }

    /// Format like `name  mean ± std  (median, n)`.
    pub fn report(&self) -> String {
        use crate::util::fmt_duration as d;
        format!(
            "{:<44} {:>12} ± {:>10}  (median {:>12}, n={})",
            self.name,
            d(self.per_iter.mean),
            d(self.per_iter.std),
            d(self.per_iter.median),
            self.per_iter.n
        )
    }
}

/// Run a benchmark over `body`. The closure result is black-boxed to keep
/// the optimizer honest.
pub fn bench<T>(name: &str, cfg: BenchConfig, mut body: impl FnMut() -> T) -> BenchResult {
    for _ in 0..cfg.warmup_iters {
        std::hint::black_box(body());
    }
    let mut samples = Vec::new();
    let total = Timer::start();
    while samples.len() < cfg.min_samples || total.elapsed_s() < cfg.min_time_s {
        let t = Timer::start();
        for _ in 0..cfg.batch {
            std::hint::black_box(body());
        }
        samples.push(t.elapsed_s() / cfg.batch as f64);
        if samples.len() > 1_000_000 {
            break; // safety valve
        }
    }
    BenchResult {
        name: name.to_string(),
        per_iter: Summary::of(&samples),
        samples,
    }
}

/// Measure one-shot setup cost (e.g. generation steps that cannot be
/// repeated cheaply): runs `body` exactly `reps` times, each timed.
pub fn bench_oneshot<T>(name: &str, reps: usize, mut body: impl FnMut() -> T) -> BenchResult {
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t = Timer::start();
        std::hint::black_box(body());
        samples.push(t.elapsed_s());
    }
    BenchResult {
        name: name.to_string(),
        per_iter: Summary::of(&samples),
        samples,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_samples() {
        let r = bench("noop", BenchConfig::quick(), || 1 + 1);
        assert!(r.per_iter.n >= 5);
        assert!(r.per_iter.mean >= 0.0);
    }

    #[test]
    fn bench_oneshot_counts() {
        let r = bench_oneshot("sleepless", 4, || std::hint::black_box(42));
        assert_eq!(r.per_iter.n, 4);
    }

    #[test]
    fn report_formats() {
        let r = bench("fmt", BenchConfig::quick(), || ());
        let line = r.report();
        assert!(line.contains("fmt"));
        assert!(line.contains("median"));
    }

    #[test]
    fn to_json_roundtrips_fields() {
        let r = bench("json", BenchConfig::quick(), || 2 * 2);
        let j = r.to_json();
        assert_eq!(j.get("name").and_then(Json::as_str), Some("json"));
        assert_eq!(
            j.get("n").and_then(Json::as_usize),
            Some(r.per_iter.n)
        );
        let parsed =
            crate::util::json::parse(&j.to_string_compact()).unwrap();
        assert_eq!(
            parsed.get("median_s").and_then(Json::as_f64),
            Some(r.per_iter.median)
        );
    }
}
