//! Algorithm 2 — random generation of the eigenvectors.
//!
//! For the `n_real` real slots: unit real Gaussian columns. For each
//! complex slot: a unit complex Gaussian column (its conjugate partner is
//! implicit in the slot form, materialized by [`full_basis`]). Gaussian
//! columns are linearly independent with probability 1, so `P ∈ GLₙ(ℂ)`.

use crate::linalg::CMat;
use crate::num::c64;
use crate::rng::{Distributions, Pcg64};

use super::Spectrum;

/// Slot-form eigenvector set: one column per slot (`n × slots`).
#[derive(Clone, Debug)]
pub struct SlotBasis {
    /// `n × slots` complex columns; real slots have zero imaginary parts.
    pub cols: CMat,
    pub n_real: usize,
}

/// Generate the slot-form eigenvector basis per Algorithm 2.
pub fn random_eigvecs(spec: &Spectrum, rng: &mut Pcg64) -> SlotBasis {
    let n = spec.n;
    let slots = spec.slots();
    let mut cols = CMat::zeros(n, slots);
    // real slots: unit real Gaussian
    for j in 0..spec.n_real {
        let v = rng.normal_vec(n);
        let norm = v.iter().map(|x| x * x).sum::<f64>().sqrt();
        for i in 0..n {
            cols[(i, j)] = c64::real(v[i] / norm);
        }
    }
    // complex slots: unit complex Gaussian
    for j in spec.n_real..slots {
        let vr = rng.normal_vec(n);
        let vi = rng.normal_vec(n);
        let norm = vr
            .iter()
            .zip(&vi)
            .map(|(a, b)| a * a + b * b)
            .sum::<f64>()
            .sqrt();
        for i in 0..n {
            cols[(i, j)] = c64::new(vr[i] / norm, vi[i] / norm);
        }
    }
    SlotBasis {
        cols,
        n_real: spec.n_real,
    }
}

impl SlotBasis {
    /// Materialize the full `n × n` basis `P` (conjugate columns appended
    /// after each complex slot, matching [`Spectrum::full`]'s order).
    pub fn full_basis(&self) -> CMat {
        let n = self.cols.rows();
        let slots = self.cols.cols();
        let mut p = CMat::zeros(n, n);
        let mut col = 0usize;
        for j in 0..self.n_real {
            for i in 0..n {
                p[(i, col)] = self.cols[(i, j)];
            }
            col += 1;
        }
        for j in self.n_real..slots {
            for i in 0..n {
                p[(i, col)] = self.cols[(i, j)];
                p[(i, col + 1)] = self.cols[(i, j)].conj();
            }
            col += 2;
        }
        debug_assert_eq!(col, n);
        p
    }

    /// The real `Q` basis of Appendix A: real columns for real slots, then
    /// `(Re v, Im v)` column pairs per complex slot — an `n × n` REAL
    /// matrix (returned as real part; imaginary parts are identically 0).
    pub fn q_basis(&self) -> crate::linalg::Mat {
        let n = self.cols.rows();
        let slots = self.cols.cols();
        let mut q = crate::linalg::Mat::zeros(n, n);
        let mut col = 0usize;
        for j in 0..self.n_real {
            for i in 0..n {
                q[(i, col)] = self.cols[(i, j)].re;
            }
            col += 1;
        }
        for j in self.n_real..slots {
            for i in 0..n {
                q[(i, col)] = self.cols[(i, j)].re;
                q[(i, col + 1)] = self.cols[(i, j)].im;
            }
            col += 2;
        }
        q
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{CLu, Lu};
    use crate::spectral::uniform::uniform_spectrum;

    fn setup(n: usize, seed: u64) -> (Spectrum, SlotBasis) {
        let mut rng = Pcg64::seeded(seed);
        let spec = uniform_spectrum(n, 0.9, &mut rng);
        let basis = random_eigvecs(&spec, &mut rng);
        (spec, basis)
    }

    #[test]
    fn full_basis_invertible() {
        let (_, basis) = setup(40, 1);
        let p = basis.full_basis();
        let lu = CLu::factor(&p);
        assert!(!lu.is_singular());
        assert!(lu.rcond_estimate() > 1e-8);
    }

    #[test]
    fn q_basis_invertible_and_real() {
        let (_, basis) = setup(30, 2);
        let q = basis.q_basis();
        let lu = Lu::factor(&q);
        assert!(!lu.is_singular());
    }

    #[test]
    fn columns_unit_norm() {
        let (_, basis) = setup(25, 3);
        for j in 0..basis.cols.cols() {
            let norm: f64 = basis.cols.col(j).iter().map(|z| z.norm_sqr()).sum();
            assert!((norm - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn reconstructed_w_is_real_with_correct_spectrum() {
        // W = P diag(Λ) P⁻¹ must be a REAL matrix whose eigenvalues match.
        let (spec, basis) = setup(16, 4);
        let p = basis.full_basis();
        let full = spec.full();
        let mut pd = p.clone();
        for j in 0..16 {
            for i in 0..16 {
                let v = pd[(i, j)];
                pd[(i, j)] = v * full[j];
            }
        }
        let pinv = CLu::factor(&p).inverse().unwrap();
        let w = pd.matmul(&pinv);
        assert!(w.imag_part().frobenius() < 1e-9, "W must be real");
        // eigenvalues of the reconstructed real matrix match the slot set
        let wr = w.real_part();
        let got = crate::linalg::eigenvalues(&wr);
        let mut got_mods: Vec<f64> = got.iter().map(|z| z.abs()).collect();
        let mut want_mods: Vec<f64> = full.iter().map(|z| z.abs()).collect();
        got_mods.sort_by(|a, b| a.partial_cmp(b).unwrap());
        want_mods.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for (g, w) in got_mods.iter().zip(&want_mods) {
            assert!((g - w).abs() < 1e-6, "{g} vs {w}");
        }
    }

    #[test]
    fn q_basis_relates_to_p_via_z_transform() {
        // Q = P·Z with Z = diag(I, [[.5,.5],[-.5i,.5i]] blocks) — check via
        // the defining property: col pairs (Re v, Im v).
        let (spec, basis) = setup(12, 5);
        let q = basis.q_basis();
        let mut col = spec.n_real;
        for j in spec.n_real..spec.slots() {
            for i in 0..12 {
                assert_eq!(q[(i, col)], basis.cols[(i, j)].re);
                assert_eq!(q[(i, col + 1)], basis.cols[(i, j)].im);
            }
            col += 2;
        }
    }
}
