//! Algorithm 1 — random generation of the eigenvalues (Uniform
//! Distribution DPG).
//!
//! `N_real ≈ √(2N/π)` eigenvalues are real, uniform on `(−sr, sr)`; the
//! remaining conjugate pairs have modulus `sr·√U` (uniform area density on
//! the disk) and angle uniform on `[0, π)`.

use crate::num::c64;
use crate::rng::{Distributions, Pcg64};

use super::{real_count_with_parity, Spectrum};

/// Generate a slot-form spectrum per Algorithm 1.
pub fn uniform_spectrum(n: usize, sr: f64, rng: &mut Pcg64) -> Spectrum {
    let n_real = real_count_with_parity(n);
    let n_cpx = (n - n_real) / 2;
    let mut lam = Vec::with_capacity(n_real + n_cpx);
    for _ in 0..n_real {
        lam.push(c64::real(rng.uniform(-sr, sr)));
    }
    for _ in 0..n_cpx {
        let modulus = sr * rng.next_f64().sqrt();
        // angle in (0, π): keep im strictly positive so the slot layout
        // invariant holds (an exactly-real draw has measure zero; nudge).
        let mut theta = rng.uniform(0.0, std::f64::consts::PI);
        if theta == 0.0 {
            theta = f64::EPSILON;
        }
        lam.push(c64::from_polar(modulus, theta));
    }
    Spectrum::new(n, n_real, lam)
}

/// Ring prior: every eigenvalue sits ON the circle `|λ| = sr` instead of
/// filling the disk — reals are `±sr` (random sign), complex slots get a
/// uniform angle in `(0, π)`. Placing all moduli at the radius maximizes
/// memory timescales (`τ = −1/ln|λ|` is the same for every mode), the
/// long-memory placement suggested by the eigenvalue-timescale analysis
/// in *Tailoring RNNs for Optimal Learning* (arXiv 1707.02469). Used by
/// the model registry's `lambda_prior: "ring"` recipes.
pub fn ring_spectrum(n: usize, sr: f64, rng: &mut Pcg64) -> Spectrum {
    assert!(sr > 0.0, "ring prior needs a positive spectral radius");
    let n_real = real_count_with_parity(n);
    let n_cpx = (n - n_real) / 2;
    let mut lam = Vec::with_capacity(n_real + n_cpx);
    for _ in 0..n_real {
        let sign = if rng.bernoulli(0.5) { 1.0 } else { -1.0 };
        lam.push(c64::real(sign * sr));
    }
    for _ in 0..n_cpx {
        let mut theta = rng.uniform(0.0, std::f64::consts::PI);
        if theta == 0.0 {
            theta = f64::EPSILON;
        }
        lam.push(c64::from_polar(sr, theta));
    }
    Spectrum::new(n, n_real, lam)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::check;

    #[test]
    fn respects_spectral_radius_bound() {
        check("uniform radius ≤ sr", 20, |rng| {
            let n = 50 + (rng.next_below(100) as usize);
            let sr = rng.uniform(0.1, 1.5);
            let s = uniform_spectrum(n, sr, rng);
            if s.radius() <= sr + 1e-12 {
                Ok(())
            } else {
                Err(format!("radius {} > sr {}", s.radius(), sr))
            }
        });
    }

    #[test]
    fn real_count_matches_edelman_kostlan() {
        let mut rng = Pcg64::seeded(1);
        let s = uniform_spectrum(100, 1.0, &mut rng);
        assert_eq!(s.n_real, 8); // √(200/π) ≈ 7.98 → 8 (even, parity ok)
        assert_eq!(s.n, 100);
        assert_eq!(s.slots(), 8 + 46);
    }

    #[test]
    fn complex_slots_upper_half_plane() {
        let mut rng = Pcg64::seeded(2);
        let s = uniform_spectrum(201, 0.9, &mut rng);
        for z in &s.lam[s.n_real..] {
            assert!(z.im > 0.0);
        }
        // full spectrum is conjugate-closed
        let sum_im: f64 = s.full().iter().map(|z| z.im).sum();
        assert!(sum_im.abs() < 1e-12);
    }

    #[test]
    fn sqrt_u_gives_uniform_disk_density() {
        // With modulus ~ sr√U the CDF of |λ| is (r/sr)² — check the median.
        let mut rng = Pcg64::seeded(3);
        let mut mods = Vec::new();
        for _ in 0..200 {
            let s = uniform_spectrum(100, 1.0, &mut rng);
            mods.extend(s.lam[s.n_real..].iter().map(|z| z.abs()));
        }
        mods.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = mods[mods.len() / 2];
        assert!(
            (median - 0.5f64.sqrt()).abs() < 0.02,
            "median={median} want ≈ {:.3}",
            0.5f64.sqrt()
        );
    }

    #[test]
    fn tiny_reservoirs() {
        let mut rng = Pcg64::seeded(4);
        for n in 1..8usize {
            let s = uniform_spectrum(n, 1.0, &mut rng);
            assert_eq!(s.n, n);
            assert_eq!(s.full().len(), n);
        }
    }

    #[test]
    fn ring_places_every_mode_on_the_circle() {
        let mut rng = Pcg64::seeded(5);
        let sr = 0.85;
        let s = ring_spectrum(100, sr, &mut rng);
        assert_eq!(s.n, 100);
        for z in &s.lam {
            assert!(
                (z.abs() - sr).abs() < 1e-15,
                "|λ|={} off the ring {sr}",
                z.abs()
            );
        }
        for z in &s.lam[s.n_real..] {
            assert!(z.im > 0.0);
        }
        // conjugate-closed like every slot-form spectrum
        let sum_im: f64 = s.full().iter().map(|z| z.im).sum();
        assert!(sum_im.abs() < 1e-12);
        // both real signs appear over a few draws
        let mut saw = (false, false);
        for seed in 0..8 {
            let mut r = Pcg64::seeded(seed);
            let s = ring_spectrum(100, sr, &mut r);
            for z in &s.lam[..s.n_real] {
                if z.re > 0.0 {
                    saw.0 = true;
                } else {
                    saw.1 = true;
                }
            }
        }
        assert!(saw.0 && saw.1, "ring reals must use both signs");
    }
}
