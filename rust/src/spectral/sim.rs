//! Sim Distribution — eigenvalues extracted from an actual random
//! reservoir matrix `W` (via the from-scratch eigensolver) combined with
//! randomly generated eigenvectors (Algorithm 2). The paper uses it to
//! isolate the role of eigen*vectors*: Sim shares the Normal baseline's
//! spectral density but not its eigenvector structure (Fig 6's
//! "eigenvectors play a secondary role" finding).

use crate::linalg::{eigenvalues, Mat};
use crate::rng::Pcg64;
use crate::sparse::Csr;

use super::{spectrum_from_eigenvalues, Spectrum};

/// Tolerance for flattening numerically-real eigenvalues.
const REAL_TOL: f64 = 1e-9;

/// Generate a random dense reservoir (i.i.d. normal entries with the given
/// connectivity), scale it to spectral radius `sr`, and return its
/// slot-form spectrum. O(N³) — this is the cost DPG's other distributions
/// avoid, kept here deliberately as the paper's comparison point.
pub fn sim_spectrum(n: usize, connectivity: f64, sr: f64, rng: &mut Pcg64) -> Spectrum {
    let w = Csr::random(n, n, connectivity, rng).to_dense();
    let vals = eigenvalues(&w);
    let rho = vals.iter().map(|z| z.abs()).fold(0.0, f64::max);
    let spec = spectrum_from_eigenvalues(&vals, REAL_TOL);
    if rho > 0.0 {
        spec.scaled(sr / rho)
    } else {
        spec
    }
}

/// Same, but from a caller-provided matrix (used by EWT/EET where the
/// matrix must be *kept* — Sim only keeps its spectrum).
pub fn spectrum_of(w: &Mat) -> Spectrum {
    spectrum_from_eigenvalues(&eigenvalues(w), REAL_TOL)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scaled_to_requested_radius() {
        let mut rng = Pcg64::seeded(1);
        let s = sim_spectrum(60, 1.0, 0.8, &mut rng);
        assert!((s.radius() - 0.8).abs() < 1e-9, "radius={}", s.radius());
        assert_eq!(s.n, 60);
    }

    #[test]
    fn real_count_close_to_edelman_kostlan() {
        // average over seeds: E[N_real] = √(2N/π) ≈ 7.98 for N=100
        let mut total = 0usize;
        let runs = 12;
        for seed in 0..runs {
            let mut rng = Pcg64::seeded(seed);
            let s = sim_spectrum(100, 1.0, 1.0, &mut rng);
            total += s.n_real;
        }
        let mean = total as f64 / runs as f64;
        assert!(
            (mean - 7.98).abs() < 3.0,
            "mean real count {mean}, want ≈ 7.98"
        );
    }

    #[test]
    fn sparse_input_lowers_rank_gracefully() {
        let mut rng = Pcg64::seeded(3);
        let s = sim_spectrum(40, 0.02, 1.0, &mut rng);
        assert_eq!(s.n, 40);
        // extremely sparse ⇒ most eigenvalues ≈ 0 (the Fig 7 collapse)
        let near_zero = s
            .full()
            .iter()
            .filter(|z| z.abs() < 1e-6)
            .count();
        assert!(near_zero > 10, "near_zero={near_zero}");
    }

    #[test]
    fn spectrum_of_matches_direct_eigenvalues() {
        let mut rng = Pcg64::seeded(4);
        let w = Mat::randn(20, 20, &mut rng);
        let s = spectrum_of(&w);
        assert_eq!(s.full().len(), 20);
    }
}
