//! Algorithm 3 — Golden (phyllotaxis) eigenvalue distribution, with the
//! optional Gaussian noise of the "Noisy Golden" variant.
//!
//! Complex eigenvalues are laid on a sunflower spiral: the angle advances
//! by the golden-angle step `(3−√5)` (mod 2, in units of π) and the modulus
//! grows as `√(k / 2n_cpx)` — constant density over the unit half-disk.
//! Only angles with `v < 1` (upper half-plane) are kept, exactly as in the
//! paper's listing. After scaling to the requested spectral radius,
//! `Normal(0,σ) + i·Normal(0,σ)` noise is added to the complex slots
//! (σ = 0 → deterministic Golden; σ = 0.2 → the paper's Noisy Golden).
//!
//! Note on the paper's line 3 (`N_real ← (N − N_real) mod 2`): taken
//! literally this discards the Edelman–Kostlan count entirely, which
//! contradicts the text ("the partition … follows the same statistical
//! scaling as Method 3"); we read it as the same parity fix used in
//! Algorithm 1 and documented the substitution in DESIGN.md.

use crate::num::c64;
use crate::rng::{Distributions, Pcg64};

use super::{real_count_with_parity, Spectrum};

/// Parameters for the golden generator.
#[derive(Clone, Copy, Debug)]
pub struct GoldenParams {
    /// Target spectral radius.
    pub sr: f64,
    /// Gaussian noise std added to complex slots (0 = deterministic).
    pub sigma: f64,
}

/// Generate a slot-form spectrum per Algorithm 3. `rng` is used for the
/// real slots, the initial spiral phase, and the noise.
pub fn golden_spectrum(n: usize, params: GoldenParams, rng: &mut Pcg64) -> Spectrum {
    let n_real = real_count_with_parity(n);
    let n_cpx = (n - n_real) / 2;

    let mut reals: Vec<f64> = (0..n_real).map(|_| rng.uniform(-1.0, 1.0)).collect();

    // phyllotaxis spiral over the upper half-disk
    let step = 3.0 - 5.0f64.sqrt(); // golden-angle increment (×π)
    let mut v = rng.uniform(0.0, 2.0);
    let mut cpx: Vec<c64> = Vec::with_capacity(n_cpx);
    let mut k = 0usize;
    while cpx.len() < n_cpx {
        k += 1;
        v = (v + step) % 2.0;
        if v < 1.0 {
            let modulus = (k as f64 / (2.0 * n_cpx as f64)).sqrt();
            // keep strictly inside the open upper half-plane
            let theta = (v * std::f64::consts::PI).max(f64::EPSILON);
            cpx.push(c64::from_polar(modulus, theta));
        }
        if k > 100 * (n_cpx + 1) {
            unreachable!("golden spiral failed to fill the half-disk");
        }
    }

    // Noisy Golden: complex-Gaussian perturbation of the complex slots.
    // NOTE on ordering: Algorithm 3 as printed adds the noise AFTER the
    // spectral-radius scaling, which would push eigenvalues outside the
    // disk of radius sr (unstable at ρ = 1, and contradicting the paper's
    // own Fig 3, where the Noisy Golden spectrum lies inside the unit
    // disk). We therefore perturb first and normalize after — the final
    // spectrum has max |λ| = sr exactly, matching Fig 3. Recorded in
    // DESIGN.md §6 as a substitution.
    if params.sigma > 0.0 {
        for z in &mut cpx {
            let mut pert = *z
                + c64::new(
                    rng.normal_ms(0.0, params.sigma),
                    rng.normal_ms(0.0, params.sigma),
                );
            // slot invariant: complex slots live strictly above the axis —
            // reflect any noise draw that crossed it (conjugate symmetry
            // makes the reflected eigenvalue equivalent).
            if pert.im <= 0.0 {
                pert = c64::new(pert.re, (-pert.im).max(1e-12));
            }
            *z = pert;
        }
    }

    // scale so max(|Λ_real|, |Λ_cpx|) == sr
    let max_mod = reals
        .iter()
        .map(|x| x.abs())
        .chain(cpx.iter().map(|z| z.abs()))
        .fold(0.0f64, f64::max);
    if max_mod > 0.0 {
        let scale = params.sr / max_mod;
        for x in &mut reals {
            *x *= scale;
        }
        for z in &mut cpx {
            *z = *z * scale;
        }
    }

    let mut lam: Vec<c64> = reals.into_iter().map(c64::real).collect();
    lam.extend(cpx);
    Spectrum::new(n, n_real, lam)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gen(n: usize, sr: f64, sigma: f64, seed: u64) -> Spectrum {
        let mut rng = Pcg64::seeded(seed);
        golden_spectrum(n, GoldenParams { sr, sigma }, &mut rng)
    }

    #[test]
    fn radius_exactly_sr_when_deterministic() {
        for &sr in &[0.5, 0.9, 1.0, 1.3] {
            let s = gen(100, sr, 0.0, 1);
            assert!((s.radius() - sr).abs() < 1e-12, "sr={sr} got {}", s.radius());
        }
    }

    #[test]
    fn spiral_covers_radii_uniformly() {
        // constant disk density ⇒ |λ|² uniform ⇒ mean |λ|² ≈ 1/2
        let s = gen(600, 1.0, 0.0, 2);
        let m2: f64 = s.lam[s.n_real..]
            .iter()
            .map(|z| z.norm_sqr())
            .sum::<f64>()
            / s.n_cpx() as f64;
        assert!((m2 - 0.5).abs() < 0.1, "mean |λ|² = {m2}");
    }

    #[test]
    fn angles_spread_over_half_plane() {
        let s = gen(400, 1.0, 0.0, 3);
        let angles: Vec<f64> = s.lam[s.n_real..].iter().map(|z| z.arg()).collect();
        let lo = angles.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = angles.iter().cloned().fold(0.0f64, f64::max);
        assert!(lo < 0.35, "min angle {lo}");
        assert!(hi > std::f64::consts::PI - 0.35, "max angle {hi}");
    }

    #[test]
    fn deterministic_given_phase() {
        let a = gen(80, 1.0, 0.0, 7);
        let b = gen(80, 1.0, 0.0, 7);
        for (x, y) in a.lam.iter().zip(&b.lam) {
            assert_eq!(x, y);
        }
    }

    #[test]
    fn noise_perturbs_but_preserves_layout() {
        let s = gen(120, 1.0, 0.2, 8);
        assert_eq!(s.n, 120);
        for z in &s.lam[s.n_real..] {
            assert!(z.im > 0.0);
        }
        // noisy version differs from the deterministic one
        let det = gen(120, 1.0, 0.0, 8);
        let diff: f64 = s
            .lam
            .iter()
            .zip(&det.lam)
            .map(|(a, b)| (*a - *b).abs())
            .sum();
        assert!(diff > 0.1);
    }

    #[test]
    fn golden_step_is_irrational_rotation() {
        // consecutive kept angles should not repeat for many steps
        let s = gen(300, 1.0, 0.0, 9);
        let mut angles: Vec<f64> = s.lam[s.n_real..].iter().map(|z| z.arg()).collect();
        angles.sort_by(|a, b| a.partial_cmp(b).unwrap());
        angles.dedup_by(|a, b| (*a - *b).abs() < 1e-9);
        assert_eq!(angles.len(), s.n_cpx(), "spiral angles must be distinct");
    }
}
