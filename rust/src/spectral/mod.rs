//! Spectral machinery for Direct Parameter Generation (DPG, paper §4.4)
//! and the shared *slot* representation of diagonalized reservoirs.
//!
//! A real `N×N` reservoir has `n_real` real eigenvalues and `n_cpx`
//! complex-conjugate pairs with `N = n_real + 2·n_cpx`. Everything
//! downstream (the Pallas kernel, the Rust engines, the readout layout)
//! stores ONE member per conjugate pair — the *slot* form:
//!
//! ```text
//! slots:   [ λ₁ … λ_{n_real} | μ₁ … μ_{n_cpx} ]      (μ_k: im > 0)
//! Q-basis: [ r₁ … r_{n_real} | Re μ₁ Im μ₁ … ]        (N real features)
//! ```
//!
//! Generators: [`uniform`] (Alg 1), [`golden`] (Alg 3, with optional noise),
//! [`sim`] (eigenvalues of an actual random `W` + random eigenvectors), and
//! [`eigvecs`] (Alg 2) for the eigenvector basis `P`.

pub mod eigvecs;
pub mod golden;
pub mod sim;
pub mod uniform;

use crate::num::c64;

/// Slot-form spectrum of a real matrix (see module docs).
#[derive(Clone, Debug)]
pub struct Spectrum {
    /// Reservoir dimension `N = n_real + 2·(slots − n_real)`.
    pub n: usize,
    /// Number of real-eigenvalue slots (they come first).
    pub n_real: usize,
    /// One eigenvalue per slot; `lam[i].im == 0` for `i < n_real`,
    /// `lam[i].im > 0` for complex slots.
    pub lam: Vec<c64>,
}

impl Spectrum {
    /// Build from a slot vector; validates the layout.
    pub fn new(n: usize, n_real: usize, lam: Vec<c64>) -> Self {
        let n_cpx = lam.len() - n_real;
        assert_eq!(n, n_real + 2 * n_cpx, "slot layout mismatch");
        debug_assert!(lam[..n_real].iter().all(|z| z.im == 0.0));
        debug_assert!(lam[n_real..].iter().all(|z| z.im > 0.0));
        Self { n, n_real, lam }
    }

    /// Number of slots (`n_real + n_cpx`).
    pub fn slots(&self) -> usize {
        self.lam.len()
    }

    /// Number of complex-conjugate pairs.
    pub fn n_cpx(&self) -> usize {
        self.lam.len() - self.n_real
    }

    /// Expand to the full `N`-element eigenvalue list (conjugates
    /// materialized, pairs adjacent, `im > 0` first — the eigensolver's
    /// convention).
    pub fn full(&self) -> Vec<c64> {
        let mut out = Vec::with_capacity(self.n);
        out.extend_from_slice(&self.lam[..self.n_real]);
        for &z in &self.lam[self.n_real..] {
            out.push(z);
            out.push(z.conj());
        }
        out
    }

    /// Spectral radius `max |λ|`.
    pub fn radius(&self) -> f64 {
        self.lam.iter().map(|z| z.abs()).fold(0.0, f64::max)
    }

    /// Leaking-rate reparametrization (paper Eq. 4, spectral form):
    /// `W ← lr·W + (1−lr)·I` ⇒ `λ ← lr·λ + (1−lr)` (same eigenvectors).
    ///
    /// NOTE: mixing with the identity can rotate a complex eigenvalue's
    /// imaginary part to exactly zero only if it was zero already, so the
    /// slot layout is preserved.
    pub fn apply_leak(&self, lr: f64) -> Spectrum {
        assert!(lr > 0.0 && lr <= 1.0);
        let lam = self
            .lam
            .iter()
            .map(|&z| z * lr + c64::real(1.0 - lr))
            .collect();
        Spectrum {
            n: self.n,
            n_real: self.n_real,
            lam,
        }
    }

    /// Scale all eigenvalues (spectral-radius adjustment:
    /// `W ← ρ·W/ρ₀` ⇒ `λ ← ρ·λ/ρ₀`).
    pub fn scaled(&self, s: f64) -> Spectrum {
        Spectrum {
            n: self.n,
            n_real: self.n_real,
            lam: self.lam.iter().map(|&z| z * s).collect(),
        }
    }

    /// Split planes for the kernels: `(re, im)` per slot.
    pub fn planes(&self) -> (Vec<f64>, Vec<f64>) {
        (
            self.lam.iter().map(|z| z.re).collect(),
            self.lam.iter().map(|z| z.im).collect(),
        )
    }
}

/// Expected number of real eigenvalues of an `N×N` i.i.d. Gaussian matrix
/// (Edelman–Kostlan 1995): `E[N_real] ~ √(2N/π)` — Eq. (21).
pub fn expected_real_count(n: usize) -> f64 {
    (2.0 * n as f64 / std::f64::consts::PI).sqrt()
}

/// The paper's real-count rule shared by Alg 1 and Alg 3: round
/// `√(2N/π)`, then fix parity so `N − N_real` is even (conjugate pairs).
pub fn real_count_with_parity(n: usize) -> usize {
    let mut n_real = expected_real_count(n).round() as usize;
    if n_real % 2 != n % 2 {
        n_real += 1;
    }
    n_real.min(n)
}

/// Assemble a [`Spectrum`] from a raw eigenvalue list in the eigensolver's
/// convention (conjugate pairs adjacent, `im > 0` first). Near-real
/// eigenvalues (|im| ≤ `tol·|λ|`) are flattened to real.
pub fn spectrum_from_eigenvalues(values: &[c64], tol: f64) -> Spectrum {
    let n = values.len();
    let mut reals = Vec::new();
    let mut cpx = Vec::new();
    let mut i = 0;
    while i < n {
        let z = values[i];
        if z.im.abs() <= tol * z.abs().max(1e-300) {
            reals.push(c64::real(z.re));
            i += 1;
        } else {
            // take the im>0 member; skip its conjugate partner
            cpx.push(if z.im > 0.0 { z } else { z.conj() });
            debug_assert!(
                i + 1 < n,
                "complex eigenvalue without a conjugate partner"
            );
            i += 2;
        }
    }
    let n_real = reals.len();
    reals.extend(cpx);
    Spectrum::new(n, n_real, reals)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn edelman_kostlan_scaling() {
        assert!((expected_real_count(100) - 7.9788).abs() < 1e-3);
        // parity: N=100 even → n_real must be even
        assert_eq!(real_count_with_parity(100) % 2, 0);
        assert_eq!(real_count_with_parity(101) % 2, 1);
    }

    #[test]
    fn full_expansion_conjugate_closed() {
        let s = Spectrum::new(
            5,
            1,
            vec![c64::real(0.5), c64::new(0.1, 0.2), c64::new(-0.3, 0.4)],
        );
        let full = s.full();
        assert_eq!(full.len(), 5);
        let sum_im: f64 = full.iter().map(|z| z.im).sum();
        assert!(sum_im.abs() < 1e-15);
    }

    #[test]
    fn leak_shrinks_toward_one() {
        let s = Spectrum::new(2, 0, vec![c64::new(0.0, 1.0)]);
        let leaked = s.apply_leak(0.5);
        assert!((leaked.lam[0] - c64::new(0.5, 0.5)).abs() < 1e-15);
        // lr = 1 is identity
        let id = s.apply_leak(1.0);
        assert_eq!(id.lam[0], s.lam[0]);
    }

    #[test]
    fn radius_and_scale() {
        let s = Spectrum::new(
            4,
            2,
            vec![c64::real(-0.8), c64::real(0.2), c64::new(0.3, 0.4)],
        );
        assert!((s.radius() - 0.8).abs() < 1e-15);
        let t = s.scaled(1.25);
        assert!((t.radius() - 1.0).abs() < 1e-15);
    }

    #[test]
    fn from_eigenvalues_roundtrip() {
        use crate::linalg::{eigenvalues, Mat};
        use crate::rng::Pcg64;
        let mut rng = Pcg64::seeded(1);
        let n = 30;
        let mut a = Mat::randn(n, n, &mut rng);
        a.scale(1.0 / (n as f64).sqrt());
        let vals = eigenvalues(&a);
        let s = spectrum_from_eigenvalues(&vals, 1e-12);
        assert_eq!(s.n, n);
        assert_eq!(s.full().len(), n);
        // multiset of |λ| preserved
        let mut a1: Vec<f64> = vals.iter().map(|z| z.abs()).collect();
        let mut a2: Vec<f64> = s.full().iter().map(|z| z.abs()).collect();
        a1.sort_by(|x, y| x.partial_cmp(y).unwrap());
        a2.sort_by(|x, y| x.partial_cmp(y).unwrap());
        for (x, y) in a1.iter().zip(&a2) {
            assert!((x - y).abs() < 1e-12);
        }
    }

    #[test]
    fn planes_layout() {
        let s = Spectrum::new(3, 1, vec![c64::real(0.7), c64::new(0.1, 0.6)]);
        let (re, im) = s.planes();
        assert_eq!(re, vec![0.7, 0.1]);
        assert_eq!(im, vec![0.0, 0.6]);
    }
}
