//! Evaluation metrics: RMSE (Table 2), the determination coefficient /
//! k-delay memory capacity (Eq. 23–24, Figs 6–7), NRMSE and R².

use crate::linalg::Mat;
use crate::util::stats::pearson;

/// Root mean squared error between prediction and target matrices.
pub fn rmse(pred: &Mat, target: &Mat) -> f64 {
    assert_eq!((pred.rows(), pred.cols()), (target.rows(), target.cols()));
    let n = (pred.rows() * pred.cols()) as f64;
    let mut s = 0.0;
    for i in 0..pred.rows() {
        let p = pred.row(i);
        let t = target.row(i);
        for j in 0..pred.cols() {
            let d = p[j] - t[j];
            s += d * d;
        }
    }
    (s / n).sqrt()
}

/// RMSE normalized by the target's standard deviation.
pub fn nrmse(pred: &Mat, target: &Mat) -> f64 {
    let n = (target.rows() * target.cols()) as f64;
    let mean: f64 = (0..target.rows())
        .map(|i| target.row(i).iter().sum::<f64>())
        .sum::<f64>()
        / n;
    let var: f64 = (0..target.rows())
        .map(|i| {
            target
                .row(i)
                .iter()
                .map(|x| (x - mean) * (x - mean))
                .sum::<f64>()
        })
        .sum::<f64>()
        / n;
    if var == 0.0 {
        f64::INFINITY
    } else {
        rmse(pred, target) / var.sqrt()
    }
}

/// Coefficient of determination R² (1 − SSE/SST) over flattened entries.
pub fn r2(pred: &Mat, target: &Mat) -> f64 {
    let n = (target.rows() * target.cols()) as f64;
    let mean: f64 = (0..target.rows())
        .map(|i| target.row(i).iter().sum::<f64>())
        .sum::<f64>()
        / n;
    let mut sse = 0.0;
    let mut sst = 0.0;
    for i in 0..target.rows() {
        for j in 0..target.cols() {
            let d = pred[(i, j)] - target[(i, j)];
            sse += d * d;
            let dm = target[(i, j)] - mean;
            sst += dm * dm;
        }
    }
    if sst == 0.0 {
        if sse == 0.0 {
            1.0
        } else {
            f64::NEG_INFINITY
        }
    } else {
        1.0 - sse / sst
    }
}

/// Eq. 23: determination coefficient `d(u(t−k), y_k(t))` — the squared
/// correlation between the delayed input and the readout's reconstruction.
/// This IS the k-delay memory capacity once the readout is ridge-optimal
/// (Eq. 24).
pub fn determination(u_delayed: &[f64], y: &[f64]) -> f64 {
    let r = pearson(u_delayed, y);
    let d = r * r;
    if d.is_finite() {
        d
    } else {
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Distributions, Pcg64};

    #[test]
    fn rmse_zero_for_identical() {
        let mut rng = Pcg64::seeded(1);
        let a = Mat::randn(10, 2, &mut rng);
        assert_eq!(rmse(&a, &a), 0.0);
        assert_eq!(r2(&a, &a), 1.0);
    }

    #[test]
    fn rmse_known_value() {
        let a = Mat::from_rows(2, 1, &[0.0, 0.0]);
        let b = Mat::from_rows(2, 1, &[3.0, 4.0]);
        // √((9+16)/2) = √12.5
        assert!((rmse(&a, &b) - 12.5f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn nrmse_scale_invariant() {
        let mut rng = Pcg64::seeded(2);
        let t = Mat::randn(200, 1, &mut rng);
        let mut p = t.clone();
        for i in 0..200 {
            p[(i, 0)] += 0.1 * rng.normal();
        }
        let base = nrmse(&p, &t);
        let mut t2 = t.clone();
        t2.scale(10.0);
        let mut p2 = p.clone();
        p2.scale(10.0);
        assert!((nrmse(&p2, &t2) - base).abs() < 1e-12);
    }

    #[test]
    fn determination_perfect_reconstruction() {
        let u: Vec<f64> = (0..50).map(|i| (i as f64 * 0.3).sin()).collect();
        let y: Vec<f64> = u.iter().map(|x| 2.0 * x + 1.0).collect(); // affine
        assert!((determination(&u, &y) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn determination_independent_signals_near_zero() {
        let mut rng = Pcg64::seeded(3);
        let u = rng.normal_vec(5000);
        let y = rng.normal_vec(5000);
        assert!(determination(&u, &y) < 0.01);
    }
}
