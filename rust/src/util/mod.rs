//! Small infrastructure substrates: JSON (manifest parsing + result
//! serialization), CSV emission for every figure/table, summary statistics,
//! and a timer.

pub mod csv;
pub mod json;
pub mod stats;

use std::time::Instant;

/// Wall-clock stopwatch returning seconds.
pub struct Timer(Instant);

impl Timer {
    pub fn start() -> Self {
        Timer(Instant::now())
    }
    pub fn elapsed_s(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
    pub fn elapsed_ns(&self) -> u128 {
        self.0.elapsed().as_nanos()
    }
}

/// Format seconds human-readably (`1.23s`, `45.6ms`, `789µs`, `12ns`).
pub fn fmt_duration(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.3}s")
    } else if secs >= 1e-3 {
        format!("{:.3}ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:.3}µs", secs * 1e6)
    } else {
        format!("{:.1}ns", secs * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_duration(2.5), "2.500s");
        assert_eq!(fmt_duration(0.0025), "2.500ms");
        assert_eq!(fmt_duration(2.5e-6), "2.500µs");
        assert_eq!(fmt_duration(2.5e-9), "2.5ns");
    }
}
