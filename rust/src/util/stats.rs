//! Summary statistics over f64 samples (bench reports, seed aggregation).

/// Aggregate of a sample: n, mean, std (unbiased), min, median, max.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub median: f64,
    pub max: f64,
}

impl Summary {
    /// Compute over a sample. Empty slices produce NaN fields and n=0.
    pub fn of(xs: &[f64]) -> Summary {
        let n = xs.len();
        if n == 0 {
            return Summary {
                n: 0,
                mean: f64::NAN,
                std: f64::NAN,
                min: f64::NAN,
                median: f64::NAN,
                max: f64::NAN,
            };
        }
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
                / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            median: quantile_sorted(&sorted, 0.5),
            max: sorted[n - 1],
        }
    }
}

/// Linear-interpolated quantile of a pre-sorted sample, q in [0,1].
pub fn quantile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = pos - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Pearson correlation coefficient.
pub fn pearson(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    let n = x.len() as f64;
    let mx = x.iter().sum::<f64>() / n;
    let my = y.iter().sum::<f64>() / n;
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for i in 0..x.len() {
        let dx = x[i] - mx;
        let dy = y[i] - my;
        sxy += dx * dy;
        sxx += dx * dx;
        syy += dy * dy;
    }
    if sxx == 0.0 || syy == 0.0 {
        0.0
    } else {
        sxy / (sxx * syy).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-15);
        assert!((s.median - 3.0).abs() < 1e-15);
        assert!((s.std - (2.5f64).sqrt()).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
    }

    #[test]
    fn summary_single() {
        let s = Summary::of(&[7.0]);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.median, 7.0);
    }

    #[test]
    fn summary_empty() {
        let s = Summary::of(&[]);
        assert_eq!(s.n, 0);
        assert!(s.mean.is_nan());
    }

    #[test]
    fn quantiles_interpolate() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((quantile_sorted(&xs, 0.5) - 2.5).abs() < 1e-15);
        assert_eq!(quantile_sorted(&xs, 0.0), 1.0);
        assert_eq!(quantile_sorted(&xs, 1.0), 4.0);
    }

    #[test]
    fn pearson_perfect_and_anti() {
        let x = [1.0, 2.0, 3.0];
        assert!((pearson(&x, &[2.0, 4.0, 6.0]) - 1.0).abs() < 1e-12);
        assert!((pearson(&x, &[3.0, 2.0, 1.0]) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn pearson_constant_is_zero() {
        assert_eq!(pearson(&[1.0, 2.0, 3.0], &[5.0, 5.0, 5.0]), 0.0);
    }
}
