//! Minimal JSON reader/writer (serde is not in the offline registry).
//!
//! Scope: what the repo needs — parsing `artifacts/manifest.json` and
//! config files, and serializing experiment results. Full RFC 8259 value
//! model; numbers are `f64`; parser accepts arbitrary nesting; writer emits
//! deterministic key order (insertion order preserved via Vec-backed map).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// JSON value. Objects use a BTreeMap (deterministic serialization order).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                // integral values print without a decimal point — EXCEPT
                // negative zero, which `as i64` would collapse to `0` and
                // lose on re-parse. `{x}` prints `-0`, which parses back
                // to -0.0, keeping serialize∘parse bit-exact on every
                // finite f64 (the checkpoint wire format depends on it).
                let neg_zero = *x == 0.0 && x.is_sign_negative();
                if x.fract() == 0.0 && x.abs() < 1e15 && !neg_zero {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Json> {
    let mut p = Parser {
        bytes: text.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        bail!("trailing characters at byte {}", p.pos);
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            bail!(
                "expected '{}' at byte {}, found {:?}",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            )
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek().ok_or_else(|| anyhow!("unexpected end of input"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'n' => self.literal("null", Json::Null),
            _ => self.number(),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                other => bail!("expected ',' or '}}' found {other:?}"),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                other => bail!("expected ',' or ']' found {other:?}"),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek().ok_or_else(|| anyhow!("unterminated string"))? {
                b'"' => {
                    self.pos += 1;
                    return Ok(s);
                }
                b'\\' => {
                    self.pos += 1;
                    let esc = self
                        .peek()
                        .ok_or_else(|| anyhow!("unterminated escape"))?;
                    self.pos += 1;
                    match esc {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(
                                &self.bytes[self.pos..self.pos + 4],
                            )?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.pos += 4;
                            s.push(
                                char::from_u32(code)
                                    .ok_or_else(|| anyhow!("bad \\u escape"))?,
                            );
                        }
                        _ => bail!("bad escape \\{}", esc as char),
                    }
                }
                _ => {
                    // consume one UTF-8 code point
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while matches!(
            self.peek(),
            Some(b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        ) {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse::<f64>().map_err(|e| {
            anyhow!("bad number {text:?} at byte {start}: {e}")
        })?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let text = r#"{"a": [1, 2.5, -3e-2], "b": {"c": "x\ny", "d": null}, "e": true}"#;
        let v = parse(text).unwrap();
        let re = parse(&v.to_string_compact()).unwrap();
        assert_eq!(v, re);
        let re2 = parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, re2);
    }

    #[test]
    fn accessors() {
        let v = parse(r#"{"dims": {"T": 1000, "slots": 100}, "file": "f.txt"}"#)
            .unwrap();
        assert_eq!(v.get("dims").unwrap().get("T").unwrap().as_usize(), Some(1000));
        assert_eq!(v.get("file").unwrap().as_str(), Some("f.txt"));
        assert!(v.get("missing").is_none());
    }

    #[test]
    fn parses_real_manifest_shape() {
        let text = r#"{"format": "hlo-text", "artifacts": [
            {"kind": "diag_states", "dims": {"T": 32, "d_in": 2, "slots": 16},
             "file": "diag_states__T32_d_in2_slots16.hlo.txt"}]}"#;
        let v = parse(text).unwrap();
        let arts = v.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts.len(), 1);
        assert_eq!(arts[0].get("kind").unwrap().as_str(), Some("diag_states"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("nul").is_err());
        assert!(parse("{} extra").is_err());
    }

    #[test]
    fn unicode_escapes() {
        let v = parse(r#""éA""#).unwrap();
        assert_eq!(v.as_str(), Some("éA"));
    }

    #[test]
    fn integer_formatting() {
        assert_eq!(Json::Num(42.0).to_string_compact(), "42");
        assert_eq!(Json::Num(0.5).to_string_compact(), "0.5");
    }

    #[test]
    fn float_serialization_is_bit_exact() {
        // serialize ∘ parse must be the identity on every finite f64 —
        // the checkpoint/restore wire format relies on it. Rust's
        // shortest-form `{}` Display guarantees round-trip for normal
        // values; the special cases are the integral shortcut and -0.0.
        let cases = [
            0.0,
            -0.0,
            1.0,
            -1.0,
            0.1,
            -0.1,
            1.0 + f64::EPSILON,
            f64::MIN_POSITIVE,
            f64::MIN_POSITIVE / 4.0, // subnormal
            1e300,
            -1e-300,
            std::f64::consts::PI,
            1234567890123456.0, // above the integral-shortcut cutoff
        ];
        for &x in &cases {
            let text = Json::Num(x).to_string_compact();
            let back = parse(&text).unwrap().as_f64().unwrap();
            assert_eq!(
                back.to_bits(),
                x.to_bits(),
                "round-trip of {x:?} via {text:?} lost bits"
            );
        }
        assert_eq!(Json::Num(-0.0).to_string_compact(), "-0");
    }
}
