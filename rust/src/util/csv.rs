//! CSV emission — every figure/table driver writes its series here so the
//! paper plots can be regenerated from plain files.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

use anyhow::Result;

/// Streaming CSV writer with a fixed header.
pub struct CsvWriter {
    out: BufWriter<File>,
    cols: usize,
}

impl CsvWriter {
    /// Create `path` (and parent dirs) and write the header row.
    pub fn create<P: AsRef<Path>>(path: P, header: &[&str]) -> Result<Self> {
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut out = BufWriter::new(File::create(path)?);
        writeln!(out, "{}", header.join(","))?;
        Ok(Self {
            out,
            cols: header.len(),
        })
    }

    /// Write a row of string fields (quoted if they contain separators).
    pub fn row(&mut self, fields: &[String]) -> Result<()> {
        debug_assert_eq!(fields.len(), self.cols, "column count mismatch");
        let escaped: Vec<String> = fields.iter().map(|f| escape(f)).collect();
        writeln!(self.out, "{}", escaped.join(","))?;
        Ok(())
    }

    /// Convenience: a row of mixed displayable values.
    pub fn rowv(&mut self, fields: &[&dyn std::fmt::Display]) -> Result<()> {
        let strs: Vec<String> = fields.iter().map(|f| f.to_string()).collect();
        self.row(&strs)
    }

    pub fn flush(&mut self) -> Result<()> {
        self.out.flush()?;
        Ok(())
    }
}

fn escape(f: &str) -> String {
    if f.contains(',') || f.contains('"') || f.contains('\n') {
        format!("\"{}\"", f.replace('"', "\"\""))
    } else {
        f.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_header_and_rows() {
        let dir = std::env::temp_dir().join("lr_csv_test");
        let path = dir.join("t.csv");
        {
            let mut w = CsvWriter::create(&path, &["a", "b"]).unwrap();
            w.row(&["1".into(), "x,y".into()]).unwrap();
            w.rowv(&[&2.5, &"plain"]).unwrap();
            w.flush().unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "a,b\n1,\"x,y\"\n2.5,plain\n");
        std::fs::remove_dir_all(&dir).ok();
    }
}
