//! Benchmark workloads from the paper's evaluation: Multiple Superimposed
//! Oscillators (§5.1), Memory Capacity (§5.2), plus NARMA-10 as an extra
//! nonlinear-readout stressor (future-work direction of the paper).

pub mod memory;
pub mod mso;
pub mod narma;
