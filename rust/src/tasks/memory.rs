//! Memory Capacity task — paper §5.2 (Jaeger 2001).
//!
//! i.i.d. input `u(t) ~ Uniform(−0.8, 0.8)`; for each delay `k` a readout
//! `y_k` is trained to reconstruct `u(t−k)` from the current state; the
//! k-delay capacity is the squared correlation (Eq. 23–24) on held-out
//! data. The paper evaluates reservoirs with spectral radius exactly 1 and
//! no leak.

use crate::linalg::Mat;
use crate::metrics::determination;
use crate::readout::{fit, Regularizer};
use crate::rng::{Distributions, Pcg64};

/// Memory-capacity workload: input sequence + split bookkeeping.
#[derive(Clone, Debug)]
pub struct McTask {
    pub input: Vec<f64>,
    pub washout: usize,
    pub train: usize,
    pub test: usize,
}

impl McTask {
    /// Standard sizes: 200 washout, `train` and `test` effective steps.
    pub fn new(train: usize, test: usize, seed: u64) -> Self {
        let washout = 200;
        let mut rng = Pcg64::new(seed, 3);
        let input = rng.uniform_vec(washout + train + test, -0.8, 0.8);
        Self {
            input,
            washout,
            train,
            test,
        }
    }

    pub fn len(&self) -> usize {
        self.input.len()
    }

    pub fn is_empty(&self) -> bool {
        self.input.is_empty()
    }

    pub fn input_mat(&self) -> Mat {
        Mat::from_rows(self.len(), 1, &self.input)
    }

    /// Delayed target `u(t−k)` for state row `t` (rows `< k` have no valid
    /// target; callers only use rows ≥ washout ≥ max delay).
    fn delayed(&self, t: usize, k: usize) -> f64 {
        if t >= k {
            self.input[t - k]
        } else {
            0.0
        }
    }

    /// Compute `MC_k` for each `k in 1..=k_max`, given the precomputed
    /// state/feature matrix `[T × F]` (one row per time step, aligned with
    /// `input`: row `t` is the state after consuming `u(t)`).
    ///
    /// A separate ridge readout is fit per delay on the train split and
    /// the determination coefficient is evaluated on the test split.
    pub fn capacities(&self, states: &Mat, k_max: usize, alpha: f64) -> Vec<f64> {
        assert_eq!(states.rows(), self.len());
        assert!(self.washout >= k_max, "washout must cover the max delay");
        let train_range = self.washout..self.washout + self.train;
        let test_range =
            self.washout + self.train..self.washout + self.train + self.test;

        let x_train = super::mso::slice_rows(states, train_range.clone());
        let x_test = super::mso::slice_rows(states, test_range.clone());

        let mut out = Vec::with_capacity(k_max);
        for k in 1..=k_max {
            let y_train = Mat::from_rows(
                train_range.len(),
                1,
                &train_range
                    .clone()
                    .map(|t| self.delayed(t, k))
                    .collect::<Vec<_>>(),
            );
            let readout = match fit(&x_train, &y_train, alpha, true, Regularizer::Identity)
            {
                Ok(r) => r,
                Err(_) => {
                    out.push(0.0);
                    continue;
                }
            };
            let pred = readout.predict(&x_test);
            let target: Vec<f64> =
                test_range.clone().map(|t| self.delayed(t, k)).collect();
            let pred_v: Vec<f64> = (0..pred.rows()).map(|i| pred[(i, 0)]).collect();
            let d = determination(&target, &pred_v);
            out.push(if d.is_finite() { d } else { 0.0 });
        }
        out
    }

    /// Total memory capacity `MC = Σ_k MC_k`.
    pub fn total_capacity(&self, states: &Mat, k_max: usize, alpha: f64) -> f64 {
        self.capacities(states, k_max, alpha).iter().sum()
    }

    /// Fast path for large sweeps (Fig 6/7): the Gram matrix `XᵀX + αI` is
    /// the SAME for every delay — factor it once, then back-substitute one
    /// rhs per delay. O(F³ + k_max·F²) instead of O(k_max·F³).
    pub fn capacities_fast(&self, states: &Mat, k_max: usize, alpha: f64) -> Vec<f64> {
        self.capacities_fast_reg(states, k_max, alpha, None)
    }

    /// [`capacities_fast`] with an optional generalized Tikhonov matrix
    /// `R` for the feature block (`G += α·R` instead of `α·I`) — Theorem 1
    /// (iv): with `R = QᵀQ`, training in the eigenbasis is EXACTLY
    /// equivalent to plain ridge on the standard states (the paper's Fig-7
    /// Diagonalization column).
    pub fn capacities_fast_reg(
        &self,
        states: &Mat,
        k_max: usize,
        alpha: f64,
        reg: Option<&Mat>,
    ) -> Vec<f64> {
        use crate::linalg::{Cholesky, Lu, Mat as M};
        assert_eq!(states.rows(), self.len());
        assert!(self.washout >= k_max, "washout must cover the max delay");
        let train_range = self.washout..self.washout + self.train;
        let test_range =
            self.washout + self.train..self.washout + self.train + self.test;
        let x_train = super::mso::slice_rows(states, train_range.clone());
        let x_test = super::mso::slice_rows(states, test_range.clone());
        let f = x_train.cols();
        let t_len = x_train.rows();
        let ext = f + 1; // + bias

        // G = [XᵀX, Xᵀ1; 1ᵀX, T] + αI
        let mut g = M::zeros(ext, ext);
        for t in 0..t_len {
            let row = x_train.row(t);
            for i in 0..f {
                let xi = row[i];
                if xi == 0.0 {
                    continue;
                }
                let gi = g.row_mut(i);
                for j in i..f {
                    gi[j] += xi * row[j];
                }
            }
        }
        for i in 0..f {
            for j in 0..i {
                g[(i, j)] = g[(j, i)];
            }
        }
        for i in 0..f {
            let s: f64 = (0..t_len).map(|t| x_train[(t, i)]).sum();
            g[(i, f)] = s;
            g[(f, i)] = s;
        }
        g[(f, f)] = t_len as f64;
        match reg {
            None => {
                for i in 0..ext {
                    g[(i, i)] += alpha;
                }
            }
            Some(r) => {
                assert_eq!(r.rows(), f, "Tikhonov matrix must match features");
                for i in 0..f {
                    for j in 0..f {
                        g[(i, j)] += alpha * r[(i, j)];
                    }
                }
                g[(f, f)] += alpha;
            }
        }

        enum Factor {
            Chol(Cholesky),
            Lu(Lu),
        }
        let factor = match Cholesky::factor(&g) {
            Ok(c) => Factor::Chol(c),
            Err(_) => Factor::Lu(Lu::factor(&g)),
        };

        let mut out = Vec::with_capacity(k_max);
        let mut rhs = vec![0.0; ext];
        for k in 1..=k_max {
            rhs.fill(0.0);
            for (row, t) in train_range.clone().enumerate() {
                let target = self.delayed(t, k);
                let xr = x_train.row(row);
                for i in 0..f {
                    rhs[i] += xr[i] * target;
                }
                rhs[f] += target;
            }
            let sol = match &factor {
                Factor::Chol(c) => c.solve_vec(&rhs),
                Factor::Lu(lu) => match lu.solve_vec(&rhs) {
                    Ok(s) => s,
                    Err(_) => {
                        out.push(0.0);
                        continue;
                    }
                },
            };
            // predictions on test
            let mut pred = Vec::with_capacity(test_range.len());
            for row in 0..x_test.rows() {
                let xr = x_test.row(row);
                let mut y = sol[f];
                for i in 0..f {
                    y += xr[i] * sol[i];
                }
                pred.push(y);
            }
            let target: Vec<f64> =
                test_range.clone().map(|t| self.delayed(t, k)).collect();
            let d = determination(&target, &pred);
            out.push(if d.is_finite() { d } else { 0.0 });
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::reservoir::{EsnConfig, StandardEsn};

    #[test]
    fn input_in_range() {
        let task = McTask::new(100, 100, 1);
        assert!(task.input.iter().all(|x| (-0.8..0.8).contains(x)));
        assert_eq!(task.len(), 400);
    }

    #[test]
    fn identity_shift_reservoir_has_perfect_short_memory() {
        // A hand-built delay-line reservoir: r_j(t) = u(t−j). MC_k must be
        // ≈1 for k ≤ N and the features trivially linear.
        let n = 5;
        let task = McTask::new(150, 150, 2);
        let t_len = task.len();
        let mut states = Mat::zeros(t_len, n);
        for t in 0..t_len {
            for j in 0..n {
                if t >= j {
                    states[(t, j)] = task.input[t - j];
                }
            }
        }
        let caps = task.capacities(&states, 4, 1e-9);
        for (k, c) in caps.iter().enumerate() {
            assert!(*c > 0.999, "MC_{} = {c}", k + 1);
        }
    }

    #[test]
    fn fast_path_matches_slow_path() {
        let esn = StandardEsn::generate(
            EsnConfig::default().with_n(20).with_sr(1.0).with_seed(9),
        );
        let task = McTask::new(200, 200, 9);
        let states = esn.run(&task.input_mat());
        let slow = task.capacities(&states, 15, 1e-7);
        let fast = task.capacities_fast(&states, 15, 1e-7);
        for (k, (a, b)) in slow.iter().zip(&fast).enumerate() {
            assert!((a - b).abs() < 1e-6, "k={} {a} vs {b}", k + 1);
        }
    }

    #[test]
    fn random_reservoir_memory_decays_with_delay() {
        let esn = StandardEsn::generate(
            EsnConfig::default().with_n(50).with_sr(1.0).with_seed(3),
        );
        let task = McTask::new(300, 300, 4);
        let states = esn.run(&task.input_mat());
        let caps = task.capacities(&states, 60, 1e-7);
        // short delays nearly perfect, long delays collapse
        assert!(caps[0] > 0.9, "MC_1 = {}", caps[0]);
        assert!(caps[59] < 0.5, "MC_60 = {}", caps[59]);
        // total capacity bounded by N (Jaeger's theorem)
        let total: f64 = caps.iter().sum();
        assert!(total < 50.0 + 1.0, "MC = {total}");
    }
}
