//! NARMA-10 — a standard nonlinear autoregressive benchmark, included as
//! an extra workload beyond the paper's evaluation (its conclusion points
//! at nonlinear-readout extensions; NARMA is the conventional stressor).
//!
//! `y(t+1) = 0.3·y(t) + 0.05·y(t)·Σ_{i=0..9} y(t−i) + 1.5·u(t−9)·u(t) + 0.1`

use crate::linalg::Mat;
use crate::rng::{Distributions, Pcg64};

/// NARMA-10 input/target pair generator.
#[derive(Clone, Debug)]
pub struct NarmaTask {
    pub input: Vec<f64>,
    pub target: Vec<f64>,
}

impl NarmaTask {
    /// Generate a sequence of length `len` with `u(t) ~ U(0, 0.5)`.
    pub fn new(len: usize, seed: u64) -> Self {
        let order = 10;
        let mut rng = Pcg64::new(seed, 4);
        let u = rng.uniform_vec(len, 0.0, 0.5);
        let mut y = vec![0.0f64; len];
        for t in order - 1..len - 1 {
            let sum_y: f64 = (0..order).map(|i| y[t - i]).sum();
            let v = 0.3 * y[t] + 0.05 * y[t] * sum_y + 1.5 * u[t - 9] * u[t] + 0.1;
            // saturation guard (standard practice: NARMA can diverge)
            y[t + 1] = v.clamp(-10.0, 10.0);
        }
        Self {
            input: u,
            target: y,
        }
    }

    pub fn len(&self) -> usize {
        self.input.len()
    }

    pub fn is_empty(&self) -> bool {
        self.input.is_empty()
    }

    pub fn input_mat(&self) -> Mat {
        Mat::from_rows(self.len(), 1, &self.input)
    }

    pub fn target_mat(&self, range: std::ops::Range<usize>) -> Mat {
        let s = &self.target[range];
        Mat::from_rows(s.len(), 1, s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bounded_and_nontrivial() {
        let t = NarmaTask::new(2000, 1);
        assert!(t.target.iter().all(|y| y.is_finite() && y.abs() <= 10.0));
        let var: f64 = {
            let m = t.target.iter().sum::<f64>() / 2000.0;
            t.target.iter().map(|y| (y - m) * (y - m)).sum::<f64>() / 2000.0
        };
        assert!(var > 1e-4, "target variance {var}");
    }

    #[test]
    fn deterministic_per_seed() {
        let a = NarmaTask::new(100, 7);
        let b = NarmaTask::new(100, 7);
        assert_eq!(a.target, b.target);
        let c = NarmaTask::new(100, 8);
        assert_ne!(a.target, c.target);
    }
}
