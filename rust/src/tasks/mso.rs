//! Multiple Superimposed Oscillators (MSO) — paper §5.1 / Fig 4.
//!
//! `U_K(t) = Σ_{k=1..K} sin(α_k·t)` with the 12 Gallicchio et al. (2017)
//! frequencies. Task: one-step-ahead prediction with teacher forcing.
//! Splits follow the paper exactly: 1000 steps = 400 train (first 100 are
//! washout) + 300 validation + 300 test.

use crate::linalg::Mat;

/// The 12 angular frequencies α₁…α₁₂ (Gallicchio et al. 2017).
pub const ALPHAS: [f64; 12] = [
    0.2, 0.331, 0.42, 0.51, 0.63, 0.74, 0.85, 0.97, 1.08, 1.19, 1.27, 1.32,
];

/// Paper split sizes.
pub const T_TRAIN: usize = 400;
pub const T_WASHOUT: usize = 100;
pub const T_VALID: usize = 300;
pub const T_TEST: usize = 300;
pub const T_TOTAL: usize = T_TRAIN + T_VALID + T_TEST;

/// `U_K(t)` for `t = 0..len` (the paper's Eq. 22; t is the integer step).
pub fn mso_series(k: usize, len: usize) -> Vec<f64> {
    assert!(
        (1..=ALPHAS.len()).contains(&k),
        "K must be in 1..=12, got {k}"
    );
    (0..len)
        .map(|t| {
            ALPHAS[..k]
                .iter()
                .map(|a| (a * t as f64).sin())
                .sum::<f64>()
        })
        .collect()
}

/// One-step-ahead MSO task with the paper's train/valid/test partition.
#[derive(Clone, Debug)]
pub struct MsoTask {
    pub k: usize,
    /// Input `u(t) = U_K(t)` for `t = 0..T_TOTAL`.
    pub input: Vec<f64>,
    /// Target `y(t) = U_K(t+1)`.
    pub target: Vec<f64>,
}

/// Index ranges of each split (into `input` / `target` / state rows).
pub struct Splits {
    pub washout: std::ops::Range<usize>,
    pub train: std::ops::Range<usize>,
    pub valid: std::ops::Range<usize>,
    pub test: std::ops::Range<usize>,
}

impl MsoTask {
    pub fn new(k: usize) -> Self {
        let series = mso_series(k, T_TOTAL + 1);
        let input = series[..T_TOTAL].to_vec();
        let target = series[1..=T_TOTAL].to_vec();
        Self { k, input, target }
    }

    pub fn splits() -> Splits {
        Splits {
            washout: 0..T_WASHOUT,
            train: T_WASHOUT..T_TRAIN,
            valid: T_TRAIN..T_TRAIN + T_VALID,
            test: T_TRAIN + T_VALID..T_TOTAL,
        }
    }

    /// Input as a `[T × 1]` matrix (the engines' expected shape).
    pub fn input_mat(&self) -> Mat {
        Mat::from_rows(self.input.len(), 1, &self.input)
    }

    /// Target rows for an index range, as `[len × 1]`.
    pub fn target_mat(&self, range: std::ops::Range<usize>) -> Mat {
        let slice = &self.target[range];
        Mat::from_rows(slice.len(), 1, slice)
    }
}

/// Row-slice helper shared by the experiment drivers: copy `range` rows of
/// `m` into a fresh matrix.
pub fn slice_rows(m: &Mat, range: std::ops::Range<usize>) -> Mat {
    let mut out = Mat::zeros(range.len(), m.cols());
    for (dst, src) in range.enumerate() {
        out.row_mut(dst).copy_from_slice(m.row(src));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn series_is_sum_of_sines() {
        let s = mso_series(2, 10);
        for (t, &v) in s.iter().enumerate() {
            let want = (0.2 * t as f64).sin() + (0.331 * t as f64).sin();
            assert!((v - want).abs() < 1e-12);
        }
    }

    #[test]
    fn mso1_bounded_by_one() {
        let s = mso_series(1, 1000);
        assert!(s.iter().all(|v| v.abs() <= 1.0 + 1e-12));
    }

    #[test]
    fn mso12_uses_all_frequencies() {
        let s = mso_series(12, 1000);
        let max = s.iter().cloned().fold(f64::MIN, f64::max);
        assert!(max > 6.0, "superposition should reach near 12, got {max}");
    }

    #[test]
    fn task_target_is_shifted_input() {
        let task = MsoTask::new(5);
        for t in 0..T_TOTAL - 1 {
            assert_eq!(task.target[t], task.input[t + 1]);
        }
        assert_eq!(task.input.len(), T_TOTAL);
        assert_eq!(task.target.len(), T_TOTAL);
    }

    #[test]
    fn splits_partition_the_series() {
        let s = MsoTask::splits();
        assert_eq!(s.washout.end, s.train.start);
        assert_eq!(s.train.end, s.valid.start);
        assert_eq!(s.valid.end, s.test.start);
        assert_eq!(s.test.end, T_TOTAL);
        assert_eq!(s.train.len(), 300);
        assert_eq!(s.valid.len(), 300);
        assert_eq!(s.test.len(), 300);
    }

    #[test]
    fn slice_rows_copies() {
        let m = Mat::from_rows(4, 2, &[1., 2., 3., 4., 5., 6., 7., 8.]);
        let s = slice_rows(&m, 1..3);
        assert_eq!(s.data(), &[3., 4., 5., 6.]);
    }

    #[test]
    #[should_panic(expected = "K must be")]
    fn rejects_k_13() {
        mso_series(13, 10);
    }
}
