//! Config-file experiment runner: a declarative JSON description of a
//! single train-and-evaluate experiment, so downstream users can drive the
//! framework without writing Rust (`repro run --config exp.json`).
//!
//! ```json
//! {
//!   "task":      {"kind": "mso", "k": 5},            // | {"kind":"narma","len":2000}
//!   "method":    {"kind": "dpg_golden", "sigma": 0.2}, // | normal | diagonalized
//!                                                      // | dpg_uniform | dpg_sim
//!   "reservoir": {"n": 100, "spectral_radius": 0.9, "leak_rate": 1.0,
//!                 "input_scaling": 1.0, "connectivity": 1.0},
//!   "train":     {"alpha": 1e-8, "washout": 100, "train_end": 700},
//!   "seed": 0
//! }
//! ```

use anyhow::{anyhow, bail, Context, Result};

use crate::linalg::Mat;
use crate::metrics::{nrmse, rmse};
use crate::readout::{fit, Regularizer};
use crate::reservoir::{DiagonalEsn, EsnConfig, StandardEsn};
use crate::rng::Pcg64;
use crate::spectral::golden::{golden_spectrum, GoldenParams};
use crate::spectral::sim::sim_spectrum;
use crate::spectral::uniform::uniform_spectrum;
use crate::tasks::mso::slice_rows;
use crate::util::json::{parse, Json};

/// Parsed experiment description.
pub struct ExperimentSpec {
    pub task: TaskSpec,
    pub method: String,
    pub sigma: f64,
    pub config: EsnConfig,
    pub alpha: f64,
    pub washout: usize,
    pub train_end: usize,
}

pub enum TaskSpec {
    Mso { k: usize },
    Narma { len: usize },
}

/// Outcome of a config run.
pub struct ExperimentResult {
    pub test_rmse: f64,
    pub test_nrmse: f64,
    pub train_rows: usize,
    pub test_rows: usize,
}

impl ExperimentSpec {
    pub fn from_json_str(text: &str) -> Result<Self> {
        let v = parse(text).context("parsing experiment config")?;
        let get = |path: &[&str]| -> Option<&Json> {
            let mut cur = &v;
            for p in path {
                cur = cur.get(p)?;
            }
            Some(cur)
        };
        let num = |path: &[&str], default: f64| -> f64 {
            get(path).and_then(Json::as_f64).unwrap_or(default)
        };

        let task = match get(&["task", "kind"]).and_then(Json::as_str) {
            Some("mso") => TaskSpec::Mso {
                k: num(&["task", "k"], 5.0) as usize,
            },
            Some("narma") => TaskSpec::Narma {
                len: num(&["task", "len"], 2000.0) as usize,
            },
            other => bail!("unknown task kind {other:?}"),
        };
        let method = get(&["method", "kind"])
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("missing method.kind"))?
            .to_string();
        let sigma = num(&["method", "sigma"], 0.0);
        let config = EsnConfig::default()
            .with_n(num(&["reservoir", "n"], 100.0) as usize)
            .with_sr(num(&["reservoir", "spectral_radius"], 0.9))
            .with_leak(num(&["reservoir", "leak_rate"], 1.0))
            .with_input_scaling(num(&["reservoir", "input_scaling"], 1.0))
            .with_connectivity(num(&["reservoir", "connectivity"], 1.0))
            .with_seed(num(&["seed"], 0.0) as u64);
        Ok(Self {
            task,
            method,
            sigma,
            config,
            alpha: num(&["train", "alpha"], 1e-8),
            washout: num(&["train", "washout"], 100.0) as usize,
            train_end: num(&["train", "train_end"], 700.0) as usize,
        })
    }

    /// Build, run, train, evaluate.
    pub fn execute(&self) -> Result<ExperimentResult> {
        let (input, target): (Vec<f64>, Vec<f64>) = match self.task {
            TaskSpec::Mso { k } => {
                let t = crate::tasks::mso::MsoTask::new(k);
                (t.input, t.target)
            }
            TaskSpec::Narma { len } => {
                let t = crate::tasks::narma::NarmaTask::new(len, self.config.seed);
                let target = t.target.clone();
                (t.input, target)
            }
        };
        let t_total = input.len();
        anyhow::ensure!(
            self.washout < self.train_end && self.train_end < t_total,
            "washout < train_end < {t_total} violated"
        );
        let u = Mat::from_rows(t_total, 1, &input);

        let states = self.build_states(&u)?;
        let train = self.washout..self.train_end;
        let test = self.train_end..t_total;
        let x_train = slice_rows(&states, train.clone());
        let y_train = Mat::from_rows(train.len(), 1, &target[train.clone()]);
        let readout = fit(&x_train, &y_train, self.alpha, true, Regularizer::Identity)?;
        let x_test = slice_rows(&states, test.clone());
        let y_test = Mat::from_rows(test.len(), 1, &target[test.clone()]);
        let pred = readout.predict(&x_test);
        Ok(ExperimentResult {
            test_rmse: rmse(&pred, &y_test),
            test_nrmse: nrmse(&pred, &y_test),
            train_rows: train.len(),
            test_rows: test.len(),
        })
    }

    fn build_states(&self, u: &Mat) -> Result<Mat> {
        let cfg = &self.config;
        let n = cfg.n;
        Ok(match self.method.as_str() {
            "normal" => StandardEsn::generate(*cfg).run(u),
            "diagonalized" => {
                let esn = StandardEsn::generate(*cfg);
                DiagonalEsn::from_standard(&esn)?.run(u)
            }
            "dpg_uniform" => {
                let mut rng = Pcg64::new(cfg.seed, 10);
                let spec = uniform_spectrum(n, cfg.spectral_radius, &mut rng);
                DiagonalEsn::from_dpg(spec, cfg, &mut rng).run(u)
            }
            "dpg_golden" => {
                let mut rng = Pcg64::new(cfg.seed, 10);
                let spec = golden_spectrum(
                    n,
                    GoldenParams {
                        sr: cfg.spectral_radius,
                        sigma: self.sigma,
                    },
                    &mut rng,
                );
                DiagonalEsn::from_dpg(spec, cfg, &mut rng).run(u)
            }
            "dpg_sim" => {
                let mut rng = Pcg64::new(cfg.seed, 10);
                let spec =
                    sim_spectrum(n, cfg.connectivity, cfg.spectral_radius, &mut rng);
                DiagonalEsn::from_dpg(spec, cfg, &mut rng).run(u)
            }
            other => bail!("unknown method {other:?}"),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "task": {"kind": "mso", "k": 2},
      "method": {"kind": "dpg_golden", "sigma": 0.0},
      "reservoir": {"n": 60, "spectral_radius": 0.9},
      "train": {"alpha": 1e-9, "washout": 100, "train_end": 700},
      "seed": 1
    }"#;

    #[test]
    fn parses_and_runs() {
        let spec = ExperimentSpec::from_json_str(SAMPLE).unwrap();
        assert_eq!(spec.config.n, 60);
        let r = spec.execute().unwrap();
        assert!(r.test_rmse < 1e-3, "rmse {}", r.test_rmse);
        assert_eq!(r.train_rows, 600);
        assert_eq!(r.test_rows, 300);
    }

    #[test]
    fn every_method_runs_from_config() {
        for method in ["normal", "diagonalized", "dpg_uniform", "dpg_sim"] {
            let text = SAMPLE.replace("dpg_golden", method);
            let spec = ExperimentSpec::from_json_str(&text).unwrap();
            let r = spec.execute().unwrap();
            assert!(r.test_rmse < 1e-2, "{method}: {}", r.test_rmse);
        }
    }

    #[test]
    fn narma_from_config() {
        let text = r#"{
          "task": {"kind": "narma", "len": 1500},
          "method": {"kind": "normal"},
          "reservoir": {"n": 80, "spectral_radius": 0.95},
          "train": {"alpha": 1e-6, "washout": 200, "train_end": 1000},
          "seed": 2
        }"#;
        let r = ExperimentSpec::from_json_str(text).unwrap().execute().unwrap();
        assert!(r.test_nrmse < 1.0, "nrmse {}", r.test_nrmse);
    }

    #[test]
    fn rejects_bad_configs() {
        assert!(ExperimentSpec::from_json_str("{}").is_err());
        let bad_task = SAMPLE.replace("mso", "lorenz");
        assert!(ExperimentSpec::from_json_str(&bad_task).is_err());
        let bad_split = SAMPLE.replace("700", "50");
        let spec = ExperimentSpec::from_json_str(&bad_split).unwrap();
        assert!(spec.execute().is_err());
    }
}
