//! Minimal work-stealing-ish worker pool over std::thread + channels
//! (tokio is not in the offline registry). Jobs are `FnOnce` closures;
//! results come back over a channel in completion order with their job
//! index, so callers can reassemble deterministic output.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

/// Fixed-size worker pool executing boxed jobs.
pub struct WorkerPool {
    workers: Vec<JoinHandle<()>>,
    sender: Option<mpsc::Sender<Job>>,
}

type Job = Box<dyn FnOnce() + Send + 'static>;

impl WorkerPool {
    /// Spawn `threads` workers (≥ 1; use
    /// [`suggested_threads`] for a default).
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let (sender, receiver) = mpsc::channel::<Job>();
        let receiver = Arc::new(Mutex::new(receiver));
        let workers = (0..threads)
            .map(|i| {
                let rx = Arc::clone(&receiver);
                std::thread::Builder::new()
                    .name(format!("lr-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // channel closed: shut down
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        Self {
            workers,
            sender: Some(sender),
        }
    }

    /// Submit a raw job.
    pub fn submit(&self, job: impl FnOnce() + Send + 'static) {
        self.sender
            .as_ref()
            .expect("pool already shut down")
            .send(Box::new(job))
            .expect("worker pool hung up");
    }

    /// Map `inputs` through `f` across the pool, preserving input order.
    pub fn map<I, O, F>(&self, inputs: Vec<I>, f: F) -> Vec<O>
    where
        I: Send + 'static,
        O: Send + 'static,
        F: Fn(I) -> O + Send + Sync + 'static,
    {
        let n = inputs.len();
        let f = Arc::new(f);
        let (tx, rx) = mpsc::channel::<(usize, O)>();
        for (idx, input) in inputs.into_iter().enumerate() {
            let tx = tx.clone();
            let f = Arc::clone(&f);
            self.submit(move || {
                let out = f(input);
                let _ = tx.send((idx, out));
            });
        }
        drop(tx);
        let mut slots: Vec<Option<O>> = (0..n).map(|_| None).collect();
        for (idx, out) in rx.iter() {
            slots[idx] = Some(out);
        }
        slots.into_iter().map(|s| s.expect("job lost")).collect()
    }

    pub fn threads(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.sender.take(); // close channel → workers exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Default parallelism: available cores (this container exposes 1; the
/// pool still structures the computation for larger hosts).
pub fn suggested_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn map_preserves_order() {
        let pool = WorkerPool::new(4);
        let out = pool.map((0..100).collect(), |x: usize| x * x);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn all_jobs_run() {
        let pool = WorkerPool::new(3);
        let counter = Arc::new(AtomicUsize::new(0));
        let c2 = Arc::clone(&counter);
        let out = pool.map(vec![(); 50], move |_| {
            c2.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(out.len(), 50);
        assert_eq!(counter.load(Ordering::SeqCst), 50);
    }

    #[test]
    fn drop_joins_cleanly() {
        let pool = WorkerPool::new(2);
        pool.submit(|| std::thread::sleep(std::time::Duration::from_millis(5)));
        drop(pool); // must not hang or panic
    }

    #[test]
    fn single_thread_pool_works() {
        let pool = WorkerPool::new(1);
        let out = pool.map(vec![1, 2, 3], |x: i32| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }
}
