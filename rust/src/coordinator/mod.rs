//! Experiment coordination: a from-scratch worker pool ([`pool`]) and the
//! grid-search orchestrator ([`grid`]) that drives the paper's model
//! selection (Table 1 grid → Table 2 scores) with the state-reuse
//! scheduling the paper describes in §5.1 (states computed once per seed
//! and shared across the input-scaling and α sweeps).

pub mod experiment;
pub mod grid;
pub mod pool;

pub use experiment::{ExperimentResult, ExperimentSpec};
pub use grid::{GridSearch, GridSpec, MethodKind, TrialResult};
pub use pool::WorkerPool;
