//! Grid-search orchestrator: the paper's model-selection protocol
//! (Table 1 grid, validation-selected, test-reported — Table 2).
//!
//! Scheduling exploits the linear-system structure exactly as §5.1
//! describes: per (seed, method, ρ, lr) the reservoir trajectory is
//! computed ONCE at unit input scaling; the input-scaling sweep reuses it
//! via `X(s·W_in) = s·X(W_in)` (Theorem 5 / D_in = 1 linearity) and the α
//! sweep reuses the Gram statistics — `|scales|·|alphas|` ridge solves per
//! trajectory instead of `|scales|·|alphas|` full re-runs (×36 with the
//! paper grid).
//!
//! Diagonal methods (EET + every DPG flavor) run their per-grid-point
//! trajectory through the **fused training scan**
//! ([`run_parallel_batch_train`]): the batched time-parallel chunk scan
//! feeds the train span's feature rows straight into streaming Gram
//! accumulators shared across the worker pool, so the grid never
//! materializes a `[T × F]` training block — only the validation/test
//! spans become matrices (they are what `predict_scaled` consumes).
//! Fusing is numerically free: it is bit-identical to materializing the
//! same chunked scan and running `GramStats::new` (tested below). The
//! trajectories themselves now come from the chunked scan instead of
//! the sequential interleaved engine, which moves per-point RMSEs at
//! the scan-association level (≲1e-9 — `run_parallel`'s documented
//! tolerance vs the sequential run); results stay deterministic per
//! seed. The `Normal` baseline keeps the materialize-then-
//! `GramStats::new` path (its `O(N²)`-per-step engine has no diagonal
//! scan).

use anyhow::Result;

use crate::linalg::Mat;
use crate::metrics::rmse;
use crate::readout::{predict_scaled, GramStats};
use crate::reservoir::parallel::{run_parallel_batch_train, TrainSpec};
use crate::reservoir::{DiagonalEsn, EsnConfig, StandardEsn};

use super::pool::{suggested_threads, WorkerPool};
use crate::rng::Pcg64;
use crate::spectral::eigvecs::random_eigvecs;
use crate::spectral::golden::{golden_spectrum, GoldenParams};
use crate::spectral::sim::sim_spectrum;
use crate::spectral::uniform::uniform_spectrum;
use crate::spectral::Spectrum;
use crate::tasks::mso::{slice_rows, MsoTask};

/// The six Table-2 methods.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum MethodKind {
    /// Standard linear ESN with an explicit `W` (§2).
    Normal,
    /// EET: the SAME `W` as Normal, diagonalized; readout trained in the
    /// eigenbasis (§4.3).
    Diagonalized,
    /// DPG with Algorithm-1 eigenvalues.
    DpgUniform,
    /// DPG with Algorithm-3 eigenvalues (σ = 0 → deterministic Golden).
    DpgGolden { sigma: f64 },
    /// DPG with eigenvalues of a real random matrix + random eigenvectors.
    DpgSim,
}

impl MethodKind {
    pub fn label(&self) -> String {
        match self {
            MethodKind::Normal => "normal".into(),
            MethodKind::Diagonalized => "diagonalized".into(),
            MethodKind::DpgUniform => "uniform".into(),
            MethodKind::DpgGolden { sigma } if *sigma == 0.0 => "golden".into(),
            MethodKind::DpgGolden { sigma } => format!("noisy_golden_{sigma}"),
            MethodKind::DpgSim => "sim".into(),
        }
    }

    /// The paper's Table-2 column set.
    pub fn table2_set() -> Vec<MethodKind> {
        vec![
            MethodKind::Normal,
            MethodKind::Diagonalized,
            MethodKind::DpgUniform,
            MethodKind::DpgGolden { sigma: 0.0 },
            MethodKind::DpgGolden { sigma: 0.2 },
            MethodKind::DpgSim,
        ]
    }
}

/// Hyper-parameter grid (Table 1).
#[derive(Clone, Debug)]
pub struct GridSpec {
    pub input_scalings: Vec<f64>,
    pub leak_rates: Vec<f64>,
    pub spectral_radii: Vec<f64>,
    pub alphas: Vec<f64>,
}

impl GridSpec {
    /// The exact Table-1 grid (3 × 6 × 6 × 12 = 1296 configurations).
    pub fn paper_table1() -> Self {
        Self {
            input_scalings: vec![0.01, 0.1, 1.0],
            leak_rates: vec![0.1, 0.3, 0.5, 0.7, 0.9, 1.0],
            spectral_radii: vec![0.1, 0.3, 0.5, 0.7, 0.9, 1.0],
            alphas: (0..12).map(|e| 10f64.powi(e - 11)).collect(),
        }
    }

    /// Reduced grid for tests / smoke runs.
    pub fn quick() -> Self {
        Self {
            input_scalings: vec![0.1, 1.0],
            leak_rates: vec![0.5, 1.0],
            spectral_radii: vec![0.9, 1.0],
            alphas: vec![1e-8, 1e-4, 1e-1],
        }
    }

    pub fn size(&self) -> usize {
        self.input_scalings.len()
            * self.leak_rates.len()
            * self.spectral_radii.len()
            * self.alphas.len()
    }
}

/// Winning configuration + scores for one (method, seed, task).
#[derive(Clone, Debug)]
pub struct TrialResult {
    pub method: MethodKind,
    pub seed: u64,
    pub input_scaling: f64,
    pub leak_rate: f64,
    pub spectral_radius: f64,
    pub alpha: f64,
    pub valid_rmse: f64,
    pub test_rmse: f64,
}

/// A reservoir family able to produce unit-scaled feature trajectories for
/// any (ρ, lr) grid point. Created once per (method, seed): the expensive
/// parts (matrix generation, eigendecomposition, eigenvector sampling,
/// input projection) happen here, not per grid point.
enum Provider {
    Normal {
        /// Base `W` scaled to spectral radius 1.
        w0: Mat,
        /// Unit-scale `W_in` (input scaling / leak applied later).
        w_in: Mat,
    },
    Diag {
        /// Base spectrum with radius normalized to 1 (or the generator's
        /// native radius for Golden with noise — see `regen`).
        spec0: Spectrum,
        win_re: Mat,
        win_im: Mat,
        /// For Noisy Golden the paper adds UNSCALED noise after setting
        /// sr = ρ, so the spectrum must be regenerated per ρ.
        regen: Option<(u64, f64)>, // (seed, sigma)
    },
}

impl Provider {
    fn build(method: MethodKind, n: usize, connectivity: f64, seed: u64) -> Result<Self> {
        use crate::rng::Distributions;
        let mut rng = Pcg64::new(seed, 10);
        match method {
            MethodKind::Normal | MethodKind::Diagonalized => {
                // shared generation: Diagonalized IS Normal's reservoir in
                // the eigenbasis (Theorem 1)
                let cfg = EsnConfig::default()
                    .with_n(n)
                    .with_connectivity(connectivity)
                    .with_sr(1.0)
                    .with_seed(seed);
                let esn = StandardEsn::generate(cfg);
                match method {
                    MethodKind::Normal => Ok(Provider::Normal {
                        w0: esn.w_dense(),
                        w_in: esn.w_in.clone(),
                    }),
                    _ => {
                        let diag = DiagonalEsn::from_standard(&esn)?;
                        Ok(Provider::Diag {
                            spec0: diag.spec.clone(),
                            win_re: diag.win_re.clone(),
                            win_im: diag.win_im.clone(),
                            regen: None,
                        })
                    }
                }
            }
            MethodKind::DpgUniform | MethodKind::DpgSim | MethodKind::DpgGolden { .. } => {
                let spec0 = match method {
                    MethodKind::DpgUniform => uniform_spectrum(n, 1.0, &mut rng),
                    MethodKind::DpgSim => sim_spectrum(n, connectivity, 1.0, &mut rng),
                    MethodKind::DpgGolden { sigma } => golden_spectrum(
                        n,
                        GoldenParams { sr: 1.0, sigma },
                        &mut rng,
                    ),
                    _ => unreachable!(),
                };
                let basis = random_eigvecs(&spec0, &mut rng);
                let mut w_in = Mat::from_fn(1, n, |_, _| rng.uniform(-1.0, 1.0));
                let _ = &mut w_in; // D_in = 1, dense input weights
                // project W_in into the eigenbasis once
                let esn = {
                    let mut re = Mat::zeros(1, spec0.slots());
                    let mut im = Mat::zeros(1, spec0.slots());
                    for j in 0..spec0.slots() {
                        let mut acc = crate::num::c64::ZERO;
                        for i in 0..n {
                            acc += basis.cols[(i, j)] * w_in[(0, i)];
                        }
                        re[(0, j)] = acc.re;
                        im[(0, j)] = acc.im;
                    }
                    (re, im)
                };
                let regen = match method {
                    MethodKind::DpgGolden { sigma } if sigma > 0.0 => {
                        Some((seed, sigma))
                    }
                    _ => None,
                };
                Ok(Provider::Diag {
                    spec0,
                    win_re: esn.0,
                    win_im: esn.1,
                    regen,
                })
            }
        }
    }

    /// The diagonal engine at unit input scaling for grid point (ρ, lr),
    /// when this provider is diagonal. Leak enters the spectrum here; the
    /// `lr` factor on `W_in` is deferred to the Gram scaling
    /// (`s = input_scaling·lr`). The fused training scan consumes this
    /// directly.
    fn diag_esn(&self, rho: f64, lr: f64) -> Option<DiagonalEsn> {
        match self {
            Provider::Normal { .. } => None,
            Provider::Diag {
                spec0,
                win_re,
                win_im,
                regen,
            } => {
                let spec = match regen {
                    Some((seed, sigma)) => {
                        // paper-faithful Noisy Golden: scale THEN noise
                        let mut rng = Pcg64::new(*seed, 10);
                        golden_spectrum(
                            spec0.n,
                            GoldenParams {
                                sr: rho,
                                sigma: *sigma,
                            },
                            &mut rng,
                        )
                    }
                    None => spec0.scaled(rho),
                }
                .apply_leak(lr);
                Some(DiagonalEsn::from_parts(
                    spec,
                    win_re.clone(),
                    win_im.clone(),
                    None,
                ))
            }
        }
    }

    /// Materialized feature trajectory at unit input scaling for grid
    /// point (ρ, lr) — the `Normal` baseline's only path (explicit `W`,
    /// no diagonal scan exists for it). Diagonal providers never come
    /// through here: the grid routes every one of them through the fused
    /// training scan ([`Provider::diag_esn`] is `Some` for all of them).
    fn features(&self, rho: f64, lr: f64, u: &Mat) -> Mat {
        match self {
            Provider::Normal { w0, w_in } => {
                let n = w0.rows();
                let mut w = w0.clone();
                w.scale(rho * lr);
                if lr < 1.0 {
                    w.add_diag(1.0 - lr);
                }
                let esn = StandardEsn::from_parts(
                    w,
                    w_in.clone(),
                    EsnConfig::default().with_n(n),
                );
                esn.run(u)
            }
            Provider::Diag { .. } => {
                unreachable!(
                    "diagonal providers run through the fused training scan"
                )
            }
        }
    }
}

/// Chunk length of the fused training scan inside the grid: a handful of
/// chunks per MSO-length sequence — enough to keep a multi-core pool
/// busy without drowning phase 2 in summaries.
const SCAN_CHUNK: usize = 256;

/// Grid-search runner for the MSO family.
pub struct GridSearch {
    pub spec: GridSpec,
    pub n: usize,
    pub connectivity: f64,
}

impl Default for GridSearch {
    fn default() -> Self {
        Self {
            spec: GridSpec::paper_table1(),
            n: 100,
            connectivity: 1.0,
        }
    }
}

impl GridSearch {
    /// Full protocol for one (task K, method, seed): sweep the grid,
    /// select by validation RMSE, report test RMSE.
    pub fn run_mso(&self, k: usize, method: MethodKind, seed: u64) -> Result<TrialResult> {
        let task = MsoTask::new(k);
        let splits = MsoTask::splits();
        let u = task.input_mat();
        let y_train = task.target_mat(splits.train.clone());
        let y_valid = task.target_mat(splits.valid.clone());
        let y_test = task.target_mat(splits.test.clone());

        let provider = Provider::build(method, self.n, self.connectivity, seed)?;
        // one pool shared by every grid point's fused scan — spawned
        // lazily on the first diagonal grid point, so the Normal
        // baseline (which never scans) spawns no threads at all. Scoped
        // per run_mso rather than hoisted to GridSearch: the struct's
        // public-field literal construction is API, and one pool spawn
        // per multi-second grid run is noise next to the scan itself.
        let mut pool: Option<WorkerPool> = None;

        let mut best: Option<TrialResult> = None;
        for &rho in &self.spec.spectral_radii {
            for &lr in &self.spec.leak_rates {
                let (stats, x_valid, x_test) = match provider.diag_esn(rho, lr) {
                    Some(esn) => {
                        // fused path: the batched scan streams the train
                        // span's rows into shared Gram accumulators; only
                        // the valid/test spans materialize
                        let pool = pool
                            .get_or_insert_with(|| WorkerPool::new(suggested_threads()));
                        let tspec = TrainSpec {
                            train: splits.train.clone(),
                            eval: vec![splits.valid.clone(), splits.test.clone()],
                        };
                        let (acc, mut evals) = run_parallel_batch_train(
                            &esn,
                            std::slice::from_ref(&u),
                            std::slice::from_ref(&y_train),
                            std::slice::from_ref(&tspec),
                            pool,
                            SCAN_CHUNK,
                        );
                        let mut spans = evals.pop().expect("one sequence");
                        let x_test = spans.pop().expect("test span");
                        let x_valid = spans.pop().expect("valid span");
                        (acc.finish(), x_valid, x_test)
                    }
                    None => {
                        let states = provider.features(rho, lr, &u);
                        (
                            GramStats::new(
                                &slice_rows(&states, splits.train.clone()),
                                &y_train,
                            ),
                            slice_rows(&states, splits.valid.clone()),
                            slice_rows(&states, splits.test.clone()),
                        )
                    }
                };
                for &scale_in in &self.spec.input_scalings {
                    let s = scale_in * lr;
                    for &alpha in &self.spec.alphas {
                        let readout = match stats.solve_scaled(alpha, s) {
                            Ok(r) => r,
                            Err(_) => continue,
                        };
                        let pv = predict_scaled(&readout, &x_valid, s);
                        let v = rmse(&pv, &y_valid);
                        if !v.is_finite() {
                            continue;
                        }
                        let better = best
                            .as_ref()
                            .map(|b| v < b.valid_rmse)
                            .unwrap_or(true);
                        if better {
                            let pt = predict_scaled(&readout, &x_test, s);
                            let t = rmse(&pt, &y_test);
                            best = Some(TrialResult {
                                method,
                                seed,
                                input_scaling: scale_in,
                                leak_rate: lr,
                                spectral_radius: rho,
                                alpha,
                                valid_rmse: v,
                                test_rmse: t,
                            });
                        }
                    }
                }
            }
        }
        best.ok_or_else(|| anyhow::anyhow!("no finite configuration found"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_grid_produces_sane_mso1_scores() {
        let gs = GridSearch {
            spec: GridSpec::quick(),
            n: 40,
            connectivity: 1.0,
        };
        for method in [
            MethodKind::Normal,
            MethodKind::DpgUniform,
            MethodKind::DpgGolden { sigma: 0.0 },
        ] {
            let r = gs.run_mso(1, method, 0).unwrap();
            assert!(
                r.test_rmse < 1e-2,
                "{method:?} test rmse {}",
                r.test_rmse
            );
            assert!(r.valid_rmse.is_finite());
        }
    }

    #[test]
    fn diagonalized_close_to_normal_on_mso1() {
        let gs = GridSearch {
            spec: GridSpec::quick(),
            n: 30,
            connectivity: 1.0,
        };
        let a = gs.run_mso(1, MethodKind::Normal, 1).unwrap();
        let b = gs.run_mso(1, MethodKind::Diagonalized, 1).unwrap();
        // same reservoir, different training basis: same order of magnitude
        assert!(b.test_rmse < a.test_rmse.max(1e-6) * 1e4 + 1e-4);
    }

    #[test]
    fn table1_grid_has_1296_points() {
        assert_eq!(GridSpec::paper_table1().size(), 1296);
    }

    #[test]
    fn results_deterministic_by_seed() {
        let gs = GridSearch {
            spec: GridSpec::quick(),
            n: 25,
            connectivity: 1.0,
        };
        let a = gs.run_mso(2, MethodKind::DpgUniform, 7).unwrap();
        let b = gs.run_mso(2, MethodKind::DpgUniform, 7).unwrap();
        assert_eq!(a.test_rmse, b.test_rmse);
        assert_eq!(a.alpha, b.alpha);
    }

    #[test]
    fn fused_grid_training_bit_identical_to_materialized_path() {
        // the grid's fused-scan consumption must be invisible: for a
        // diagonal method at one grid point, the streamed Gram fit and
        // the eval spans equal the materialize-then-GramStats::new
        // reference bit for bit
        let provider = Provider::build(MethodKind::DpgUniform, 24, 1.0, 3).unwrap();
        let task = MsoTask::new(1);
        let splits = MsoTask::splits();
        let u = task.input_mat();
        let y_train = task.target_mat(splits.train.clone());
        let pool = WorkerPool::new(2);
        let esn = provider.diag_esn(0.9, 0.5).expect("diag provider");
        let tspec = TrainSpec {
            train: splits.train.clone(),
            eval: vec![splits.valid.clone()],
        };
        let (acc, mut evals) = run_parallel_batch_train(
            &esn,
            std::slice::from_ref(&u),
            std::slice::from_ref(&y_train),
            std::slice::from_ref(&tspec),
            &pool,
            SCAN_CHUNK,
        );
        let states =
            crate::reservoir::parallel::run_parallel(&esn, &u, &pool, SCAN_CHUNK);
        let stats =
            GramStats::new(&slice_rows(&states, splits.train.clone()), &y_train);
        let a = acc.finish().solve_scaled(1e-6, 0.5).unwrap();
        let b = stats.solve_scaled(1e-6, 0.5).unwrap();
        assert_eq!(a.w.data(), b.w.data(), "fused grid fit diverged");
        assert_eq!(a.b, b.b);
        let x_valid = evals.pop().unwrap().pop().unwrap();
        assert_eq!(
            x_valid.data(),
            slice_rows(&states, splits.valid.clone()).data(),
            "fused eval span diverged"
        );
    }

    #[test]
    fn method_labels_unique() {
        let labels: Vec<String> = MethodKind::table2_set()
            .iter()
            .map(|m| m.label())
            .collect();
        let mut dedup = labels.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(dedup.len(), labels.len());
    }
}
