//! Multi-tenant model registry: DPG-minted per-tenant reservoirs.
//!
//! The paper's Direct Parameter Generation (§4.4) samples eigenvalues and
//! input weights directly, skipping the O(N³) eig step — operationally
//! that means a brand-new tenant model is minted in **O(N·d)** at request
//! time. The registry leans on a stronger form of the same idea: tenant
//! planes are sampled **directly in the eigenbasis** (`[W_in]_P`, not
//! `W_in` followed by a projection), so minting never touches an O(N²)
//! object at all — no `Q`, no dense anything. A 1000-mode tenant is three
//! O(N)-sized vectors.
//!
//! ## Determinism is the replication protocol
//!
//! A [`ModelRecipe`] is `{seed, n, spectral_radius, lambda_prior}` and
//! minting is a pure function of it: one freshly keyed [`Pcg64`] stream
//! drives the spectrum generator and the plane sampler in a fixed draw
//! order, so the same recipe produces **bit-identical planes on every
//! node**. Cluster failover therefore needs no model transfer — any owner
//! re-mints a tenant from its recipe (see `cluster.rs`); checkpoints and
//! standby deltas keep carrying only lane state, never parameters.
//!
//! ## Identity and sharing
//!
//! [`ModelId`] is FNV-1a over the canonical recipe bytes masked to 53
//! bits (wire ids travel as JSON numbers = f64; 2⁵³ is the exact-integer
//! ceiling), with id 0 reserved for the base (deployed) model. `create`
//! is idempotent: re-creating an existing recipe hands back an
//! `Arc`-clone of the already-minted model, so tenants sharing a template
//! share one copy of the λ/input/readout planes — copy-on-write at the
//! model granularity (a future `train`+`commit` on a lane clones only
//! that lane's readout, never the shared planes).
//!
//! ## Budget
//!
//! `max_models` bounds registry size. The check runs **before** any
//! allocation: a refused `create_model` (typed `model_budget` on the
//! wire) has minted nothing — chaos-tested in `rust/tests/chaos.rs`.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::linalg::Mat;
use crate::readout::Readout;
use crate::reservoir::DiagonalEsn;
use crate::rng::{Distributions, Pcg64};
use crate::spectral::uniform::{ring_spectrum, uniform_spectrum};

use super::cluster::fnv1a;
use super::{Model, Precision};

/// Per-tenant model identity. 0 is the base (deployed) model; minted ids
/// are nonzero and fit exactly in an f64 (≤ 53 bits) so they round-trip
/// JSON without loss.
pub type ModelId = u64;

/// The base model's reserved id.
pub const BASE_MODEL: ModelId = 0;

/// Largest tenant reservoir the wire accepts — a sanity bound, not a
/// memory budget (that's `--max-models`): N=65536 f64 planes are ~1.5 MB,
/// well under any realistic per-tenant budget, while a fat-fingered
/// `"n": 1e12` is refused before allocation.
pub const MAX_TENANT_N: usize = 65_536;

/// Upper sanity bound on a tenant's requested spectral radius (serving a
/// wildly unstable reservoir helps nobody; the paper's grids top out well
/// below this).
pub const MAX_TENANT_SR: f64 = 2.0;

/// Stream constant keying the mint RNG — distinct from every other Pcg64
/// stream in the crate so recipe seeds can't collide with experiment
/// seeds.
const MINT_STREAM: u64 = 0x4d4f_4445_4c52_4547; // "MODELREG"

/// Eigenvalue prior for the DPG sampler.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LambdaPrior {
    /// Disk-uniform placement (the paper's Algorithm 1) — mixed
    /// timescales, the default.
    Uniform,
    /// Every mode on the circle `|λ| = sr` — the long-memory placement
    /// (arXiv 1707.02469): maximal uniform timescale.
    Ring,
}

impl LambdaPrior {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "uniform" => Some(LambdaPrior::Uniform),
            "ring" => Some(LambdaPrior::Ring),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            LambdaPrior::Uniform => "uniform",
            LambdaPrior::Ring => "ring",
        }
    }
}

/// Everything needed to mint a tenant model, anywhere, bit-identically.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ModelRecipe {
    pub seed: u64,
    pub n: usize,
    pub spectral_radius: f64,
    pub lambda_prior: LambdaPrior,
}

impl ModelRecipe {
    /// Build and validate a recipe in one step — the wire layer's (and
    /// tests') entry point. `prior` is the wire-level name (`"uniform"` /
    /// `"ring"`); errors are human-readable refusal reasons (wire code
    /// `bad_request`).
    pub fn new(
        seed: u64,
        n: usize,
        spectral_radius: f64,
        prior: &str,
    ) -> Result<Self, String> {
        let lambda_prior = LambdaPrior::parse(prior)
            .ok_or_else(|| format!("unknown lambda_prior {prior:?}"))?;
        let recipe = Self {
            seed,
            n,
            spectral_radius,
            lambda_prior,
        };
        recipe.validate()?;
        Ok(recipe)
    }

    /// Validate the sanity bounds shared by both transports. Returns a
    /// human-readable refusal reason (wire code `bad_request`).
    pub fn validate(&self) -> Result<(), String> {
        if self.n == 0 || self.n > MAX_TENANT_N {
            return Err(format!(
                "n must be in 1..={MAX_TENANT_N}, got {}",
                self.n
            ));
        }
        if !(self.spectral_radius > 0.0)
            || !(self.spectral_radius <= MAX_TENANT_SR)
        {
            return Err(format!(
                "spectral_radius must be in (0, {MAX_TENANT_SR}], got {}",
                self.spectral_radius
            ));
        }
        Ok(())
    }

    /// Canonical byte encoding — the hash input for [`Self::id`]. Field
    /// order is part of the wire contract (ids must agree across nodes
    /// and releases).
    fn canonical_bytes(&self) -> [u8; 25] {
        let mut out = [0u8; 25];
        out[..8].copy_from_slice(&self.seed.to_le_bytes());
        out[8..16].copy_from_slice(&(self.n as u64).to_le_bytes());
        out[16..24].copy_from_slice(&self.spectral_radius.to_bits().to_le_bytes());
        out[24] = match self.lambda_prior {
            LambdaPrior::Uniform => 0,
            LambdaPrior::Ring => 1,
        };
        out
    }

    /// Deterministic model id: FNV-1a of the canonical bytes masked to 53
    /// bits (exact in f64 / JSON), nudged off the reserved base id.
    pub fn id(&self) -> ModelId {
        let h = fnv1a(&self.canonical_bytes()) & ((1u64 << 53) - 1);
        if h == BASE_MODEL {
            1
        } else {
            h
        }
    }
}

/// Mint the tenant reservoir for a recipe — pure, deterministic, O(N·d).
///
/// Draw order (fixed forever; ids and failover re-mints depend on it):
///  1. spectrum from the prior's generator,
///  2. `[W_in]_P` row-major: per slot one real draw, plus one imaginary
///     draw for complex slots only.
///
/// Real slots keep `win_im = 0` — the slot-layout invariant every engine
/// relies on (a real mode's state never grows an imaginary part).
pub fn mint_esn(recipe: &ModelRecipe, d_in: usize) -> DiagonalEsn {
    let mut rng = Pcg64::new(recipe.seed, MINT_STREAM);
    let spec = match recipe.lambda_prior {
        LambdaPrior::Uniform => {
            uniform_spectrum(recipe.n, recipe.spectral_radius, &mut rng)
        }
        LambdaPrior::Ring => {
            ring_spectrum(recipe.n, recipe.spectral_radius, &mut rng)
        }
    };
    let slots = spec.slots();
    let n_real = spec.n_real;
    let mut win_re = Mat::zeros(d_in, slots);
    let mut win_im = Mat::zeros(d_in, slots);
    for d in 0..d_in {
        for j in 0..slots {
            win_re[(d, j)] = rng.uniform(-1.0, 1.0);
            if j >= n_real {
                win_im[(d, j)] = rng.uniform(-1.0, 1.0);
            }
        }
    }
    DiagonalEsn::from_parts(spec, win_re, win_im, None)
}

/// Mint the full servable bundle: reservoir + zeroed readout (tenants
/// train in-band via `train`/`commit`) at the given serving precision.
pub fn mint_model(
    recipe: &ModelRecipe,
    d_in: usize,
    precision: Precision,
) -> Model {
    let esn = mint_esn(recipe, d_in);
    let n = esn.n();
    let readout = Readout {
        w: Mat::zeros(n, 1),
        b: vec![0.0],
    };
    Model::with_precision(esn, readout, precision)
}

struct Entry {
    model: Arc<Model>,
    recipe: ModelRecipe,
}

/// Why a registry operation was refused — mapped to typed wire errors.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum RegistryError {
    /// `create_model` would exceed `max_models`; nothing was allocated.
    Budget { max_models: usize },
    /// The referenced model id is not registered (and not the base).
    UnknownModel(ModelId),
}

/// Process-wide tenant model table. One instance is shared (Arc) by every
/// shard's sweeper, the wire layer, and the predict-engine pools; the
/// inner lock is taken only on create/delete/lookup — never inside a
/// sweep (sweepers cache `Arc<Model>` clones per hub).
pub struct ModelRegistry {
    base: Arc<Model>,
    max_models: usize,
    inner: Mutex<HashMap<ModelId, Entry>>,
}

impl ModelRegistry {
    /// `max_models` = 0 disables tenant creation entirely (every
    /// `create_model` refuses with `model_budget`); the base model always
    /// serves regardless.
    pub fn new(base: Arc<Model>, max_models: usize) -> Self {
        Self {
            base,
            max_models,
            inner: Mutex::new(HashMap::new()),
        }
    }

    pub fn base(&self) -> &Arc<Model> {
        &self.base
    }

    pub fn max_models(&self) -> usize {
        self.max_models
    }

    /// Registered tenant count (excludes the base model).
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Idempotent create. Returns `(id, created)` — `created == false`
    /// means the recipe was already registered and the caller got the
    /// shared instance (no new planes). The budget check precedes the
    /// mint, so a refusal allocates nothing.
    pub fn create(
        &self,
        recipe: &ModelRecipe,
    ) -> Result<(ModelId, bool), RegistryError> {
        let id = recipe.id();
        {
            let inner = self.inner.lock().unwrap();
            if inner.contains_key(&id) {
                return Ok((id, false));
            }
            if inner.len() >= self.max_models {
                return Err(RegistryError::Budget {
                    max_models: self.max_models,
                });
            }
        }
        // Mint outside the lock — O(N·d) but no reason to serialize
        // against lookups. Concurrent same-recipe creates race benignly:
        // both mint bit-identical models, one insert wins.
        let model = Arc::new(mint_model(
            recipe,
            self.base.esn.d_in,
            self.base.precision,
        ));
        let mut inner = self.inner.lock().unwrap();
        if inner.contains_key(&id) {
            return Ok((id, false));
        }
        if inner.len() >= self.max_models {
            return Err(RegistryError::Budget {
                max_models: self.max_models,
            });
        }
        inner.insert(
            id,
            Entry {
                model,
                recipe: *recipe,
            },
        );
        Ok((id, true))
    }

    /// Resolve an id to its servable model. Id 0 is always the base.
    pub fn get(&self, id: ModelId) -> Option<Arc<Model>> {
        if id == BASE_MODEL {
            return Some(Arc::clone(&self.base));
        }
        self.inner
            .lock()
            .unwrap()
            .get(&id)
            .map(|e| Arc::clone(&e.model))
    }

    /// The recipe an id was minted from (None for base/unknown) — what a
    /// failed-over owner needs to re-mint the tenant locally.
    pub fn recipe(&self, id: ModelId) -> Option<ModelRecipe> {
        self.inner.lock().unwrap().get(&id).map(|e| e.recipe)
    }

    /// Evict a tenant. Lanes still bound to it keep serving off their
    /// hub's cached `Arc` until released; new bindings and predicts get
    /// `unknown_model`. Deleting the base is refused.
    pub fn delete(&self, id: ModelId) -> Result<(), RegistryError> {
        if id == BASE_MODEL {
            return Err(RegistryError::UnknownModel(id));
        }
        match self.inner.lock().unwrap().remove(&id) {
            Some(_) => Ok(()),
            None => Err(RegistryError::UnknownModel(id)),
        }
    }

    /// Registered ids in ascending order (deterministic `info` output).
    pub fn ids(&self) -> Vec<ModelId> {
        let mut v: Vec<ModelId> =
            self.inner.lock().unwrap().keys().copied().collect();
        v.sort_unstable();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::make_model;
    use super::*;

    fn recipe(seed: u64) -> ModelRecipe {
        ModelRecipe {
            seed,
            n: 40,
            spectral_radius: 0.9,
            lambda_prior: LambdaPrior::Uniform,
        }
    }

    #[test]
    fn ids_are_deterministic_distinct_and_53_bit() {
        let a = recipe(1).id();
        let b = recipe(1).id();
        let c = recipe(2).id();
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert_ne!(a, BASE_MODEL);
        assert!(a < (1u64 << 53));
        // id changes with every recipe field
        let mut r = recipe(1);
        r.n = 41;
        assert_ne!(r.id(), a);
        let mut r = recipe(1);
        r.spectral_radius = 0.95;
        assert_ne!(r.id(), a);
        let mut r = recipe(1);
        r.lambda_prior = LambdaPrior::Ring;
        assert_ne!(r.id(), a);
    }

    #[test]
    fn mint_is_bit_reproducible_across_instances() {
        // same recipe ⇒ bit-identical planes, minted twice from scratch —
        // the property cluster failover's re-mint path rests on.
        for prior in [LambdaPrior::Uniform, LambdaPrior::Ring] {
            let r = ModelRecipe {
                seed: 7,
                n: 64,
                spectral_radius: 0.8,
                lambda_prior: prior,
            };
            let a = mint_esn(&r, 1);
            let b = mint_esn(&r, 1);
            assert_eq!(a.spec.n, b.spec.n);
            assert_eq!(a.spec.n_real, b.spec.n_real);
            for (x, y) in a.spec.lam.iter().zip(&b.spec.lam) {
                assert_eq!(x.re.to_bits(), y.re.to_bits());
                assert_eq!(x.im.to_bits(), y.im.to_bits());
            }
            for j in 0..a.spec.slots() {
                assert_eq!(
                    a.win_re[(0, j)].to_bits(),
                    b.win_re[(0, j)].to_bits()
                );
                assert_eq!(
                    a.win_im[(0, j)].to_bits(),
                    b.win_im[(0, j)].to_bits()
                );
            }
            // real slots never carry imaginary input weight
            for j in 0..a.spec.n_real {
                assert_eq!(a.win_im[(0, j)], 0.0);
            }
            // different seed ⇒ different planes
            let c = mint_esn(&recipe(8), 1);
            assert!(c.spec.lam[0] != a.spec.lam[0] || c.win_re[(0, 0)] != a.win_re[(0, 0)]);
        }
    }

    #[test]
    fn minted_planes_are_o_n_d() {
        // DPG-direct minting must not materialize Q or any N×N object.
        let r = ModelRecipe {
            seed: 3,
            n: 1000,
            spectral_radius: 0.9,
            lambda_prior: LambdaPrior::Uniform,
        };
        let esn = mint_esn(&r, 1);
        assert!(esn.q.is_none(), "mint must not build the O(N²) basis");
        assert_eq!(esn.win_re.rows(), 1);
        assert_eq!(esn.win_re.cols(), esn.spec.slots());
        assert_eq!(esn.n(), 1000);
    }

    #[test]
    fn create_is_idempotent_and_shares_planes() {
        let reg = ModelRegistry::new(Arc::new(make_model()), 4);
        let (id1, created1) = reg.create(&recipe(1)).unwrap();
        let (id2, created2) = reg.create(&recipe(1)).unwrap();
        assert_eq!(id1, id2);
        assert!(created1);
        assert!(!created2, "re-create must reuse the minted instance");
        assert_eq!(reg.len(), 1);
        // copy-on-write sharing: both handles are the same allocation
        let a = reg.get(id1).unwrap();
        let b = reg.get(id2).unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(reg.recipe(id1), Some(recipe(1)));
    }

    #[test]
    fn budget_refusal_allocates_nothing() {
        let reg = ModelRegistry::new(Arc::new(make_model()), 2);
        reg.create(&recipe(1)).unwrap();
        reg.create(&recipe(2)).unwrap();
        let err = reg.create(&recipe(3)).unwrap_err();
        assert_eq!(err, RegistryError::Budget { max_models: 2 });
        assert_eq!(reg.len(), 2, "refused create must not allocate");
        assert!(reg.get(recipe(3).id()).is_none());
        // but re-creating a registered recipe still succeeds at budget
        let (_, created) = reg.create(&recipe(1)).unwrap();
        assert!(!created);
        // and deleting frees the slot
        reg.delete(recipe(1).id()).unwrap();
        let (_, created) = reg.create(&recipe(3)).unwrap();
        assert!(created);
    }

    #[test]
    fn lifecycle_base_and_unknown() {
        let reg = ModelRegistry::new(Arc::new(make_model()), 4);
        // base always resolves, is never listed, can't be deleted
        assert!(reg.get(BASE_MODEL).is_some());
        assert!(reg.ids().is_empty());
        assert!(reg.delete(BASE_MODEL).is_err());
        assert_eq!(
            reg.delete(12345),
            Err(RegistryError::UnknownModel(12345))
        );
        assert!(reg.get(12345).is_none());
        let (id, _) = reg.create(&recipe(9)).unwrap();
        assert_eq!(reg.ids(), vec![id]);
        reg.delete(id).unwrap();
        assert!(reg.get(id).is_none());
        assert!(reg.ids().is_empty());
    }

    #[test]
    fn recipe_validation_bounds() {
        let mut r = recipe(1);
        r.n = 0;
        assert!(r.validate().is_err());
        r.n = MAX_TENANT_N + 1;
        assert!(r.validate().is_err());
        r.n = MAX_TENANT_N;
        assert!(r.validate().is_ok());
        r.spectral_radius = 0.0;
        assert!(r.validate().is_err());
        r.spectral_radius = f64::NAN;
        assert!(r.validate().is_err());
        r.spectral_radius = MAX_TENANT_SR + 0.1;
        assert!(r.validate().is_err());
        r.spectral_radius = 1.0;
        assert!(r.validate().is_ok());
    }

    #[test]
    fn minted_model_serves_at_both_precisions() {
        // a fresh tenant's readout is zero ⇒ predict returns zeros, but
        // the sweep itself must run at either precision without panic
        let r = recipe(5);
        for precision in [Precision::F64, Precision::F32] {
            let m = mint_model(&r, 1, precision);
            let input: Vec<f64> =
                (0..16).map(|t| (t as f64 * 0.3).sin()).collect();
            let y = m.predict(&input);
            assert_eq!(y.len(), input.len());
            assert!(y.iter().all(|v| *v == 0.0));
        }
    }

    #[test]
    fn f32_tenant_planes_inherit_f64_mint_bits() {
        // DPG determinism across precisions: the mint always samples in
        // f64; an f32 tenant downcasts the same bit-pattern planes, so
        // two registries at different precisions agree on the recipe's
        // f64 master planes.
        let r = recipe(6);
        let a = mint_model(&r, 1, Precision::F64);
        let b = mint_model(&r, 1, Precision::F32);
        for (x, y) in a.esn.spec.lam.iter().zip(&b.esn.spec.lam) {
            assert_eq!(x.re.to_bits(), y.re.to_bits());
            assert_eq!(x.im.to_bits(), y.im.to_bits());
        }
        for j in 0..a.esn.spec.slots() {
            assert_eq!(
                a.esn.win_re[(0, j)].to_bits(),
                b.esn.win_re[(0, j)].to_bits()
            );
        }
    }
}
