//! Wire layer: the line-delimited JSON protocol over TCP, and the
//! connection-to-shard binding.
//!
//! Protocol (one JSON object per line):
//!
//! ```text
//! → {"op": "predict", "input": [u0, u1, …]}     forecast 1-step-ahead for
//!                                               the whole sequence
//! → {"op": "stream", "input": [u_t]}            stateful per-connection
//!                                               streaming step
//! → {"op": "reset"}                             zero this connection's state
//! → {"op": "info"}
//! ← {"ok": true, "output": […], "steps_per_sec": …}
//! ```
//!
//! The protocol is unchanged from the single-front server — sharding is
//! invisible on the wire except through `info`, which now reports
//! `shards`, this connection's `shard`, and per-shard
//! `shard_queue_depth` / `shard_sweeps` next to the aggregate
//! `queue_depth` / `sweeps`.
//!
//! Each accepted connection derives a key from its **peer IP** (ports
//! change per connection, the address does not) and hashes to a **home
//! shard** for its lifetime: `stream`/`reset` state lives on the home
//! shard's hub, while stateless `predict`s are dealt to the least-loaded
//! shard. Because the hash is a pure function of the key and the key is
//! a pure function of the client's address, a reconnecting client lands
//! on the same shard — shard placement is stable across reconnects
//! (tested). When the peer address is unreadable the accept counter
//! stands in. Connections beyond the home hub's lane capacity fall back
//! to a connection-local state with the same arithmetic
//! (precision-matched, bit-identical to a hub lane).

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::reservoir::{BatchEsn, LaneReadout};
use crate::util::json::{parse, Json};
use crate::util::Timer;

use super::shard::ShardedFront;
use super::{Model, Precision};

/// Default shard count: one sweeper per available core.
pub(crate) fn default_shards() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Connection key from the peer IP (NOT the port — ports are ephemeral,
/// so keying on the address is what makes a reconnecting client hash to
/// its previous home shard).
fn ip_key(ip: &std::net::IpAddr) -> u64 {
    match ip {
        std::net::IpAddr::V4(v4) => u32::from_be_bytes(v4.octets()) as u64,
        std::net::IpAddr::V6(v6) => {
            let o = v6.octets();
            let hi = u64::from_be_bytes(o[..8].try_into().expect("8 bytes"));
            let lo = u64::from_be_bytes(o[8..].try_into().expect("8 bytes"));
            hi ^ lo.rotate_left(1)
        }
    }
}

/// Serve `model` on `addr` (e.g. "127.0.0.1:7878"). Blocks; one
/// lightweight handler thread per connection, each bound to a home shard
/// of a [`ShardedFront`] sized to the available cores, with immediate
/// drain (no hold-off — the latency-safe default; high-concurrency
/// deployments that prefer deeper coalescing use [`serve_with_holdoff`]).
/// `max_requests` bounds the total connections accepted (tests /
/// examples) — all of them are joined before returning; `None` runs
/// forever.
pub fn serve(model: Arc<Model>, addr: &str, max_requests: Option<usize>) -> Result<()> {
    serve_sharded(model, addr, max_requests, 0, None)
}

/// [`serve`] with an explicit sweeper hold-off window (µs): with a
/// shallow queue each shard's sweeper waits up to the window for more
/// requests to coalesce into one sweep. This trades up to `holdoff_us`
/// of latency on lightly-loaded request/response traffic for fewer,
/// larger sweeps when many clients arrive together; a batch-worthy
/// queue always drains immediately.
pub fn serve_with_holdoff(
    model: Arc<Model>,
    addr: &str,
    max_requests: Option<usize>,
    holdoff_us: u64,
) -> Result<()> {
    serve_sharded(model, addr, max_requests, holdoff_us, None)
}

/// The fully-knobbed server: [`serve_with_holdoff`] plus an explicit
/// shard count. `None` shards = one per available core; `Some(1)`
/// reproduces the single-front server bit-exactly (one sweeper, one hub
/// — the PR-2 behavior); responses are bit-identical at every shard
/// count either way, since shards never share mutable state.
pub fn serve_sharded(
    model: Arc<Model>,
    addr: &str,
    max_requests: Option<usize>,
    holdoff_us: u64,
    shards: Option<usize>,
) -> Result<()> {
    let listener = TcpListener::bind(addr)?;
    let shards = shards.unwrap_or_else(default_shards);
    let front = ShardedFront::start_with_holdoff(model, shards, holdoff_us);
    let mut served = 0usize;
    let mut handles = Vec::new();
    let mut accept_err: Option<anyhow::Error> = None;
    for stream in listener.incoming() {
        let stream = match stream {
            Ok(s) => s,
            Err(e) => {
                // don't early-return: the sweepers and any live handlers
                // must still be wound down below
                accept_err = Some(e.into());
                break;
            }
        };
        let front2 = Arc::clone(&front);
        // key by peer IP so the same client re-hashes to the same home
        // shard across reconnects; fall back to the accept counter when
        // the peer address is unreadable
        let conn_key = stream
            .peer_addr()
            .map(|a| ip_key(&a.ip()))
            .unwrap_or(served as u64);
        let handle = std::thread::spawn(move || {
            let _ = handle_connection(front2, conn_key, stream);
        });
        served += 1;
        if let Some(max) = max_requests {
            handles.push(handle);
            if served >= max {
                break;
            }
        } else {
            drop(handle); // detach
        }
    }
    for h in handles {
        let _ = h.join();
    }
    front.shutdown();
    match accept_err {
        Some(e) => Err(e),
        None => Ok(()),
    }
}

/// Per-connection fallback streaming state at the oracle precision (used
/// when the home hub is full and the model serves `F64`).
struct LocalStream {
    s_re: Vec<f64>,
    s_im: Vec<f64>,
}

/// Hub-less streaming state at the model's precision: the `F64` form is
/// the legacy split-plane walk; the `F32` form is a 1-lane f32 engine
/// with its pre-cast readout (bit-identical to an f32 hub lane — lane
/// results are batch-size independent — and allocation-free per round).
enum LocalFallback {
    F64(LocalStream),
    F32(BatchEsn<f32>, LaneReadout<f32>),
}

/// Per-connection streaming identity: the home shard is fixed at accept
/// time (hash of the connection key); a hub lane on that shard is
/// acquired LAZILY on the first `stream` op (predict-only connections
/// never occupy one) and kept for the connection's lifetime; once the
/// hub was full for this connection, it sticks to the local fallback so
/// its state never jumps between hub and local.
struct ConnState {
    shard_idx: usize,
    lane: Option<usize>,
    hub_denied: bool,
    /// Built lazily on the first hub-denied `stream` op — predict-only
    /// connections (and connections that win a hub lane) never pay for it.
    local: Option<LocalFallback>,
}

/// Construct the hub-less streaming state at the model's precision.
fn local_fallback(model: &Model) -> LocalFallback {
    match model.precision {
        Precision::F64 => {
            let slots = model.esn.spec.slots();
            LocalFallback::F64(LocalStream {
                s_re: vec![0.0f64; slots],
                s_im: vec![0.0f64; slots],
            })
        }
        Precision::F32 => LocalFallback::F32(
            BatchEsn::<f32>::with_precision(model.qesn.clone(), 1),
            LaneReadout::new(&model.readout),
        ),
    }
}

fn handle_connection(
    front: Arc<ShardedFront>,
    conn_key: u64,
    stream: TcpStream,
) -> Result<()> {
    let mut conn = ConnState {
        shard_idx: front.shard_for_key(conn_key),
        lane: None,
        hub_denied: false,
        local: None,
    };
    let result = serve_lines(&front, &mut conn, stream);
    if let Some(l) = conn.lane {
        front.shard(conn.shard_idx).release_lane(l);
    }
    result
}

fn serve_lines(
    front: &ShardedFront,
    conn: &mut ConnState,
    stream: TcpStream,
) -> Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut out = stream;
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(()); // client closed
        }
        let response = match handle_request(front, conn, &line) {
            Ok(json) => json,
            Err(e) => Json::obj(vec![
                ("ok", Json::Bool(false)),
                ("error", Json::Str(format!("{e:#}"))),
            ]),
        };
        out.write_all(response.to_string_compact().as_bytes())?;
        out.write_all(b"\n")?;
    }
}

fn handle_request(
    front: &ShardedFront,
    conn: &mut ConnState,
    line: &str,
) -> Result<Json> {
    let model = front.model();
    let home = front.shard(conn.shard_idx);
    let req = parse(line.trim())?;
    let op = req
        .get("op")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("missing 'op'"))?;
    match op {
        "info" => {
            let depths = front.queue_depths();
            let sweeps = front.sweep_counts();
            Ok(Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("n", Json::Num(model.esn.n() as f64)),
                ("slots", Json::Num(model.esn.spec.slots() as f64)),
                ("n_real", Json::Num(model.esn.spec.n_real as f64)),
                (
                    "spectral_radius",
                    Json::Num(model.esn.spec.radius()),
                ),
                ("precision", Json::Str(model.precision.name().into())),
                ("shards", Json::Num(front.shards() as f64)),
                ("shard", Json::Num(conn.shard_idx as f64)),
                (
                    "queue_depth",
                    Json::Num(depths.iter().sum::<usize>() as f64),
                ),
                (
                    "sweeps",
                    Json::Num(sweeps.iter().sum::<u64>() as f64),
                ),
                (
                    "shard_queue_depth",
                    Json::Arr(
                        depths.iter().map(|&d| Json::Num(d as f64)).collect(),
                    ),
                ),
                (
                    "shard_sweeps",
                    Json::Arr(
                        sweeps.iter().map(|&s| Json::Num(s as f64)).collect(),
                    ),
                ),
                (
                    "holdoff_us",
                    Json::Num(home.holdoff_us() as f64),
                ),
                ("stream_lane", match conn.lane {
                    Some(l) => Json::Num(l as f64),
                    None => Json::Null,
                }),
            ]))
        }
        "predict" => {
            let input = parse_input(&req)?;
            let steps = input.len();
            let t = Timer::start();
            // stateless: dealt to the least-loaded shard, not the home
            let output = front.predict(input);
            let dt = t.elapsed_s().max(1e-12);
            Ok(Json::obj(vec![
                ("ok", Json::Bool(true)),
                (
                    "output",
                    Json::Arr(output.into_iter().map(Json::Num).collect()),
                ),
                (
                    "steps_per_sec",
                    Json::Num(steps as f64 / dt),
                ),
            ]))
        }
        "stream" => {
            let input = parse_input(&req)?;
            // first stream op: try to claim a lane on the home shard's
            // hub (and never switch engines once this connection's
            // streaming has started)
            if conn.lane.is_none() && !conn.hub_denied {
                conn.lane = home.acquire_lane();
                if conn.lane.is_none() {
                    conn.hub_denied = true;
                }
            }
            let outs = match conn.lane {
                Some(l) => home.stream(l, input)?,
                None => {
                    let local = conn
                        .local
                        .get_or_insert_with(|| local_fallback(model));
                    match local {
                        LocalFallback::F64(ls) => {
                            stream_local(model, &input, ls)
                        }
                        LocalFallback::F32(engine, ro) => engine
                            .sweep_streams_cast(&[(0, input.as_slice())], ro)
                            .pop()
                            .unwrap_or_default(),
                    }
                }
            };
            Ok(Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("output", Json::Arr(outs.into_iter().map(Json::Num).collect())),
            ]))
        }
        "reset" => {
            if let Some(l) = conn.lane {
                home.reset(l)?;
            }
            // dropping the lazy fallback IS the reset: it is rebuilt from
            // the zero state on the next hub-denied stream op
            conn.local = None;
            Ok(Json::obj(vec![("ok", Json::Bool(true))]))
        }
        other => Err(anyhow!("unknown op {other:?}")),
    }
}

/// Hub-less f64 streaming fallback: same arithmetic (and therefore the
/// same bits) as a hub lane, on connection-local slot planes.
fn stream_local(model: &Model, input: &[f64], local: &mut LocalStream) -> Vec<f64> {
    let n = model.esn.n();
    let mut outs = Vec::with_capacity(input.len());
    let mut feat = vec![0.0; n];
    for &u in input {
        model.esn.step(&mut local.s_re, &mut local.s_im, &[u]);
        model.esn.write_features(&local.s_re, &local.s_im, &mut feat);
        // y = b + feat·w (bias-first: the shared accumulation contract)
        let mut y = model.readout.b[0];
        for (j, &f) in feat.iter().enumerate() {
            y += f * model.readout.w[(j, 0)];
        }
        outs.push(y);
    }
    outs
}

fn parse_input(req: &Json) -> Result<Vec<f64>> {
    req.get("input")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("missing 'input' array"))?
        .iter()
        .map(|v| v.as_f64().ok_or_else(|| anyhow!("non-numeric input")))
        .collect()
}

/// Minimal client for the examples/tests.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Self> {
        let stream = TcpStream::connect(addr)?;
        Ok(Self {
            reader: BufReader::new(stream.try_clone()?),
            writer: stream,
        })
    }

    pub fn request(&mut self, req: &Json) -> Result<Json> {
        self.writer
            .write_all(req.to_string_compact().as_bytes())?;
        self.writer.write_all(b"\n")?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        parse(line.trim())
    }

    fn io_op(&mut self, op: &str, input: &[f64]) -> Result<Vec<f64>> {
        let req = Json::obj(vec![
            ("op", Json::Str(op.into())),
            (
                "input",
                Json::Arr(input.iter().map(|&x| Json::Num(x)).collect()),
            ),
        ]);
        let resp = self.request(&req)?;
        anyhow::ensure!(
            resp.get("ok").map(|j| *j == Json::Bool(true)).unwrap_or(false),
            "server error: {resp:?}"
        );
        resp.get("output")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("missing output"))?
            .iter()
            .map(|v| v.as_f64().ok_or_else(|| anyhow!("bad output")))
            .collect()
    }

    pub fn predict(&mut self, input: &[f64]) -> Result<Vec<f64>> {
        self.io_op("predict", input)
    }

    /// Stateful streaming step(s) on this connection's lane.
    pub fn stream(&mut self, input: &[f64]) -> Result<Vec<f64>> {
        self.io_op("stream", input)
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::{make_model, make_model_f32};
    use super::*;

    use crate::tasks::mso::MsoTask;

    #[test]
    fn predict_and_stream_agree() {
        let model = make_model();
        let task = MsoTask::new(1);
        let input = &task.input[..50];
        let batch = model.predict(input);
        // streaming path (local fallback arithmetic)
        let mut local = LocalStream {
            s_re: vec![0.0; model.esn.spec.slots()],
            s_im: vec![0.0; model.esn.spec.slots()],
        };
        let line_out = stream_local(&model, input, &mut local);
        for (a, b) in batch.iter().zip(&line_out) {
            assert!((a - b).abs() < 1e-10);
        }
    }

    #[test]
    fn end_to_end_over_tcp() {
        let model = Arc::new(make_model());
        let addr = "127.0.0.1:47391";
        let server_model = Arc::clone(&model);
        let handle = std::thread::spawn(move || {
            serve(server_model, addr, Some(1)).unwrap();
        });
        std::thread::sleep(std::time::Duration::from_millis(100));
        let mut client = Client::connect(addr).unwrap();
        let task = MsoTask::new(1);
        let out = client.predict(&task.input[..40]).unwrap();
        assert_eq!(out.len(), 40);
        let direct = model.predict(&task.input[..40]);
        for (a, b) in out.iter().zip(&direct) {
            assert!((a - b).abs() < 1e-9);
        }
        // info op
        let resp = client
            .request(&Json::obj(vec![("op", Json::Str("info".into()))]))
            .unwrap();
        assert_eq!(resp.get("n").unwrap().as_usize(), Some(30));
        drop(client);
        handle.join().unwrap();
    }

    #[test]
    fn explicit_two_shard_server_over_tcp_is_invisible() {
        // shards must be unobservable on the wire: an explicitly 2-shard
        // server answers bit-identically to Model::predict, and `info`
        // reports the shard topology
        let model = Arc::new(make_model());
        let addr = "127.0.0.1:47421";
        let server_model = Arc::clone(&model);
        let handle = std::thread::spawn(move || {
            serve_sharded(server_model, addr, Some(2), 0, Some(2)).unwrap();
        });
        std::thread::sleep(std::time::Duration::from_millis(100));
        let task = MsoTask::new(2);
        // both connections come from the same peer IP, so they (and any
        // reconnect) must hash to the same home shard — shard placement
        // is stable across reconnects
        let mut c1 = Client::connect(addr).unwrap();
        let mut c2 = Client::connect(addr).unwrap();
        let shard_of = |c: &mut Client| {
            c.request(&Json::obj(vec![("op", Json::Str("info".into()))]))
                .unwrap()
                .get("shard")
                .and_then(Json::as_f64)
                .unwrap()
        };
        assert_eq!(
            shard_of(&mut c1),
            shard_of(&mut c2),
            "same peer IP must keep its home shard across connections"
        );
        for i in 0..3 {
            let input = &task.input[i * 8..i * 8 + 25];
            for c in [&mut c1, &mut c2] {
                let got = c.predict(input).unwrap();
                let want = model.predict(input);
                assert_eq!(got.len(), want.len());
                for (a, b) in got.iter().zip(&want) {
                    assert!((a - b).abs() == 0.0, "{a} vs {b}");
                }
            }
        }
        let resp = c1
            .request(&Json::obj(vec![("op", Json::Str("info".into()))]))
            .unwrap();
        assert_eq!(resp.get("shards").and_then(Json::as_f64), Some(2.0));
        let shard = resp.get("shard").and_then(Json::as_f64).unwrap();
        assert!(shard == 0.0 || shard == 1.0);
        assert_eq!(
            resp.get("shard_queue_depth").and_then(Json::as_arr).unwrap().len(),
            2
        );
        assert_eq!(
            resp.get("shard_sweeps").and_then(Json::as_arr).unwrap().len(),
            2
        );
        drop(c1);
        drop(c2);
        handle.join().unwrap();
    }

    #[test]
    fn info_reports_precision_and_sweeper_metrics() {
        let model = Arc::new(make_model_f32());
        let addr = "127.0.0.1:47417";
        let server_model = Arc::clone(&model);
        let handle = std::thread::spawn(move || {
            serve(server_model, addr, Some(1)).unwrap();
        });
        std::thread::sleep(std::time::Duration::from_millis(100));
        let mut client = Client::connect(addr).unwrap();
        let task = MsoTask::new(1);
        // drive at least one sweep through the front
        let out = client.predict(&task.input[..20]).unwrap();
        assert_eq!(out.len(), 20);
        let resp = client
            .request(&Json::obj(vec![("op", Json::Str("info".into()))]))
            .unwrap();
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(
            resp.get("precision").and_then(Json::as_str),
            Some("f32")
        );
        // aggregate sweeps count every shard's rounds; the predict above
        // ran on one of them
        assert!(resp.get("sweeps").and_then(Json::as_f64).unwrap() >= 1.0);
        assert!(resp.get("queue_depth").and_then(Json::as_f64).is_some());
        // default serve() shards one sweeper per available core
        let shards = resp.get("shards").and_then(Json::as_f64).unwrap();
        assert!(shards >= 1.0);
        assert_eq!(
            resp.get("shard_sweeps").and_then(Json::as_arr).unwrap().len(),
            shards as usize
        );
        // serve() runs with immediate drain; the hold-off is opt-in via
        // serve_with_holdoff / start_with_holdoff
        assert_eq!(
            resp.get("holdoff_us").and_then(Json::as_f64),
            Some(0.0)
        );
        drop(client);
        handle.join().unwrap();
    }
}
