//! Wire layer: the line-delimited JSON protocol over TCP, and the
//! connection-to-shard binding.
//!
//! Protocol (one JSON object per line):
//!
//! ```text
//! → {"op": "predict", "input": [u0, u1, …]}     forecast 1-step-ahead for
//!                                               the whole sequence
//! → {"op": "stream", "input": [u_t]}            stateful per-connection
//!                                               streaming step
//! → {"op": "train", "input": […],               advance the connection's
//!    "target": […]}                             state AND stream the
//!                                               (features, target) rows
//!                                               into its online ridge
//!                                               accumulator
//! → {"op": "commit", "alpha": 1e-8}             solve the accumulated
//!                                               ridge system, hot-swap
//!                                               this connection's readout
//! → {"op": "rollback", "version": 3}            reinstall a retained
//!                                               committed readout (0 =
//!                                               base model readout)
//! → {"op": "checkpoint"}                        snapshot this connection's
//!                                               full lane value
//! → {"op": "restore", "checkpoint": {…}}        reinstall a snapshot
//!                                               bit-exactly (also the
//!                                               post-fault recovery op)
//! → {"op": "reset"}                             zero this connection's
//!                                               state AND training
//! → {"op": "info"}
//! ← {"ok": true, "output": […], "steps_per_sec": …}
//! ← {"ok": true, "rows": …}                     (train)
//! ← {"ok": true, "version": …}                  (commit/rollback/restore)
//! ← {"ok": true, "checkpoint": {…}}             (checkpoint)
//! ← {"ok": false, "error": "…", "code": "…"}    (typed failures — see
//!                                               DESIGN.md §10 for the
//!                                               error-code contract)
//! ```
//!
//! ## Fault tolerance (checkpoint / restore / rollback)
//!
//! `checkpoint` snapshots the connection's full lane value — dynamics
//! state, online-trainer accumulator, and the committed-readout version
//! ring — as a JSON object whose every number round-trips f64
//! bit-exactly (the crate's JSON writer prints shortest-form floats).
//! `restore` validates such a snapshot fully and installs it atomically
//! on the connection's lane (acquiring one if needed), reproducing the
//! lane bit-for-bit: a client that checkpoints periodically can
//! reconnect after any failure — including a contained sweeper panic
//! that quarantined its lane — restore, and continue as if
//! uninterrupted. The same snapshot restores onto a different
//! connection, server, or shard serving the same model at the same
//! precision, which makes it the lane-migration primitive. `commit`
//! answers a monotonically increasing per-lane version id and retains
//! each committed readout in a bounded per-lane ring ([`VERSION_RING`]
//! deep, sweeper-side); `rollback` reinstalls any retained version — or
//! version 0, the base model readout — atomically, WITHOUT dropping the
//! trainer's accumulated rows. Failures answer `{"ok": false, "error",
//! "code"}` with a stable machine-readable [`WireError`] code, identical
//! on both transports.
//!
//! ## Online training (train / commit)
//!
//! `train` is `stream`'s training twin: the connection's hub lane
//! advances through `input` exactly as a stream would (state evolution
//! is identical), and each step's `(feature row, target)` pair feeds a
//! per-lane streaming Gram accumulator on the lane's home-shard sweeper
//! — training rides the same O(N) step that serves. `commit` solves the
//! accumulated ridge system at the hub's precision and **atomically
//! hot-swaps this connection's readout** (an `Arc` swap owned by the
//! sweeper thread): subsequent `stream` calls on the connection use the
//! committed readout; further `train` rows extend the same accumulator,
//! so a later `commit` refines it online. `predict` (stateless, dealt
//! across shards) always serves the model readout. `reset` — and lane
//! recycling when the connection closes — drops the accumulator AND the
//! committed readout, so no later connection can inherit another's
//! training. Training needs a hub lane: connections beyond the hub's
//! lane capacity get an error (their local-fallback state has no
//! sweeper-side accumulator). One `train` op's row count is capped by a
//! per-model WORK budget ([`max_train_rows`]: `2²⁸/N²` rows, clamped to
//! `[64, 4096]`) — accumulation is `O(N²)`/row on the sweeper, so the
//! cap bounds head-of-line blocking regardless of model size; larger
//! streams arrive as multiple ops, which interleave with the shard's
//! serving jobs.
//!
//! The protocol is unchanged from the single-front server — sharding is
//! invisible on the wire except through `info`, which reports `shards`,
//! this connection's `shard`, and per-shard `shard_queue_depth` /
//! `shard_sweeps` next to the aggregate `queue_depth` / `sweeps`.
//!
//! ## Two transports, one request handler
//!
//! Request handling is transport-agnostic: [`parse_op`] classifies a
//! line, the `*_response` builders produce the reply JSON, and the
//! per-connection identity lives in a [`ConnState`]. Two transports
//! drive that core:
//!
//! * **event loop** (`server/poll.rs`, the Linux default): ONE poll
//!   thread owns every connection through an epoll readiness loop;
//!   requests are submitted to the shard queues with event replies and
//!   responses flush on socket writability. N idle connections cost N
//!   file descriptors and zero threads.
//! * **threaded** (`serve_on(…, threaded = true)`, the `--threaded` A/B
//!   path and the non-Linux fallback): one handler thread per
//!   connection, parked in `read_line`, blocking on mpsc reply channels.
//!
//! Both transports run the same sweeper arithmetic on the same shard
//! queues, so responses are bit-identical between them at both
//! precisions (tested below).
//!
//! Each accepted connection derives a key from its **peer IP** (ports
//! change per connection, the address does not) and hashes to a **home
//! shard** for its lifetime: `stream`/`reset` state lives on the home
//! shard's hub, while stateless `predict`s are dealt to the least-loaded
//! shard. Because the hash is a pure function of the key and the key is
//! a pure function of the client's address, a reconnecting client lands
//! on the same shard — shard placement is stable across reconnects
//! (tested). When the peer address is unreadable, a tagged accept
//! counter stands in ([`fallback_key`] — disjoint from the IPv4 key
//! space, so an unreadable peer can never alias a real client's home
//! shard). Connections beyond the home hub's lane capacity fall back to
//! a connection-local state with the same arithmetic
//! (precision-matched, bit-identical to a hub lane).

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::readout::GramAccRaw;
use crate::reservoir::{BatchEsn, LaneReadout};
use crate::util::json::{parse, Json};
use crate::util::Timer;

use super::binframe;
use super::front::LaneSnapshot;
use super::registry::{
    ModelId, ModelRecipe, ModelRegistry, RegistryError, BASE_MODEL,
};
use super::shard::{LaneBinding, ShardedFront};
use super::{Model, Precision};

/// Default registry capacity when `--max-models` is not given: enough
/// for serious multi-tenancy, small enough that a runaway minting loop
/// hits the typed `model_budget` refusal before memory does.
pub(crate) const DEFAULT_MAX_MODELS: usize = 256;

/// Default spectral radius for a `create_model` without an explicit
/// `"spectral_radius"` — the paper's workhorse operating point.
pub(crate) const DEFAULT_TENANT_SR: f64 = 0.9;

/// Default shard count: one sweeper per available core.
pub(crate) fn default_shards() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Connection key from the peer IP (NOT the port — ports are ephemeral,
/// so keying on the address is what makes a reconnecting client hash to
/// its previous home shard).
pub(crate) fn ip_key(ip: &std::net::IpAddr) -> u64 {
    match ip {
        std::net::IpAddr::V4(v4) => u32::from_be_bytes(v4.octets()) as u64,
        std::net::IpAddr::V6(v6) => {
            let o = v6.octets();
            let hi = u64::from_be_bytes(o[..8].try_into().expect("8 bytes"));
            let lo = u64::from_be_bytes(o[8..].try_into().expect("8 bytes"));
            hi ^ lo.rotate_left(1)
        }
    }
}

/// Tag for connection keys minted when the peer address is unreadable.
/// IPv4 keys are at most `2³² − 1`, so a raw accept counter must NOT
/// stand in: `0.0.0.7` and "7th unreadable peer" would be the same key,
/// and because the shard map is a pure function of the key they would
/// KEEP colliding onto the same home shard. The tag moves the fallback
/// range into the top half of the key space, disjoint from every IPv4
/// key (IPv6 keys are 128→64-bit mixes spread over the whole space; a
/// chance collision there is no likelier than between two IPv6 peers).
pub(crate) const FALLBACK_KEY_TAG: u64 = 1 << 63;

/// Connection key for the `counter`-th accepted connection whose peer
/// address could not be read. See [`FALLBACK_KEY_TAG`].
pub(crate) fn fallback_key(counter: usize) -> u64 {
    FALLBACK_KEY_TAG | counter as u64
}

// ---------------------------------------------------------------------------
// serving entry points
// ---------------------------------------------------------------------------

/// Serve `model` on `addr` (e.g. "127.0.0.1:7878"). Blocks. Connections
/// bind to a home shard of a [`ShardedFront`] sized to the available
/// cores, with immediate drain (no hold-off — the latency-safe default;
/// high-concurrency deployments that prefer deeper coalescing use
/// [`serve_with_holdoff`]). On Linux the connections are served by the
/// epoll event loop (`server/poll.rs`); elsewhere by one handler thread
/// per connection. `max_requests` bounds the total connections accepted
/// (tests / examples) — all of them are served to completion before
/// returning; `None` runs forever.
pub fn serve(model: Arc<Model>, addr: &str, max_requests: Option<usize>) -> Result<()> {
    serve_sharded(model, addr, max_requests, 0, None)
}

/// [`serve`] with an explicit sweeper hold-off window (µs): with a
/// shallow queue each shard's sweeper waits up to the window for more
/// requests to coalesce into one sweep. This trades up to `holdoff_us`
/// of latency on lightly-loaded request/response traffic for fewer,
/// larger sweeps when many clients arrive together; a batch-worthy
/// queue always drains immediately.
pub fn serve_with_holdoff(
    model: Arc<Model>,
    addr: &str,
    max_requests: Option<usize>,
    holdoff_us: u64,
) -> Result<()> {
    serve_sharded(model, addr, max_requests, holdoff_us, None)
}

/// [`serve_with_holdoff`] plus an explicit shard count. `None` shards =
/// one per available core; `Some(1)` reproduces the single-front server
/// bit-exactly (one sweeper, one hub — the PR-2 behavior); responses are
/// bit-identical at every shard count either way, since shards never
/// share mutable state. Binds `addr` and delegates to [`serve_on`] with
/// the default transport.
pub fn serve_sharded(
    model: Arc<Model>,
    addr: &str,
    max_requests: Option<usize>,
    holdoff_us: u64,
    shards: Option<usize>,
) -> Result<()> {
    let listener = TcpListener::bind(addr)?;
    serve_on(listener, model, max_requests, holdoff_us, shards, false).map(|_| ())
}

/// The fully-knobbed, listener-based entry point: serve `model` on an
/// already-bound `listener` (bind to port 0 and read
/// `listener.local_addr()` for a race-free ephemeral-port server — the
/// test/bench idiom). Returns the bound address once serving completes.
///
/// `threaded = false` picks the transport default: the epoll event loop
/// on Linux (one poll thread, thread-free idle connections), the
/// thread-per-connection loop elsewhere. `threaded = true` forces the
/// thread-per-connection path everywhere (`repro serve --threaded`) —
/// the A/B twin whose responses the event loop must match bit-for-bit.
pub fn serve_on(
    listener: TcpListener,
    model: Arc<Model>,
    max_requests: Option<usize>,
    holdoff_us: u64,
    shards: Option<usize>,
    threaded: bool,
) -> Result<SocketAddr> {
    serve_on_opts(
        listener,
        model,
        max_requests,
        ServeOpts {
            holdoff_us,
            shards,
            threaded,
            ..Default::default()
        },
    )
}

/// Knobs of [`serve_on_opts`] — the positional `serve_on` parameters
/// plus the options that arrived later.
#[derive(Clone, Debug, Default)]
pub struct ServeOpts {
    /// Sweeper coalescing window in µs (0 = drain immediately).
    pub holdoff_us: u64,
    /// Shard count; `None` = one per available core.
    pub shards: Option<usize>,
    /// Force the thread-per-connection transport (the A/B twin; the
    /// non-Linux default either way).
    pub threaded: bool,
    /// Reap connections with no incoming traffic for this long (event
    /// loop only — a coarse timer wheel in `server/poll.rs`; `None` =
    /// never. The threaded transport parks in `read_line` and is not
    /// covered). A connection with an in-flight request or an unflushed
    /// response is never reaped.
    pub idle_timeout: Option<Duration>,
    /// Per-shard online-trainer memory budget in bytes (`None` =
    /// unlimited): the lazily-allocated per-lane Gram accumulators on
    /// one shard may not exceed this, and a `train` that would answers
    /// the typed `trainer_budget` error instead of allocating — so a
    /// reconnecting (or hostile) client population can't grow sweeper
    /// memory without bound. `--trainer-budget-mb` on the CLI.
    pub trainer_budget: Option<usize>,
    /// Run the occupancy rebalancer: a policy thread that periodically
    /// migrates lanes off the hottest shard when the occupancy skew
    /// exceeds the threshold (`ShardedFront::rebalance_once`). Off by
    /// default — `--rebalance` on the CLI.
    pub rebalance: bool,
    /// Warm-standby fan-out: a comma-separated list of replica
    /// addresses (up to 64). Per-lane checkpoint deltas stream to EVERY
    /// replica over the wire protocol's `migrate_in` op; each replica
    /// has its own dirty set, so a slow or dead replica only delays its
    /// own copy. Only lanes whose state changed since that replica's
    /// last push are shipped, and each round's deltas are batched
    /// (pipelined) on one connection. `--standby` on the CLI.
    pub standby: Option<String>,
    /// Standby push interval in ms (0 = the 200 ms default).
    pub standby_interval_ms: u64,
    /// Cluster peers: a comma-separated list of the OTHER members'
    /// advertised addresses. Enables the membership layer — gossip
    /// pings with the failure detector, the consistent-hash ring over
    /// live members, and `moved` redirects for keys this node does not
    /// own. `--peers` on the CLI.
    pub peers: Option<String>,
    /// This node's own address as the rest of the group spells it in
    /// their `--peers` lists (ring placement compares address strings
    /// byte-for-byte). `None` = the listener's local address.
    /// `--advertise` on the CLI.
    pub advertise: Option<String>,
    /// Gossip ping interval in ms (0 = the 50 ms default).
    pub ping_interval_ms: u64,
    /// Autotune each shard's hold-off window from its observed
    /// inter-arrival EWMA, capped by `holdoff_us` (`--holdoff-auto`):
    /// idle shards converge to zero added latency, busy shards coalesce
    /// up to the cap.
    pub holdoff_auto: bool,
    /// On graceful drain, spill every live lane's checkpoint to
    /// `dir/lane-<id>.json` before exit — `--drain-checkpoint` on the
    /// CLI. The spilled files feed `migrate_in` on a successor server.
    pub drain_checkpoint: Option<PathBuf>,
    /// Treat SIGTERM as a `shutdown_drain` request (the CLI serve path
    /// enables this; embedded/test servers default off so test harness
    /// signals can't stop them).
    pub drain_on_sigterm: bool,
    /// Tenant-model registry capacity (`None` = [`DEFAULT_MAX_MODELS`]):
    /// `create_model` past this answers the typed `model_budget` error
    /// without allocating. `--max-models` on the CLI.
    pub max_models: Option<usize>,
    /// Pin each shard's sweeper thread to core `shard mod cores`
    /// (`sched_setaffinity`; silently unpinned where unsupported — the
    /// pinned core, if any, is reported per shard in `info`).
    /// `--pin-cores` on the CLI.
    pub pin_cores: bool,
    /// Event-loop poll threads (0 or 1 = the single-poll-thread loop,
    /// bit-identical to the pre-scale-out transport). With P > 1,
    /// accepted connections are dealt round-robin across P epoll loops,
    /// each owning its conns' buffers, idle wheel, and completion
    /// eventfd; sweepers/shards/cluster/registry are untouched. Ignored
    /// by the threaded transport (every conn owns a thread there
    /// already). `--poll-threads` on the CLI.
    pub poll_threads: usize,
}

/// Set by the SIGTERM handler; polled by both transports' accept loops
/// when [`ServeOpts::drain_on_sigterm`] is on.
pub(crate) static SIGTERM_DRAIN: AtomicBool = AtomicBool::new(false);

/// Install the SIGTERM → drain-flag handler (an async-signal-safe
/// atomic store; the accept loops poll the flag).
#[cfg(unix)]
fn install_sigterm_handler() {
    extern "C" fn on_sigterm(_signum: i32) {
        SIGTERM_DRAIN.store(true, Ordering::SeqCst);
    }
    const SIGTERM: i32 = 15;
    extern "C" {
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
    }
    unsafe {
        signal(SIGTERM, on_sigterm);
    }
}

#[cfg(not(unix))]
fn install_sigterm_handler() {}

/// Graceful-drain configuration threaded into both transports.
pub(crate) struct DrainCfg {
    /// Spill live lanes here on drain (`--drain-checkpoint`).
    pub(crate) spill_dir: Option<PathBuf>,
    /// Poll [`SIGTERM_DRAIN`] in the accept loop.
    pub(crate) watch_sigterm: bool,
}

/// [`serve_on`] with the full option set.
pub fn serve_on_opts(
    listener: TcpListener,
    model: Arc<Model>,
    max_requests: Option<usize>,
    opts: ServeOpts,
) -> Result<SocketAddr> {
    let addr = listener.local_addr()?;
    let shards = opts.shards.unwrap_or_else(default_shards);
    // every served front carries a registry: with zero tenants the
    // serving paths never consult it (bit-identical to pre-registry
    // serving — the A/B tests below), and `create_model` can mint
    // tenants at any time without a restart
    let registry = Arc::new(ModelRegistry::new(
        Arc::clone(&model),
        opts.max_models.unwrap_or(DEFAULT_MAX_MODELS),
    ));
    let front = ShardedFront::start_registry(
        model,
        Some(registry),
        shards,
        opts.holdoff_us,
        opts.trainer_budget.unwrap_or(usize::MAX),
        opts.pin_cores,
    );
    if opts.drain_on_sigterm {
        install_sigterm_handler();
    }
    if opts.holdoff_auto {
        front.set_holdoff_auto(true);
    }
    // sidecar threads (rebalancer / standby pusher / gossip) stop on
    // this flag and are joined BEFORE the sweepers wind down, so none
    // ever observes a dead front
    let stop = Arc::new(AtomicBool::new(false));
    let gossip = opts.peers.clone().map(|peers_csv| {
        let advertise = opts
            .advertise
            .clone()
            .unwrap_or_else(|| addr.to_string());
        let peers: Vec<String> = peers_csv
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect();
        let cluster = super::cluster::ClusterState::new(advertise, peers);
        front.set_cluster(Arc::clone(&cluster));
        let s = Arc::clone(&stop);
        let interval = Duration::from_millis(match opts.ping_interval_ms {
            0 => super::cluster::DEFAULT_PING_INTERVAL_MS,
            ms => ms,
        });
        std::thread::Builder::new()
            .name("lr-gossip".into())
            .spawn(move || super::cluster::gossip_loop(cluster, s, interval))
            .expect("spawn gossip thread")
    });
    let rebalancer = opts.rebalance.then(|| {
        let f = Arc::clone(&front);
        let s = Arc::clone(&stop);
        std::thread::Builder::new()
            .name("lr-rebalancer".into())
            .spawn(move || {
                while !s.load(Ordering::SeqCst) {
                    f.rebalance_once();
                    std::thread::sleep(Duration::from_millis(50));
                }
            })
            .expect("spawn rebalancer thread")
    });
    let pusher = opts.standby.clone().and_then(|standby_csv| {
        let replicas: Vec<String> = standby_csv
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .take(64)
            .collect();
        if replicas.is_empty() {
            return None;
        }
        front.set_replicas(replicas.len());
        let f = Arc::clone(&front);
        let s = Arc::clone(&stop);
        let interval = Duration::from_millis(match opts.standby_interval_ms {
            0 => 200,
            ms => ms,
        });
        Some(
            std::thread::Builder::new()
                .name("lr-standby-pusher".into())
                .spawn(move || standby_push_loop(f, s, replicas, interval))
                .expect("spawn standby pusher thread"),
        )
    });
    let drain = DrainCfg {
        spill_dir: opts.drain_checkpoint.clone(),
        watch_sigterm: opts.drain_on_sigterm,
    };
    let use_event = !opts.threaded && cfg!(target_os = "linux");
    let res = if use_event {
        serve_event(
            listener,
            Arc::clone(&front),
            max_requests,
            opts.idle_timeout,
            &drain,
            opts.poll_threads.max(1),
        )
    } else {
        serve_threaded(&listener, &front, max_requests, &drain)
    };
    stop.store(true, Ordering::SeqCst);
    if let Some(h) = rebalancer {
        let _ = h.join();
    }
    if let Some(h) = pusher {
        let _ = h.join();
    }
    if let Some(h) = gossip {
        let _ = h.join();
    }
    front.shutdown();
    res.map(|()| addr)
}

/// The warm-standby delta pusher, fan-out form: every `interval`, for
/// EACH replica, checkpoint each lane whose state changed since that
/// replica's last push (the binding's per-replica dirty bit) and ship
/// the round's deltas as a PIPELINED batch of
/// `{"op": "migrate_in", "lane_id", "checkpoint"}` frames on one
/// lazily-connected wire client per replica — all frames written, then
/// all acks read, so a round costs one round-trip instead of one per
/// lane. Any frame not positively acked re-marks its lane dirty for
/// that replica and drops that replica's connection, so a dead,
/// restarted, or mid-frame-severed standby costs retries, never lost or
/// torn deltas (the replica parses whole lines only — a partial frame
/// is never applied); IO timeouts bound every hang.
fn standby_push_loop(
    front: Arc<ShardedFront>,
    stop: Arc<AtomicBool>,
    replicas: Vec<String>,
    interval: Duration,
) {
    let mut clients: Vec<Option<Client>> =
        (0..replicas.len()).map(|_| None).collect();
    'push: loop {
        // sleep in short slices so serve_on_opts joins promptly
        let mut slept = Duration::ZERO;
        while slept < interval {
            if stop.load(Ordering::SeqCst) {
                break 'push;
            }
            let slice = Duration::from_millis(10).min(interval - slept);
            std::thread::sleep(slice);
            slept += slice;
        }
        for (idx, addr) in replicas.iter().enumerate() {
            if stop.load(Ordering::SeqCst) {
                break 'push;
            }
            push_replica_deltas(&front, idx, addr, &mut clients[idx]);
        }
    }
}

/// One replica's batched delta round (see [`standby_push_loop`]).
fn push_replica_deltas(
    front: &ShardedFront,
    idx: usize,
    addr: &str,
    client: &mut Option<Client>,
) {
    // claim this replica's dirty lanes and snapshot them first, so the
    // wire phase ships a consistent batch
    let mut claimed: Vec<(Arc<LaneBinding>, Json)> = Vec::new();
    for b in front.live_bindings() {
        if !b.begin_push(idx) {
            continue; // clean since this replica's last push
        }
        match front.checkpoint_binding(&b) {
            Ok(snap) => {
                let req = Json::obj(vec![
                    ("op", Json::Str("migrate_in".into())),
                    ("lane_id", Json::Num(b.id() as f64)),
                    ("checkpoint", snapshot_to_json(&snap)),
                ]);
                claimed.push((b, req));
            }
            // lane released/poisoned mid-push: retry next round
            Err(_) => b.end_push(idx, false),
        }
    }
    if claimed.is_empty() {
        return;
    }
    if client.is_none() {
        match Client::connect(addr) {
            Ok(mut c) => {
                // a wedged replica must not hang the pusher forever
                let _ = c.set_io_timeout(Some(Duration::from_secs(5)));
                *client = Some(c);
            }
            Err(_) => {
                for (b, _) in &claimed {
                    b.end_push(idx, false);
                }
                return;
            }
        }
    }
    let c = client.as_mut().expect("connected above");
    // pipeline: write every frame, then collect the acks in order
    let mut sent = 0;
    for (_, req) in &claimed {
        if send_delta_frame(c, req).is_err() {
            break;
        }
        sent += 1;
    }
    let mut acked = 0;
    while acked < sent {
        match c.recv() {
            Ok(resp) if resp.get("ok") == Some(&Json::Bool(true)) => {
                claimed[acked].0.end_push(idx, true);
                acked += 1;
            }
            _ => break,
        }
    }
    // everything past the last positive ack — refused, torn mid-frame,
    // or timed out — stays owed to this replica
    for (b, _) in &claimed[acked..] {
        b.end_push(idx, false);
    }
    if acked < claimed.len() {
        *client = None; // reconnect next round
    }
}

/// Write one delta frame. Under the chaos suite's short-write shaping
/// this deliberately severs the frame mid-line — bytes on the wire but
/// no terminating newline — and reports failure, reproducing a
/// connection cut at the worst possible instant: the caller must
/// re-dirty the lane, and the replica (which only ever parses complete
/// lines) must never count the partial delta as applied.
fn send_delta_frame(c: &mut Client, req: &Json) -> Result<()> {
    if let Some((chunk, delay)) = super::fault::short_write_chunk() {
        std::thread::sleep(delay);
        let line = format!("{}\n", req.to_string_compact());
        let take = chunk.min(line.len().saturating_sub(1));
        c.send_raw(&line.as_bytes()[..take])?;
        anyhow::bail!("fault-inject: standby delta frame torn mid-write");
    }
    c.send(req)
}

#[cfg(target_os = "linux")]
fn serve_event(
    listener: TcpListener,
    front: Arc<ShardedFront>,
    max_conns: Option<usize>,
    idle_timeout: Option<Duration>,
    drain: &DrainCfg,
    poll_threads: usize,
) -> Result<()> {
    super::poll::serve_event_loop(
        listener,
        front,
        max_conns,
        idle_timeout,
        drain,
        poll_threads,
    )
}

#[cfg(not(target_os = "linux"))]
fn serve_event(
    _listener: TcpListener,
    _front: Arc<ShardedFront>,
    _max_conns: Option<usize>,
    _idle_timeout: Option<Duration>,
    _drain: &DrainCfg,
    _poll_threads: usize,
) -> Result<()> {
    unreachable!("event loop is Linux-only; serve_on routes non-Linux to the threaded path")
}

/// Shared drain state of the threaded transport: the accept loop and
/// every handler thread coordinate a graceful stop through it.
struct DrainCtl {
    /// Set by a `shutdown_drain` op (any handler) or the SIGTERM poll.
    draining: AtomicBool,
    /// Read-half handles of parked connections, keyed by accept id: on
    /// drain the accept loop shuts each one down so `read_line` wakes
    /// with EOF and the handler exits AFTER flushing its last reply —
    /// never a mid-reply RST.
    streams: Mutex<HashMap<u64, TcpStream>>,
    /// Lane bindings retained (NOT released) by handlers that exited
    /// while draining, so their lanes survive to be spilled.
    keep: Mutex<Vec<Arc<LaneBinding>>>,
}

/// The thread-per-connection transport: one lightweight handler thread
/// per accepted connection, parked in `read_line` between requests.
/// Kept as the `--threaded` A/B twin of the event loop (and the
/// non-Linux default). The listener runs non-blocking with a short
/// accept poll so a drain request (op or SIGTERM) can stop the loop
/// even while no connection is arriving.
fn serve_threaded(
    listener: &TcpListener,
    front: &Arc<ShardedFront>,
    max_requests: Option<usize>,
    drain: &DrainCfg,
) -> Result<()> {
    listener.set_nonblocking(true)?;
    let ctl = Arc::new(DrainCtl {
        draining: AtomicBool::new(false),
        streams: Mutex::new(HashMap::new()),
        keep: Mutex::new(Vec::new()),
    });
    let mut served = 0usize;
    let mut handles: Vec<std::thread::JoinHandle<()>> = Vec::new();
    let mut accept_err: Option<anyhow::Error> = None;
    loop {
        if drain.watch_sigterm && SIGTERM_DRAIN.load(Ordering::SeqCst) {
            ctl.draining.store(true, Ordering::SeqCst);
        }
        if ctl.draining.load(Ordering::SeqCst) {
            break; // stop accepting; drain below
        }
        match listener.accept() {
            Ok((stream, peer)) => {
                // accepted sockets must block: handlers park in read_line
                let _ = stream.set_nonblocking(false);
                // key by peer IP so the same client re-hashes to the
                // same home shard across reconnects
                let conn_key = ip_key(&peer.ip());
                let id = served as u64;
                served += 1;
                if let Ok(dup) = stream.try_clone() {
                    ctl.streams.lock().unwrap().insert(id, dup);
                }
                let front2 = Arc::clone(front);
                let ctl2 = Arc::clone(&ctl);
                handles.push(std::thread::spawn(move || {
                    let _ = handle_connection(front2, conn_key, stream, &ctl2, id);
                }));
                if let Some(max) = max_requests {
                    if served >= max {
                        break;
                    }
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                // idle: reap finished handlers so the vec stays bounded
                handles.retain(|h| !h.is_finished());
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(e) => {
                // don't early-return: any live handlers must still be
                // joined below (and the caller winds the sweepers down)
                accept_err = Some(e.into());
                break;
            }
        }
    }
    if ctl.draining.load(Ordering::SeqCst) {
        // wake every parked handler with EOF; in-flight requests finish
        // and flush first (the handler checks the drain flag only
        // BETWEEN requests)
        for s in ctl.streams.lock().unwrap().values() {
            let _ = s.shutdown(std::net::Shutdown::Read);
        }
    }
    for h in handles {
        let _ = h.join();
    }
    // spill the lanes retained by draining handlers, then free them
    let keep = std::mem::take(&mut *ctl.keep.lock().unwrap());
    if let Some(dir) = &drain.spill_dir {
        if !keep.is_empty() {
            let n = front.spill_bindings(&keep, dir);
            eprintln!(
                "drain-checkpoint: spilled {n} lane(s) to {}",
                dir.display()
            );
        }
    }
    for b in &keep {
        front.release_binding(b);
    }
    match accept_err {
        Some(e) => Err(e),
        None => Ok(()),
    }
}

// ---------------------------------------------------------------------------
// per-connection identity + hub-less fallback state
// ---------------------------------------------------------------------------

/// Per-connection fallback streaming state at the oracle precision (used
/// when the home hub is full and the model serves `F64`).
struct LocalStream {
    s_re: Vec<f64>,
    s_im: Vec<f64>,
}

/// Hub-less streaming state at the model's precision: the `F64` form is
/// the legacy split-plane walk; the `F32` form is a 1-lane f32 engine
/// with its pre-cast readout (bit-identical to an f32 hub lane — lane
/// results are batch-size independent — and allocation-free per round).
enum LocalFallback {
    F64(LocalStream),
    F32(BatchEsn<f32>, LaneReadout<f32>),
}

/// Per-connection streaming identity, shared by both transports: the
/// home shard is fixed at accept time (hash of the connection key); a
/// hub lane on that shard is acquired LAZILY on the first `stream` op
/// (predict-only connections never occupy one) — wrapped in a mobile
/// [`LaneBinding`], so a live migration re-homes the lane under the
/// connection without it noticing — and kept for the connection's
/// lifetime; once the hub was full for this connection, it sticks to
/// the local fallback so its state never jumps between hub and local.
pub(crate) struct ConnState {
    /// The connection key (peer-IP hash) — what the cluster ring hashes
    /// to decide which NODE owns this connection's lane state.
    pub(crate) key: u64,
    pub(crate) shard_idx: usize,
    pub(crate) binding: Option<Arc<LaneBinding>>,
    /// The registry model this connection serves ([`BASE_MODEL`] unless
    /// a model-bearing op bound it to a tenant). Sticky for the
    /// connection's lifetime, like the home shard: per-connection lane
    /// state never switches models mid-stream.
    pub(crate) model: ModelId,
    /// Home poll thread (event transport only; `None` on the threaded
    /// path) — surfaced as `poll_thread` in `info` so a client can see
    /// which wire-path owner serves it.
    pub(crate) poll_thread: Option<usize>,
    hub_denied: bool,
    /// Built lazily on the first hub-denied `stream` op — predict-only
    /// connections (and connections that win a hub lane) never pay for it.
    local: Option<LocalFallback>,
}

impl ConnState {
    pub(crate) fn new(key: u64, shard_idx: usize) -> Self {
        Self {
            key,
            shard_idx,
            binding: None,
            model: BASE_MODEL,
            poll_thread: None,
            hub_denied: false,
            local: None,
        }
    }

    /// Drop the lazy local-fallback state — dropping it IS the reset: it
    /// is rebuilt from the zero state on the next hub-denied stream op.
    pub(crate) fn clear_local(&mut self) {
        self.local = None;
    }
}

/// Construct the hub-less streaming state at the model's precision.
fn local_fallback(model: &Model) -> LocalFallback {
    match model.precision {
        Precision::F64 => {
            let slots = model.esn.spec.slots();
            LocalFallback::F64(LocalStream {
                s_re: vec![0.0f64; slots],
                s_im: vec![0.0f64; slots],
            })
        }
        Precision::F32 => LocalFallback::F32(
            BatchEsn::<f32>::with_precision(model.qesn.clone(), 1),
            LaneReadout::new(&model.readout),
        ),
    }
}

/// First-`stream`-op lane claim: try the home shard's hub once; a denial
/// is sticky so the connection's state never migrates between hub and
/// local fallback.
pub(crate) fn try_acquire_lane(front: &ShardedFront, conn: &mut ConnState) {
    if conn.binding.is_none() && !conn.hub_denied {
        conn.binding = front.acquire_binding(conn.shard_idx);
        match &conn.binding {
            // a tenant connection carries its model onto the hub lane,
            // so the sweeper routes every job for this lane to the
            // tenant's hub (captured per job at submit time)
            Some(b) if conn.model != BASE_MODEL => {
                front.with_binding(b, |s, l| s.bind_lane_model(l, conn.model));
            }
            Some(_) => {}
            None => conn.hub_denied = true,
        }
    }
}

/// Resolve a request's optional `"model"` field against the connection:
/// the FIRST model-bearing op binds the connection to that tenant (it
/// must precede any streaming state — a lane never switches models);
/// later ops must name the same model or omit the field. Shared by both
/// transports.
pub(crate) fn bind_conn_model(
    front: &ShardedFront,
    conn: &mut ConnState,
    wire_model: Option<ModelId>,
) -> Result<()> {
    let Some(m) = wire_model else {
        return Ok(());
    };
    if m == conn.model {
        return Ok(());
    }
    anyhow::ensure!(
        conn.model == BASE_MODEL,
        "connection is bound to model {}; open a new connection for \
         model {m}",
        conn.model
    );
    anyhow::ensure!(
        conn.binding.is_none() && conn.local.is_none(),
        "model binding must precede streaming on a connection"
    );
    // the binding must name a live registry entry; a deleted or
    // never-minted id is the typed refusal
    match front.registry().and_then(|r| r.get(m)) {
        Some(_) => {
            conn.model = m;
            Ok(())
        }
        None => Err(coded_error("unknown_model")),
    }
}

/// Hub-denied streaming step(s) on the connection-local state — the same
/// per-lane arithmetic as a hub lane, so the fallback is bit-identical.
pub(crate) fn stream_fallback(
    model: &Model,
    conn: &mut ConnState,
    input: &[f64],
) -> Vec<f64> {
    let local = conn.local.get_or_insert_with(|| local_fallback(model));
    match local {
        LocalFallback::F64(ls) => stream_local(model, input, ls),
        LocalFallback::F32(engine, ro) => engine
            .sweep_streams_cast(&[(0, input)], ro)
            .pop()
            .unwrap_or_default(),
    }
}

/// The hub's masked stream sweep asserts `D_out = 1`; reject the op at
/// the wire instead of letting a client panic a shared sweeper thread.
pub(crate) fn guard_streamable(model: &Model) -> Result<()> {
    anyhow::ensure!(
        model.readout.w.cols() == 1,
        "stream requires a single-output model (D_out = 1); use predict"
    );
    Ok(())
}

/// A typed serving failure: a stable machine-readable `code` slug plus
/// the human-readable message. Every failure either transport can emit
/// resolves through ONE constructor per code ([`coded_error`]), so the
/// event loop and the threaded path answer each failure mode with the
/// identical message AND the identical `code` field (the error-code
/// contract, documented in DESIGN.md §10).
#[derive(Debug)]
pub struct WireError {
    /// Stable machine-readable slug, e.g. `"commit_empty"`,
    /// `"lane_poisoned"`, `"trainer_budget"`.
    pub code: &'static str,
    msg: String,
    /// For `moved` only: the owning node's advertised address, emitted
    /// as the response's `addr` field so clients can follow the
    /// redirect without parsing the message text.
    pub addr: Option<String>,
}

impl WireError {
    /// The human-readable message (also what `Display` prints).
    pub fn message(&self) -> &str {
        &self.msg
    }
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for WireError {}

/// Wrap a `(code, message)` pair as an `anyhow::Error` carrying a
/// downcastable [`WireError`].
pub(crate) fn coded(code: &'static str, msg: impl Into<String>) -> anyhow::Error {
    anyhow::Error::new(WireError {
        code,
        msg: msg.into(),
        addr: None,
    })
}

/// The cluster redirect: this node does not own the request's
/// connection key; the response carries the owner's address for the
/// client to follow.
pub(crate) fn moved_error(addr: String) -> anyhow::Error {
    anyhow::Error::new(WireError {
        code: "moved",
        msg: format!("key is owned by {addr}; reconnect there"),
        addr: Some(addr),
    })
}

/// Every stable error-code slug of the one-table contract (DESIGN.md
/// §10/§11) — the list [`coded_error`] maps. The retryable subset
/// ([`RETRYABLE_CODES`]) is pinned to this table by a unit test.
pub(crate) const ERROR_CODES: &[&str] = &[
    "commit_empty",
    "commit_singular",
    "trainer_budget",
    "lane_poisoned",
    "restore_mismatch",
    "rollback_unknown_version",
    "hub_full",
    "no_lane",
    "unavailable",
    "overloaded",
    "deadline_exceeded",
    "unknown_lane",
    "moved",
    "restore_corrupt",
    "redirect_loop",
    "unknown_model",
    "model_budget",
    "bad_frame",
];

/// Resolve a sweeper-side error-code slug into the shared typed wire
/// error — the single source of each failure mode's `(code, message)`
/// pair for both transports.
pub(crate) fn coded_error(code: &'static str) -> anyhow::Error {
    let msg = match code {
        "commit_empty" => "nothing to commit: train some rows first",
        "commit_singular" => {
            "commit failed: accumulated system is singular \
             (train more rows or raise alpha)"
        }
        "trainer_budget" => {
            "trainer memory budget exhausted; reset a lane or raise \
             --trainer-budget-mb"
        }
        "lane_poisoned" => {
            "lane quarantined by a contained sweeper fault; \
             reset or restore a checkpoint to recover"
        }
        "restore_mismatch" => {
            "restore rejected: snapshot does not match this server's \
             model/precision or is malformed"
        }
        "rollback_unknown_version" => {
            "rollback failed: version not retained on this lane \
             (the ring keeps the most recent commits; 0 = base readout)"
        }
        "hub_full" => {
            "this op requires a hub streaming lane (hub full); \
             reconnect when capacity frees up"
        }
        "no_lane" => "this op requires an active streaming lane",
        "unavailable" => "service unavailable: sweeper not running",
        "overloaded" => {
            "server overloaded: request shed at admission; \
             retry with backoff"
        }
        "deadline_exceeded" => {
            "deadline exceeded before the request ran; nothing was applied"
        }
        "unknown_lane" => {
            "unknown lane: no such parked lane id or migration target"
        }
        "moved" => {
            "key is owned by another cluster node; follow the addr field"
        }
        "restore_corrupt" => {
            "restore rejected: snapshot failed its integrity check \
             (corrupt or truncated); nothing was applied"
        }
        "redirect_loop" => {
            "redirect loop: moved-hop limit exceeded without reaching \
             an owning node"
        }
        "unknown_model" => {
            "unknown model: not registered on this server \
             (never minted, or deleted)"
        }
        "model_budget" => {
            "model budget exhausted; delete a model or raise --max-models"
        }
        "bad_frame" => {
            "malformed binary frame: the connection's framing cannot be \
             trusted (torn, oversized, or shape-violating frame)"
        }
        other => {
            debug_assert!(false, "unmapped wire error code {other:?}");
            "internal serving error"
        }
    };
    coded(code, msg)
}

/// The deterministic "sweeper gone / job dropped" failure, shared by
/// every path that observes a dead or restarting sweeper.
pub(crate) fn unavailable_error() -> anyhow::Error {
    coded_error("unavailable")
}

/// Error for a `train` op on a connection that couldn't get a hub lane.
pub(crate) fn hub_full_train_error() -> anyhow::Error {
    coded_error("hub_full")
}

/// Error for a `commit` with nothing accumulated (no lane / no rows).
pub(crate) fn nothing_to_commit_error() -> anyhow::Error {
    coded_error("commit_empty")
}

/// Error for a lane-resident op (`checkpoint`, `rollback`) on a
/// connection with no active streaming lane.
pub(crate) fn no_lane_error(op: &str) -> anyhow::Error {
    coded(
        "no_lane",
        format!("{op} requires an active streaming lane on this connection"),
    )
}

/// Map a registry refusal onto its typed wire error — one mapping for
/// both transports, so `create_model`/`delete_model` failures are
/// byte-identical on the wire.
pub(crate) fn registry_error(e: RegistryError) -> anyhow::Error {
    match e {
        RegistryError::Budget { max_models } => coded(
            "model_budget",
            format!(
                "model budget exhausted ({max_models} models registered); \
                 delete one or raise --max-models"
            ),
        ),
        RegistryError::UnknownModel(id) => coded(
            "unknown_model",
            format!("model {id} is not registered on this server"),
        ),
    }
}

/// The cluster ownership guard, shared by both transports: on a
/// clustered node, a KEY-HOMED op (anything that reads or mutates this
/// connection's lane state, including adopting a parked lane) whose
/// connection key hashes to ANOTHER live node answers `moved {addr}`
/// instead of executing. Exempt: `info`/`ping` (introspection must work
/// anywhere), stateless `predict` (any node computes the identical
/// answer), standby delta pushes (`migrate_in` with id + checkpoint —
/// the primary targets a specific replica deliberately), and
/// `shutdown_drain`. Returns the error to answer, or `None` to proceed.
pub(crate) fn ownership_guard(
    front: &ShardedFront,
    key: u64,
    op: &Op,
) -> Option<anyhow::Error> {
    let key_homed = match op {
        Op::Stream(_)
        | Op::Train { .. }
        | Op::Commit { .. }
        | Op::Rollback { .. }
        | Op::Checkpoint
        | Op::Restore(_)
        | Op::Reset
        | Op::Migrate { .. } => true,
        // adopt (id only) and cross-server restore (snapshot only) home
        // with the key; the push form (both) is replica-targeted
        Op::MigrateIn { lane_id, snap } => {
            !(lane_id.is_some() && snap.is_some())
        }
        _ => false,
    };
    if !key_homed {
        return None;
    }
    let addr = front.cluster()?.owned_elsewhere(key)?;
    Some(moved_error(addr))
}

// ---------------------------------------------------------------------------
// transport-agnostic request core
// ---------------------------------------------------------------------------

/// Default ridge α for a `commit` without an explicit `"alpha"`.
pub(crate) const DEFAULT_COMMIT_ALPHA: f64 = 1e-8;

/// Absolute max rows one `train` op may carry (the parse-time sanity
/// bound; the per-model WORK bound below is usually tighter).
pub(crate) const MAX_TRAIN_ROWS_PER_OP: usize = 4096;

/// Per-op Gram-work budget in multiply-accumulates (~0.1–0.3 s of one
/// core). Gram accumulation is `O(F²)` per row ON THE SWEEPER THREAD,
/// so an unbounded op would head-of-line block every other lane on the
/// shard for its whole duration. A fixed row count only bounds the
/// stall for small models; the row cap therefore SCALES with the model:
/// `max_rows = WORK / N²` (clamped to [64, MAX_TRAIN_ROWS_PER_OP]).
/// Larger training sets arrive as multiple ops, which interleave with
/// the shard's serving jobs between queue drains. (The in-process
/// `BatchFront::train` API is uncapped — it's not the untrusted
/// surface.)
const MAX_TRAIN_ROW_WORK: usize = 1 << 28;

/// The work-scaled per-op row cap for a model with `n` features.
pub(crate) fn max_train_rows(n: usize) -> usize {
    (MAX_TRAIN_ROW_WORK / (n * n).max(1)).clamp(64, MAX_TRAIN_ROWS_PER_OP)
}

/// Reject a `train` op whose row count exceeds the model's work-scaled
/// cap — shared by both transports so the error is identical on the
/// wire.
pub(crate) fn guard_train_rows(model: &Model, rows: usize) -> Result<()> {
    let cap = max_train_rows(model.esn.n());
    anyhow::ensure!(
        rows <= cap,
        "train op too large ({rows} rows; max {cap} per op at N={} — \
         split the stream across multiple ops)",
        model.esn.n()
    );
    Ok(())
}

/// A classified request line. Parsing is transport-independent; the
/// transports differ only in how they wait for the shard queues.
pub(crate) enum Op {
    Info,
    /// Cluster liveness probe: answered inline by both transports
    /// (never queued behind sweeps), so gossip RTTs measure the wire,
    /// not the workload.
    Ping,
    Predict(Vec<f64>),
    Stream(Vec<f64>),
    Train { input: Vec<f64>, target: Vec<f64> },
    Commit { alpha: f64 },
    Rollback { version: u64 },
    Checkpoint,
    Restore(Box<LaneSnapshot>),
    Reset,
    /// Live lane migration to another shard of THIS server (`None` =
    /// server picks the coldest shard).
    Migrate { shard: Option<usize> },
    /// The receiving half of cross-server mobility. `lane_id` + `snap`
    /// parks a standby delta; `lane_id` alone adopts a parked lane onto
    /// this connection (promotion); `snap` alone restores a foreign
    /// checkpoint onto this connection (cross-server migration).
    MigrateIn {
        lane_id: Option<u64>,
        snap: Option<Box<LaneSnapshot>>,
    },
    /// Graceful drain: stop accepting, finish in-flight work, flush,
    /// spill live lanes (with `--drain-checkpoint`), exit.
    ShutdownDrain,
    /// Mint (or idempotently re-reference) a per-tenant reservoir from a
    /// deterministic DPG recipe — same recipe ⇒ same id and the same
    /// planes, on every node, so failover needs no model transfer.
    CreateModel { recipe: ModelRecipe },
    /// Evict a tenant model. Lanes still bound to it keep serving off
    /// their hub's cached `Arc` until released; everything new answers
    /// `unknown_model`.
    DeleteModel { model: ModelId },
}

/// Wrap a snapshot-decode failure as the typed `restore_corrupt` error:
/// a corrupt or truncated checkpoint (a torn spill file, a tampered
/// snapshot, a cut-off frame) is a first-class refusal on BOTH
/// transports — never a parse panic, and nothing is applied.
fn restore_corrupt_error(e: anyhow::Error) -> anyhow::Error {
    coded(
        "restore_corrupt",
        format!("snapshot failed its integrity check ({e}); nothing applied"),
    )
}

/// Parse an optional non-negative integer field (`None` when absent or
/// JSON null).
fn parse_opt_uint(req: &Json, field: &str) -> Result<Option<u64>> {
    match req.get(field) {
        None | Some(Json::Null) => Ok(None),
        Some(v) => {
            let x = v
                .as_f64()
                .ok_or_else(|| anyhow!("non-numeric '{field}'"))?;
            anyhow::ensure!(
                x.is_finite() && x >= 0.0 && x.fract() == 0.0,
                "'{field}' must be a non-negative integer"
            );
            Ok(Some(x as u64))
        }
    }
}

/// Classify one request line into `(op, deadline budget, model)`. Every
/// op accepts an optional `"deadline_ms"`: the client's end-to-end
/// budget for this request, honored at queue admission AND when the
/// sweeper picks the job up — an expired job answers the typed
/// `deadline_exceeded` error without touching lane state. Every
/// SERVING op additionally accepts an optional `"model"` naming a
/// registry tenant; the first such op binds the connection
/// ([`bind_conn_model`]). `create_model`/`delete_model` operate ON the
/// registry, so their fields are operands, not a connection binding.
pub(crate) fn parse_op(
    line: &str,
) -> Result<(Op, Option<Duration>, Option<ModelId>)> {
    let req = parse(line.trim())?;
    let deadline = match req.get("deadline_ms") {
        None | Some(Json::Null) => None,
        Some(v) => {
            let ms = v
                .as_f64()
                .ok_or_else(|| anyhow!("non-numeric 'deadline_ms'"))?;
            anyhow::ensure!(
                ms.is_finite() && ms >= 0.0,
                "'deadline_ms' must be a finite non-negative number"
            );
            Some(
                Duration::try_from_secs_f64(ms / 1000.0)
                    .map_err(|_| anyhow!("'deadline_ms' out of range"))?,
            )
        }
    };
    let op = req
        .get("op")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("missing 'op'"))?;
    let op = match op {
        "info" => Op::Info,
        "ping" => Op::Ping,
        "predict" => Op::Predict(parse_input(&req)?),
        "stream" => Op::Stream(parse_input(&req)?),
        "train" => {
            let input = parse_input(&req)?;
            let target = parse_vec(&req, "target")?;
            anyhow::ensure!(
                input.len() == target.len(),
                "train input/target length mismatch ({} vs {})",
                input.len(),
                target.len()
            );
            anyhow::ensure!(
                input.len() <= MAX_TRAIN_ROWS_PER_OP,
                "train op too large ({} rows; max {MAX_TRAIN_ROWS_PER_OP} \
                 per op — split the stream across multiple ops)",
                input.len()
            );
            Op::Train { input, target }
        }
        "commit" => {
            let alpha = match req.get("alpha") {
                None => DEFAULT_COMMIT_ALPHA,
                Some(v) => v
                    .as_f64()
                    .ok_or_else(|| anyhow!("non-numeric 'alpha'"))?,
            };
            anyhow::ensure!(
                alpha.is_finite() && alpha >= 0.0,
                "'alpha' must be a finite non-negative number"
            );
            Op::Commit { alpha }
        }
        "rollback" => {
            // default 0 = the base model readout
            let version = parse_opt_uint(&req, "version")?.unwrap_or(0);
            Op::Rollback { version }
        }
        "checkpoint" => Op::Checkpoint,
        "restore" => {
            let snap = req
                .get("checkpoint")
                .ok_or_else(|| anyhow!("missing 'checkpoint' object"))?;
            Op::Restore(Box::new(
                snapshot_from_json(snap).map_err(restore_corrupt_error)?,
            ))
        }
        "reset" => Op::Reset,
        "migrate" => Op::Migrate {
            shard: parse_opt_uint(&req, "shard")?.map(|s| s as usize),
        },
        "migrate_in" => {
            let lane_id = parse_opt_uint(&req, "lane_id")?;
            let snap = match req.get("checkpoint") {
                None | Some(Json::Null) => None,
                Some(j) => Some(Box::new(
                    snapshot_from_json(j).map_err(restore_corrupt_error)?,
                )),
            };
            anyhow::ensure!(
                lane_id.is_some() || snap.is_some(),
                "migrate_in requires 'lane_id' and/or 'checkpoint'"
            );
            Op::MigrateIn { lane_id, snap }
        }
        "shutdown_drain" => Op::ShutdownDrain,
        "create_model" => {
            let seed = parse_opt_uint(&req, "seed")?
                .ok_or_else(|| anyhow!("create_model requires integer 'seed'"))?;
            let n = parse_opt_uint(&req, "n")?
                .ok_or_else(|| anyhow!("create_model requires integer 'n'"))?
                as usize;
            let sr = match req.get("spectral_radius") {
                None | Some(Json::Null) => DEFAULT_TENANT_SR,
                Some(v) => v
                    .as_f64()
                    .ok_or_else(|| anyhow!("non-numeric 'spectral_radius'"))?,
            };
            let prior = match req.get("lambda_prior") {
                None | Some(Json::Null) => "uniform",
                Some(v) => v
                    .as_str()
                    .ok_or_else(|| anyhow!("non-string 'lambda_prior'"))?,
            };
            let recipe =
                ModelRecipe::new(seed, n, sr, prior).map_err(|e| anyhow!(e))?;
            Op::CreateModel { recipe }
        }
        "delete_model" => Op::DeleteModel {
            model: parse_opt_uint(&req, "model")?.ok_or_else(|| {
                anyhow!("delete_model requires integer 'model'")
            })?,
        },
        other => return Err(anyhow!("unknown op {other:?}")),
    };
    // the sticky connection binding — registry ops carry no binding
    // (their "model" field, if any, is the operand)
    let model = match &op {
        Op::CreateModel { .. } | Op::DeleteModel { .. } => None,
        _ => parse_opt_uint(&req, "model")?,
    };
    Ok((op, deadline, model))
}

// ---------------------------------------------------------------------------
// lane-snapshot wire codec
// ---------------------------------------------------------------------------

/// Encode a [`LaneSnapshot`] as the wire object of a `checkpoint`
/// response. Every f64 prints in shortest-form round-trip notation, so
/// `snapshot_from_json(snapshot_to_json(s)) == s` bit-for-bit (tested).
pub(crate) fn snapshot_to_json(snap: &LaneSnapshot) -> Json {
    let nums = |v: &[f64]| Json::Arr(v.iter().map(|&x| Json::Num(x)).collect());
    let mut fields = vec![
        ("n", Json::Num(snap.n as f64)),
        ("precision", Json::Str(snap.precision.name().into())),
        ("state", nums(&snap.state)),
        ("active_version", Json::Num(snap.active_version as f64)),
        ("next_version", Json::Num(snap.next_version as f64)),
        (
            "versions",
            Json::Arr(
                snap.versions
                    .iter()
                    .map(|(v, w, b)| {
                        Json::obj(vec![
                            ("version", Json::Num(*v as f64)),
                            ("w", nums(w)),
                            ("b", Json::Num(*b)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ];
    if let Some(raw) = &snap.trainer {
        let mut t = vec![
            ("f", Json::Num(raw.f as f64)),
            ("d", Json::Num(raw.d as f64)),
            ("g", nums(&raw.g)),
            ("b", nums(&raw.b)),
            ("col_sums", nums(&raw.col_sums)),
            ("y_sums", nums(&raw.y_sums)),
            ("rows", Json::Num(raw.rows as f64)),
        ];
        if let Some(carry) = &raw.carry {
            t.push(("carry", nums(carry)));
        }
        fields.push(("trainer", Json::obj(t)));
    }
    Json::obj(fields)
}

/// Decode the wire form back into a [`LaneSnapshot`]. Shape errors are
/// rejected here (malformed JSON); SEMANTIC validation — dimensions
/// against the serving model, version-ring invariants, finiteness —
/// happens sweeper-side in `restore`, which answers `restore_mismatch`.
pub(crate) fn snapshot_from_json(j: &Json) -> Result<LaneSnapshot> {
    let nums = |field: &str| -> Result<Vec<f64>> {
        j.get(field)
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("checkpoint: missing '{field}' array"))?
            .iter()
            .map(|v| {
                v.as_f64()
                    .ok_or_else(|| anyhow!("checkpoint: non-numeric {field}"))
            })
            .collect()
    };
    let int = |field: &str| -> Result<u64> {
        j.get(field)
            .and_then(Json::as_f64)
            .filter(|x| x.is_finite() && *x >= 0.0 && x.fract() == 0.0)
            .map(|x| x as u64)
            .ok_or_else(|| anyhow!("checkpoint: missing integer '{field}'"))
    };
    let precision = match j.get("precision").and_then(Json::as_str) {
        Some("f64") => Precision::F64,
        Some("f32") => Precision::F32,
        _ => return Err(anyhow!("checkpoint: missing 'precision' (f64|f32)")),
    };
    let versions = j
        .get("versions")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("checkpoint: missing 'versions' array"))?
        .iter()
        .map(|e| {
            let v = e
                .get("version")
                .and_then(Json::as_f64)
                .filter(|x| x.is_finite() && *x >= 0.0 && x.fract() == 0.0)
                .ok_or_else(|| anyhow!("checkpoint: bad version entry"))?;
            let w = e
                .get("w")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("checkpoint: version entry missing 'w'"))?
                .iter()
                .map(|x| {
                    x.as_f64()
                        .ok_or_else(|| anyhow!("checkpoint: non-numeric w"))
                })
                .collect::<Result<Vec<f64>>>()?;
            let b = e
                .get("b")
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow!("checkpoint: version entry missing 'b'"))?;
            Ok((v as u64, w, b))
        })
        .collect::<Result<Vec<_>>>()?;
    let trainer = match j.get("trainer") {
        None | Some(Json::Null) => None,
        Some(t) => {
            let tnums = |field: &str| -> Result<Vec<f64>> {
                t.get(field)
                    .and_then(Json::as_arr)
                    .ok_or_else(|| {
                        anyhow!("checkpoint: trainer missing '{field}'")
                    })?
                    .iter()
                    .map(|v| {
                        v.as_f64().ok_or_else(|| {
                            anyhow!("checkpoint: non-numeric trainer {field}")
                        })
                    })
                    .collect()
            };
            let tint = |field: &str| -> Result<u64> {
                t.get(field)
                    .and_then(Json::as_f64)
                    .filter(|x| x.is_finite() && *x >= 0.0 && x.fract() == 0.0)
                    .map(|x| x as u64)
                    .ok_or_else(|| {
                        anyhow!("checkpoint: trainer missing integer '{field}'")
                    })
            };
            let carry = match t.get("carry") {
                None | Some(Json::Null) => None,
                Some(_) => Some(tnums("carry")?),
            };
            Some(GramAccRaw {
                f: tint("f")? as usize,
                d: tint("d")? as usize,
                g: tnums("g")?,
                b: tnums("b")?,
                col_sums: tnums("col_sums")?,
                y_sums: tnums("y_sums")?,
                rows: tint("rows")?,
                carry,
            })
        }
    };
    Ok(LaneSnapshot {
        n: int("n")? as usize,
        precision,
        state: nums("state")?,
        trainer,
        active_version: int("active_version")?,
        next_version: int("next_version")?,
        versions,
    })
}

pub(crate) fn info_response(front: &ShardedFront, conn: &ConnState) -> Json {
    let model = front.model();
    let home = front.shard(conn.shard_idx);
    let depths = front.queue_depths();
    let sweeps = front.sweep_counts();
    let mut fields = vec![
        ("ok", Json::Bool(true)),
        ("n", Json::Num(model.esn.n() as f64)),
        ("slots", Json::Num(model.esn.spec.slots() as f64)),
        ("n_real", Json::Num(model.esn.spec.n_real as f64)),
        ("spectral_radius", Json::Num(model.esn.spec.radius())),
        ("precision", Json::Str(model.precision.name().into())),
        ("shards", Json::Num(front.shards() as f64)),
        ("shard", Json::Num(conn.shard_idx as f64)),
        (
            "queue_depth",
            Json::Num(depths.iter().sum::<usize>() as f64),
        ),
        ("sweeps", Json::Num(sweeps.iter().sum::<u64>() as f64)),
        (
            "shard_queue_depth",
            Json::Arr(depths.iter().map(|&d| Json::Num(d as f64)).collect()),
        ),
        (
            "shard_sweeps",
            Json::Arr(sweeps.iter().map(|&s| Json::Num(s as f64)).collect()),
        ),
        ("holdoff_us", Json::Num(home.holdoff_us() as f64)),
        // the window the home shard's sweeper will actually use next —
        // equals holdoff_us in fixed mode; tracks the arrival EWMA
        // (zero when idle) under --holdoff-auto
        (
            "holdoff_effective_us",
            Json::Num(home.holdoff_effective_us() as f64),
        ),
        ("stream_lane", match &conn.binding {
            Some(b) => Json::Num(b.home_lane() as f64),
            None => Json::Null,
        }),
        // self-healing metrics (PR 7): identical on both transports
        ("lanes_migrated", Json::Num(front.lanes_migrated() as f64)),
        ("jobs_shed", Json::Num(front.jobs_shed_total() as f64)),
        (
            "deadline_misses",
            Json::Num(front.deadline_misses_total() as f64),
        ),
        (
            "standby_lag_lanes",
            Json::Num(front.standby_lag_lanes() as f64),
        ),
        ("parked_lanes", Json::Num(front.parked_lanes() as f64)),
        (
            "shard_occupancy_ewma",
            Json::Arr(
                front
                    .update_occupancy_ewma()
                    .into_iter()
                    .map(Json::Num)
                    .collect(),
            ),
        ),
        // the connection's mobile lane identity: `lane_id` names the
        // lane in standby pushes and drain spills; `lane_shard` is the
        // CURRENT home (it changes when the lane migrates — `shard`
        // above stays the dispatch home for this connection's key)
        ("lane_id", match &conn.binding {
            Some(b) => Json::Num(b.id() as f64),
            None => Json::Null,
        }),
        ("lane_shard", match &conn.binding {
            Some(b) => Json::Num(b.home_shard() as f64),
            None => Json::Null,
        }),
    ];
    // multi-tenant registry (PR 9): tenant count, budget, which model
    // THIS connection serves, and bound-lane counts per model — the
    // per-tenant occupancy view an operator reads to see who holds lanes
    if let Some(reg) = front.registry() {
        fields.push(("models", Json::Num(reg.len() as f64)));
        fields.push(("max_models", Json::Num(reg.max_models() as f64)));
        fields.push(("model", Json::Num(conn.model as f64)));
        fields.push((
            "model_lanes",
            Json::Obj(
                front
                    .lane_counts_by_model()
                    .into_iter()
                    .map(|(m, c)| (m.to_string(), Json::Num(c as f64)))
                    .collect(),
            ),
        ));
    }
    // sweeper core pinning (PR 9): per-shard pinned core, null where
    // unpinned — only reported when at least one shard pinned
    let pins = front.pinned_cores();
    if pins.iter().any(Option::is_some) {
        fields.push((
            "pinned_cores",
            Json::Arr(
                pins.into_iter()
                    .map(|p| match p {
                        Some(c) => Json::Num(c as f64),
                        None => Json::Null,
                    })
                    .collect(),
            ),
        ));
    }
    // standby fan-out (PR 8): per-replica lag alongside the worst-case
    // scalar above, so an operator sees WHICH replica is behind
    let replicas = front.standby_replicas();
    if replicas > 0 {
        fields.push(("standby_replicas", Json::Num(replicas as f64)));
        fields.push((
            "standby_lag_per_replica",
            Json::Arr(
                (0..replicas)
                    .map(|i| Json::Num(front.standby_lag_lanes_for(i) as f64))
                    .collect(),
            ),
        ));
    }
    // cluster membership (PR 8): only on clustered nodes. cluster_owner
    // is the live node owning THIS connection's key — after a failover,
    // reading it from any member names where the client should be.
    if let Some(c) = front.cluster() {
        fields.push(("advertise", Json::Str(c.advertise().into())));
        fields.push(("cluster_nodes", Json::Num(c.members() as f64)));
        fields.push(("cluster_live", Json::Num(c.live_members() as f64)));
        fields.push(("ring_epoch", Json::Num(c.epoch() as f64)));
        fields.push((
            "cluster_owner",
            Json::Str(c.owner_for_key(conn.key)),
        ));
        let status = c.peer_status();
        fields.push((
            "peer_alive",
            Json::Arr(
                status.iter().map(|(_, a, _)| Json::Bool(*a)).collect(),
            ),
        ));
        fields.push((
            "peer_rtt_us",
            Json::Arr(
                status.iter().map(|(_, _, rtt)| Json::Num(*rtt)).collect(),
            ),
        ));
    }
    // wire-path scale-out (PR 10): only the event-loop transport
    // publishes poll stats — the poll-thread count, THIS connection's
    // home poll thread, binary-upgraded connection count, and the
    // per-thread readiness-round counters (a stuck thread reads as a
    // frozen counter while its siblings advance)
    if let Some(ps) = front.poll_stats() {
        fields.push(("poll_threads", Json::Num(ps.threads() as f64)));
        fields.push(("poll_thread", match conn.poll_thread {
            Some(t) => Json::Num(t as f64),
            None => Json::Null,
        }));
        fields.push(("binary_conns", Json::Num(ps.binary_conns() as f64)));
        fields.push((
            "poll_rounds",
            Json::Arr(
                ps.rounds().into_iter().map(|r| Json::Num(r as f64)).collect(),
            ),
        ));
    }
    Json::obj(fields)
}

/// `ping` reply — the gossip probe's answer, also useful to operators
/// as a cheap liveness check. Carries the node's cluster identity when
/// clustered so a misconfigured peer list is visible on the wire.
pub(crate) fn pong_response(front: &ShardedFront) -> Json {
    let mut fields = vec![
        ("ok", Json::Bool(true)),
        ("pong", Json::Bool(true)),
    ];
    if let Some(c) = front.cluster() {
        fields.push(("advertise", Json::Str(c.advertise().into())));
        fields.push(("ring_epoch", Json::Num(c.epoch() as f64)));
    }
    Json::obj(fields)
}

pub(crate) fn predict_response(output: Vec<f64>, steps: usize, dt_s: f64) -> Json {
    let dt = dt_s.max(1e-12);
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        (
            "output",
            Json::Arr(output.into_iter().map(Json::Num).collect()),
        ),
        ("steps_per_sec", Json::Num(steps as f64 / dt)),
    ])
}

pub(crate) fn stream_response(outs: Vec<f64>) -> Json {
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("output", Json::Arr(outs.into_iter().map(Json::Num).collect())),
    ])
}

/// `train` reply: the lane's TOTAL accumulated row count (not just this
/// op's), so a client can track its online training set size.
pub(crate) fn train_response(rows: u64) -> Json {
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("rows", Json::Num(rows as f64)),
    ])
}

pub(crate) fn ok_response() -> Json {
    Json::obj(vec![("ok", Json::Bool(true))])
}

/// `commit` / `rollback` / `restore` reply: the lane's now-active
/// committed-readout version id (0 = base model readout).
pub(crate) fn version_response(version: u64) -> Json {
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("version", Json::Num(version as f64)),
    ])
}

/// `migrate` reply: the lane's new home and its active readout version.
pub(crate) fn migrate_response(shard: usize, lane: usize, version: u64) -> Json {
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("shard", Json::Num(shard as f64)),
        ("lane", Json::Num(lane as f64)),
        ("version", Json::Num(version as f64)),
    ])
}

/// `checkpoint` reply: the encoded lane snapshot.
pub(crate) fn checkpoint_response(snap: &LaneSnapshot) -> Json {
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("checkpoint", snapshot_to_json(snap)),
    ])
}

pub(crate) fn error_response(e: &anyhow::Error) -> Json {
    let mut fields = vec![
        ("ok", Json::Bool(false)),
        ("error", Json::Str(format!("{e:#}"))),
    ];
    // typed failures additionally carry their stable machine-readable
    // code — identical on both transports by construction (one
    // constructor per code)
    if let Some(we) = e.downcast_ref::<WireError>() {
        fields.push(("code", Json::Str(we.code.into())));
        // `moved` carries the owning node's address for the client
        if let Some(addr) = &we.addr {
            fields.push(("addr", Json::Str(addr.clone())));
        }
    }
    Json::obj(fields)
}

// ---------------------------------------------------------------------------
// threaded transport: blocking per-connection handler
// ---------------------------------------------------------------------------

fn handle_connection(
    front: Arc<ShardedFront>,
    conn_key: u64,
    stream: TcpStream,
    ctl: &DrainCtl,
    id: u64,
) -> Result<()> {
    let mut conn = ConnState::new(conn_key, front.shard_for_key(conn_key));
    let result = serve_lines(&front, &mut conn, stream, ctl);
    ctl.streams.lock().unwrap().remove(&id);
    if let Some(b) = conn.binding.take() {
        if ctl.draining.load(Ordering::SeqCst) {
            // drain keeps the lane alive so the accept loop can spill it
            ctl.keep.lock().unwrap().push(b);
        } else {
            front.release_binding(&b);
        }
    }
    result
}

fn serve_lines(
    front: &ShardedFront,
    conn: &mut ConnState,
    stream: TcpStream,
    ctl: &DrainCtl,
) -> Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut out = stream;
    // --- protocol negotiation ---------------------------------------
    // The connection's first bytes pick its codec. A proper prefix of
    // the binary magic keeps probing one byte at a time; the first
    // divergence makes this a JSON connection with the probed bytes as
    // the head of its first line ('{' and '\n' diverge at byte 0, so a
    // probe never eats past the first line). A full magic match reads
    // the rest of the 8-byte hello and upgrades to binary frames.
    let mut probe: Vec<u8> = Vec::with_capacity(binframe::HELLO_LEN);
    let binary = loop {
        let mut b = [0u8; 1];
        match reader.read(&mut b) {
            Ok(0) => {
                // EOF mid-probe: nothing arrived → clean close;
                // otherwise the probed bytes are a final partial line,
                // handled below exactly as read_line would have
                if probe.is_empty() {
                    return Ok(());
                }
                break false;
            }
            Ok(_) => {}
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e.into()),
        }
        probe.push(b[0]);
        if probe.len() <= binframe::MAGIC.len() {
            if probe[..] != binframe::MAGIC[..probe.len()] {
                break false; // JSON: probe starts the first line
            }
        } else if probe.len() == binframe::HELLO_LEN {
            break true; // full hello received, magic matched
        }
    };
    if binary {
        if probe[..] != binframe::client_hello()[..] {
            // magic matched but version/reserved bytes did not — the
            // peer speaks a framing we don't; refuse typed, close
            out.write_all(&binframe::bad_frame_close_frame())?;
            return Ok(());
        }
        out.write_all(&binframe::server_hello())?;
        front.note_binary_conn();
        return serve_frames(front, conn, reader, out, ctl);
    }
    // --- JSON codec -------------------------------------------------
    // `carry` holds the probed head of the FIRST line (possibly already
    // newline-terminated); later rounds start empty. `read_until` plus
    // the UTF-8 check below is exactly `read_line`.
    let mut carry = probe;
    loop {
        let mut bytes = std::mem::take(&mut carry);
        if bytes.last() != Some(&b'\n') {
            let had_head = !bytes.is_empty();
            if reader.read_until(b'\n', &mut bytes)? == 0 && !had_head {
                return Ok(()); // client closed (or the drain woke us with EOF)
            }
        }
        let line = std::str::from_utf8(&bytes).map_err(|e| {
            std::io::Error::new(std::io::ErrorKind::InvalidData, e)
        })?;
        let mut drain_req = false;
        let response = match handle_request(front, conn, line, &mut drain_req) {
            Ok(json) => json,
            Err(e) => error_response(&e),
        };
        out.write_all(response.to_string_compact().as_bytes())?;
        out.write_all(b"\n")?;
        if drain_req {
            ctl.draining.store(true, Ordering::SeqCst);
        }
        if ctl.draining.load(Ordering::SeqCst) {
            // the reply above flushed; exit between requests, cleanly
            return Ok(());
        }
    }
}

/// The binary-frame twin of the JSON loop above: one frame in, one
/// frame out, the SAME request handler, the same drain semantics.
/// Framing-lost conditions (a torn or oversized frame) answer the typed
/// `bad_frame` refusal and close — the length prefix can no longer be
/// trusted as a skip distance. In-body shape violations are ordinary
/// typed errors (the frame was consumed exactly) and the connection
/// survives them.
fn serve_frames(
    front: &ShardedFront,
    conn: &mut ConnState,
    mut reader: BufReader<TcpStream>,
    mut out: TcpStream,
    ctl: &DrainCtl,
) -> Result<()> {
    let mut frame = Vec::new();
    loop {
        let body = match binframe::read_frame(&mut reader)? {
            binframe::ReadFrame::Eof => return Ok(()),
            binframe::ReadFrame::TornEof | binframe::ReadFrame::Oversized => {
                out.write_all(&binframe::bad_frame_close_frame())?;
                return Ok(());
            }
            binframe::ReadFrame::Frame(body) => body,
        };
        let mut drain_req = false;
        let response = match binframe::decode_request(&body).and_then(
            |(op, budget, wire_model)| {
                handle_parsed_request(
                    front, conn, op, budget, wire_model, &mut drain_req,
                )
            },
        ) {
            Ok(json) => json,
            Err(e) => error_response(&e),
        };
        frame.clear();
        binframe::encode_response(&response, &mut frame);
        out.write_all(&frame)?;
        if drain_req {
            ctl.draining.store(true, Ordering::SeqCst);
        }
        if ctl.draining.load(Ordering::SeqCst) {
            return Ok(());
        }
    }
}

/// One request → one response, blocking on the shard queues. The event
/// loop mirrors this decision tree with event replies in
/// `server/poll.rs::dispatch` — the two must stay semantically aligned
/// (enforced by the bit-identity tests below). A `shutdown_drain` op
/// sets `drain_out` AFTER its ok-reply is built; the transport flushes
/// the reply and then begins the drain.
fn handle_request(
    front: &ShardedFront,
    conn: &mut ConnState,
    line: &str,
    drain_out: &mut bool,
) -> Result<Json> {
    let (op, budget, wire_model) = parse_op(line)?;
    handle_parsed_request(front, conn, op, budget, wire_model, drain_out)
}

/// The transport-independent half of [`handle_request`]: op already
/// parsed (by the JSON parser OR the binary frame decoder — both feed
/// the SAME `Op`), response built as the SAME `Json` either way. This
/// is the error-code parity contract's enforcement point: a binary
/// connection cannot produce a different refusal because there is only
/// one decision tree to refuse from.
pub(crate) fn handle_parsed_request(
    front: &ShardedFront,
    conn: &mut ConnState,
    op: Op,
    budget: Option<Duration>,
    wire_model: Option<ModelId>,
    drain_out: &mut bool,
) -> Result<Json> {
    let model = front.model();
    // cluster ownership: key-homed ops on a key another live node owns
    // answer `moved {addr}` before touching any lane state
    if let Some(e) = ownership_guard(front, conn.key, &op) {
        return Err(e);
    }
    // the sticky model binding (no-op unless the line names a model)
    bind_conn_model(front, conn, wire_model)?;
    // the budget starts when the request is UNDERSTOOD; Instant addition
    // saturates via checked_add (an astronomically large budget = none)
    let deadline = budget.and_then(|d| Instant::now().checked_add(d));
    match op {
        Op::Info => Ok(info_response(front, conn)),
        Op::Ping => Ok(pong_response(front)),
        Op::Predict(input) => {
            let steps = input.len();
            let t = Timer::start();
            // stateless: dealt to the least-loaded shard, not the home
            let output =
                front.predict_deadline_model(conn.model, input, deadline)?;
            Ok(predict_response(output, steps, t.elapsed_s()))
        }
        Op::Stream(input) => {
            // minted tenants are single-output by construction; the
            // guard is the BASE model's multi-output refusal
            if conn.model == BASE_MODEL {
                guard_streamable(model)?;
            }
            // first stream op: try to claim a lane on the home shard's
            // hub (and never switch engines once this connection's
            // streaming has started)
            try_acquire_lane(front, conn);
            let outs = match &conn.binding {
                Some(b) => {
                    let outs = front
                        .with_binding(b, |s, l| s.stream_deadline(l, input, deadline))?;
                    b.mark_dirty();
                    outs
                }
                // the local fallback serves only the base model (its
                // state is built from the base planes); a tenant
                // connection denied a hub lane gets the typed refusal
                None if conn.model != BASE_MODEL => {
                    return Err(coded_error("hub_full"))
                }
                None => stream_fallback(model, conn, &input),
            };
            Ok(stream_response(outs))
        }
        Op::Train { input, target } => {
            if conn.model == BASE_MODEL {
                guard_streamable(model)?;
            }
            // the per-op work cap scales with the model the rows land
            // on — the connection's tenant, not necessarily the base
            let cap_model = if conn.model == BASE_MODEL {
                Arc::clone(model)
            } else {
                front
                    .registry()
                    .and_then(|r| r.get(conn.model))
                    .ok_or_else(|| coded_error("unknown_model"))?
            };
            guard_train_rows(&cap_model, input.len())?;
            // training is lane-resident: the Gram accumulator lives next
            // to the lane state on the home shard's sweeper
            try_acquire_lane(front, conn);
            match &conn.binding {
                Some(b) => {
                    let rows = front.with_binding(b, |s, l| {
                        s.train_deadline(l, input, target, deadline)
                    })?;
                    b.mark_dirty();
                    Ok(train_response(rows))
                }
                None => Err(hub_full_train_error()),
            }
        }
        Op::Commit { alpha } => match &conn.binding {
            Some(b) => {
                let version = front
                    .with_binding(b, |s, l| s.commit_deadline(l, alpha, deadline))?;
                b.mark_dirty();
                Ok(version_response(version))
            }
            None => Err(nothing_to_commit_error()),
        },
        Op::Rollback { version } => match &conn.binding {
            Some(b) => {
                let active = front
                    .with_binding(b, |s, l| s.rollback_deadline(l, version, deadline))?;
                b.mark_dirty();
                Ok(version_response(active))
            }
            None => Err(no_lane_error("rollback")),
        },
        Op::Checkpoint => match &conn.binding {
            Some(b) => {
                let snap = front
                    .with_binding(b, |s, l| s.checkpoint_deadline(l, deadline))?;
                Ok(checkpoint_response(&snap))
            }
            None => Err(no_lane_error("checkpoint")),
        },
        Op::Restore(snap) => {
            if conn.model == BASE_MODEL {
                guard_streamable(model)?;
            }
            // restore targets a hub lane (acquiring one on first use,
            // like stream); it also supersedes any local-fallback state
            try_acquire_lane(front, conn);
            match &conn.binding {
                Some(b) => {
                    let active = front
                        .with_binding(b, |s, l| s.restore_deadline(l, *snap, deadline))?;
                    b.mark_dirty();
                    conn.clear_local();
                    Ok(version_response(active))
                }
                None => Err(hub_full_train_error()),
            }
        }
        Op::Reset => {
            if let Some(b) = &conn.binding {
                front.with_binding(b, |s, l| s.reset_deadline(l, deadline))?;
                b.mark_dirty();
            }
            conn.clear_local();
            Ok(ok_response())
        }
        Op::Migrate { shard } => handle_migrate(front, conn, shard),
        Op::MigrateIn { lane_id, snap } => {
            handle_migrate_in(front, conn, lane_id, snap, deadline)
        }
        Op::ShutdownDrain => {
            *drain_out = true;
            Ok(ok_response())
        }
        Op::CreateModel { recipe } => handle_create_model(front, &recipe),
        Op::DeleteModel { model } => handle_delete_model(front, model),
    }
}

/// `create_model`: mint (or idempotently re-reference) a tenant model
/// from its deterministic recipe. Shared by both transports.
pub(crate) fn handle_create_model(
    front: &ShardedFront,
    recipe: &ModelRecipe,
) -> Result<Json> {
    let reg = front
        .registry()
        .ok_or_else(|| anyhow!("this server has no model registry"))?;
    let (id, created) = reg.create(recipe).map_err(registry_error)?;
    Ok(Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("model", Json::Num(id as f64)),
        ("created", Json::Bool(created)),
    ]))
}

/// `delete_model`: evict a tenant from the registry. Shared by both
/// transports.
pub(crate) fn handle_delete_model(
    front: &ShardedFront,
    model: ModelId,
) -> Result<Json> {
    let reg = front
        .registry()
        .ok_or_else(|| anyhow!("this server has no model registry"))?;
    reg.delete(model).map_err(registry_error)?;
    Ok(ok_response())
}

/// `migrate`: move this connection's live lane to another shard
/// (coldest when unspecified), mid-stream, bit-invisibly. Shared by
/// both transports.
pub(crate) fn handle_migrate(
    front: &ShardedFront,
    conn: &mut ConnState,
    shard: Option<usize>,
) -> Result<Json> {
    match &conn.binding {
        Some(b) => {
            let (dst, lane, version) =
                front.migrate_binding(b, shard).map_err(coded_error)?;
            Ok(migrate_response(dst, lane, version))
        }
        None => Err(no_lane_error("migrate")),
    }
}

/// `migrate_in`: the receiving half of cross-server lane mobility,
/// shared by both transports. Three forms (see [`Op::MigrateIn`]):
/// a standby delta push (`lane_id` + `checkpoint`, parked without
/// occupying a hub lane), a promotion adopt (`lane_id` alone), and a
/// cross-server restore (`checkpoint` alone).
pub(crate) fn handle_migrate_in(
    front: &ShardedFront,
    conn: &mut ConnState,
    lane_id: Option<u64>,
    snap: Option<Box<LaneSnapshot>>,
    deadline: Option<Instant>,
) -> Result<Json> {
    let model = front.model();
    match (lane_id, snap) {
        (Some(id), Some(snap)) => {
            // push: validate against OUR model up front so a primary
            // pointed at the wrong replica fails its push loudly
            // instead of parking garbage that can never be adopted
            if snap.n != model.esn.n() || snap.precision != model.precision {
                return Err(coded_error("restore_mismatch"));
            }
            if front.park(id, *snap) {
                Ok(ok_response())
            } else {
                Err(coded_error("hub_full"))
            }
        }
        (Some(id), None) => {
            // adopt: restore the parked delta onto THIS connection's
            // lane; the snapshot is only unparked once the restore
            // succeeded, so a failed adopt can be retried
            guard_streamable(model)?;
            let parked = front
                .parked_snapshot(id)
                .ok_or_else(|| coded_error("unknown_lane"))?;
            try_acquire_lane(front, conn);
            match &conn.binding {
                Some(b) => {
                    let active = front.with_binding(b, |s, l| {
                        s.restore_deadline(l, parked, deadline)
                    })?;
                    b.mark_dirty();
                    front.unpark(id);
                    conn.clear_local();
                    Ok(version_response(active))
                }
                None => Err(hub_full_train_error()),
            }
        }
        (None, Some(snap)) => {
            // cross-server migrate: restore semantics on a fresh lane
            guard_streamable(model)?;
            try_acquire_lane(front, conn);
            match &conn.binding {
                Some(b) => {
                    let active = front.with_binding(b, |s, l| {
                        s.restore_deadline(l, *snap, deadline)
                    })?;
                    b.mark_dirty();
                    conn.clear_local();
                    Ok(version_response(active))
                }
                None => Err(hub_full_train_error()),
            }
        }
        // parse_op guarantees at least one field; keep the refusal typed
        (None, None) => Err(anyhow!(
            "migrate_in requires 'lane_id' and/or 'checkpoint'"
        )),
    }
}

/// Hub-less f64 streaming fallback: same arithmetic (and therefore the
/// same bits) as a hub lane, on connection-local slot planes.
fn stream_local(model: &Model, input: &[f64], local: &mut LocalStream) -> Vec<f64> {
    let n = model.esn.n();
    let mut outs = Vec::with_capacity(input.len());
    let mut feat = vec![0.0; n];
    for &u in input {
        model.esn.step(&mut local.s_re, &mut local.s_im, &[u]);
        model.esn.write_features(&local.s_re, &local.s_im, &mut feat);
        // bias-first ascending-feature: the shared accumulation contract
        outs.push(model.readout.apply_row(&feat, 0));
    }
    outs
}

fn parse_vec(req: &Json, field: &str) -> Result<Vec<f64>> {
    req.get(field)
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("missing '{field}' array"))?
        .iter()
        .map(|v| v.as_f64().ok_or_else(|| anyhow!("non-numeric {field}")))
        .collect()
}

fn parse_input(req: &Json) -> Result<Vec<f64>> {
    parse_vec(req, "input")
}

/// The transient error codes [`Client::with_retry`] retries. Everything
/// else in the [`ERROR_CODES`] table is DETERMINISTIC — retrying a
/// `restore_mismatch` or `commit_singular` can only fail identically,
/// so those surface immediately. `moved` is transient BY DEFINITION:
/// [`Client::request`] normally follows it transparently, so a `moved`
/// that reaches the retry layer means ownership was mid-transition
/// (ring rebuild racing the request) — exactly the case a backoff
/// retry resolves. Pinned to the table by a unit test.
pub const RETRYABLE_CODES: &[&str] = &["unavailable", "overloaded", "moved"];

/// Is this error-code slug in the transient, retry-worthy set?
pub fn is_retryable_code(code: &str) -> bool {
    RETRYABLE_CODES.contains(&code)
}

/// Most `moved` hops [`Client::request`] follows before giving up with
/// the typed `redirect_loop` error. A healthy cluster resolves in ONE
/// hop (every node knows the full ring); a few more tolerate a ring
/// transition racing the request. Anything deeper is a configuration
/// cycle (nodes disagreeing about ownership forever), which must
/// surface as an error, never as an infinite client loop.
const MAX_REDIRECT_HOPS: usize = 4;

/// Minimal client for the examples/tests.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    /// The configured IO timeout, remembered so redirect-follow
    /// reconnects keep the caller's deadline bounds.
    io_timeout: Option<Duration>,
    /// Binary-frame mode (after a successful [`Self::upgrade_binary`]).
    /// Requests and replies carry raw LE float bits instead of JSON
    /// text; the decoded `Json` is structurally identical either way.
    binary: bool,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Self> {
        let stream = TcpStream::connect(addr)?;
        Ok(Self {
            reader: BufReader::new(stream.try_clone()?),
            writer: stream,
            io_timeout: None,
            binary: false,
        })
    }

    /// [`Self::connect`] with a bound on the connection attempt itself —
    /// the gossip prober and failover-aware tooling use this so a
    /// black-holed peer costs a timeout, never a hang.
    pub fn connect_timeout(addr: &str, timeout: Duration) -> Result<Self> {
        use std::net::ToSocketAddrs;
        let sa = addr
            .to_socket_addrs()?
            .next()
            .ok_or_else(|| anyhow!("unresolvable address {addr:?}"))?;
        let stream = TcpStream::connect_timeout(&sa, timeout)?;
        Ok(Self {
            reader: BufReader::new(stream.try_clone()?),
            writer: stream,
            io_timeout: None,
            binary: false,
        })
    }

    /// Negotiate the binary frame protocol on this connection: send the
    /// magic+version hello, require the server's ack. Must be the FIRST
    /// bytes on the wire (the server sniffs them against the magic), so
    /// call it straight after connecting, before any request. After the
    /// upgrade every [`Self::request`]/[`Self::send`]/[`Self::recv`]
    /// moves raw little-endian float bits — no float formatting on
    /// either side — and redirect follows re-negotiate automatically.
    pub fn upgrade_binary(&mut self) -> Result<()> {
        self.writer.write_all(&binframe::client_hello())?;
        let mut ack = [0u8; binframe::HELLO_LEN];
        self.reader.read_exact(&mut ack)?;
        anyhow::ensure!(
            ack == binframe::server_hello(),
            "server refused the binary upgrade (not a binary-capable \
             endpoint?)"
        );
        self.binary = true;
        Ok(())
    }

    /// Is this connection in binary-frame mode?
    pub fn is_binary(&self) -> bool {
        self.binary
    }

    /// Bound every read AND write on this connection (`None` = block
    /// forever). Deadline-bounded reads are what turn a hung server
    /// into a visible error instead of a stuck client — the chaos suite
    /// drives all its assertions through timed clients.
    pub fn set_io_timeout(&mut self, timeout: Option<Duration>) -> Result<()> {
        self.writer.set_write_timeout(timeout)?;
        self.reader.get_ref().set_read_timeout(timeout)?;
        self.io_timeout = timeout;
        Ok(())
    }

    /// One request → one response — transparently following cluster
    /// redirects: a `moved {addr}` reply reconnects this client to the
    /// named owner (keeping the IO timeout) and resends the SAME
    /// request, up to [`MAX_REDIRECT_HOPS`] hops; past that the typed
    /// `redirect_loop` error surfaces instead of looping forever. After
    /// a successful follow the client STAYS on the owning node, so a
    /// session's later requests pay zero extra hops. Pipelined callers
    /// using raw [`Self::send`]/[`Self::recv`] see `moved` verbatim and
    /// handle placement themselves.
    pub fn request(&mut self, req: &Json) -> Result<Json> {
        self.send(req)?;
        let mut resp = self.recv()?;
        let mut hops = 0usize;
        while resp.get("code").and_then(Json::as_str) == Some("moved") {
            let Some(addr) = resp
                .get("addr")
                .and_then(Json::as_str)
                .map(str::to_string)
            else {
                break; // moved without an address: nothing to follow
            };
            hops += 1;
            if hops > MAX_REDIRECT_HOPS {
                return Err(coded(
                    "redirect_loop",
                    format!(
                        "redirect loop: {hops} moved hops without reaching \
                         an owner (last claimed owner: {addr})"
                    ),
                ));
            }
            let mut next = Client::connect(&addr)?;
            next.set_io_timeout(self.io_timeout)?;
            if self.binary {
                // the session keeps its codec across redirects
                next.upgrade_binary()?;
            }
            *self = next;
            self.send(req)?;
            resp = self.recv()?;
        }
        Ok(resp)
    }

    /// Write one request line (or frame, in binary mode) without waiting
    /// for the reply — pair with [`Self::recv`] to pipeline requests
    /// across many connections (the event-loop benches fan out this
    /// way).
    pub fn send(&mut self, req: &Json) -> Result<()> {
        if self.binary {
            self.writer.write_all(&binframe::encode_request(req))?;
            return Ok(());
        }
        self.writer
            .write_all(req.to_string_compact().as_bytes())?;
        self.writer.write_all(b"\n")?;
        Ok(())
    }

    /// Write raw bytes with no framing — the fault-injection hook for
    /// deliberately torn frames (tests only take this path).
    pub(crate) fn send_raw(&mut self, bytes: &[u8]) -> Result<()> {
        self.writer.write_all(bytes)?;
        self.writer.flush()?;
        Ok(())
    }

    /// Read one reply line (or frame, in binary mode) — FIFO with the
    /// requests sent on this connection.
    pub fn recv(&mut self) -> Result<Json> {
        if self.binary {
            return match binframe::read_frame(&mut self.reader)? {
                binframe::ReadFrame::Frame(body) => {
                    binframe::decode_response(&body)
                }
                binframe::ReadFrame::Eof => {
                    Err(anyhow!("connection closed mid-reply"))
                }
                binframe::ReadFrame::TornEof | binframe::ReadFrame::Oversized => {
                    Err(anyhow!("malformed reply frame from server"))
                }
            };
        }
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        parse(line.trim())
    }

    fn io_op(&mut self, op: &str, input: &[f64]) -> Result<Vec<f64>> {
        let req = Json::obj(vec![
            ("op", Json::Str(op.into())),
            (
                "input",
                Json::Arr(input.iter().map(|&x| Json::Num(x)).collect()),
            ),
        ]);
        let resp = self.request(&req)?;
        anyhow::ensure!(
            resp.get("ok").map(|j| *j == Json::Bool(true)).unwrap_or(false),
            "server error: {resp:?}"
        );
        resp.get("output")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("missing output"))?
            .iter()
            .map(|v| v.as_f64().ok_or_else(|| anyhow!("bad output")))
            .collect()
    }

    pub fn predict(&mut self, input: &[f64]) -> Result<Vec<f64>> {
        self.io_op("predict", input)
    }

    /// Stateful streaming step(s) on this connection's lane.
    pub fn stream(&mut self, input: &[f64]) -> Result<Vec<f64>> {
        self.io_op("stream", input)
    }

    /// Online training step(s): advance this connection's state over
    /// `input` and accumulate `(features, target)` rows server-side.
    /// Returns the lane's total accumulated row count.
    pub fn train(&mut self, input: &[f64], target: &[f64]) -> Result<u64> {
        let req = Json::obj(vec![
            ("op", Json::Str("train".into())),
            (
                "input",
                Json::Arr(input.iter().map(|&x| Json::Num(x)).collect()),
            ),
            (
                "target",
                Json::Arr(target.iter().map(|&x| Json::Num(x)).collect()),
            ),
        ]);
        let resp = self.request(&req)?;
        anyhow::ensure!(
            resp.get("ok").map(|j| *j == Json::Bool(true)).unwrap_or(false),
            "server error: {resp:?}"
        );
        resp.get("rows")
            .and_then(Json::as_f64)
            .map(|r| r as u64)
            .ok_or_else(|| anyhow!("missing rows"))
    }

    /// Solve the accumulated ridge system and hot-swap this connection's
    /// readout; subsequent [`Self::stream`] calls use it. Returns the
    /// newly retained readout's version id (monotonic per lane).
    pub fn commit(&mut self, alpha: f64) -> Result<u64> {
        let req = Json::obj(vec![
            ("op", Json::Str("commit".into())),
            ("alpha", Json::Num(alpha)),
        ]);
        self.version_op(&req)
    }

    /// Atomically reinstall a retained committed-readout version (0 =
    /// base model readout) without dropping accumulated training rows.
    /// Returns the now-active version id.
    pub fn rollback(&mut self, version: u64) -> Result<u64> {
        let req = Json::obj(vec![
            ("op", Json::Str("rollback".into())),
            ("version", Json::Num(version as f64)),
        ]);
        self.version_op(&req)
    }

    /// Snapshot this connection's full lane value (state + trainer +
    /// committed-readout version ring) as the wire checkpoint object —
    /// feed it back through [`Self::restore`] (on this connection, a
    /// reconnect, or a different server over the same model) to continue
    /// bit-identically.
    pub fn checkpoint(&mut self) -> Result<Json> {
        let req = Json::obj(vec![("op", Json::Str("checkpoint".into()))]);
        let resp = self.request(&req)?;
        anyhow::ensure!(
            resp.get("ok").map(|j| *j == Json::Bool(true)).unwrap_or(false),
            "server error: {resp:?}"
        );
        resp.get("checkpoint")
            .cloned()
            .ok_or_else(|| anyhow!("missing checkpoint"))
    }

    /// Install a checkpoint object (from [`Self::checkpoint`]) onto this
    /// connection's lane, bit-exactly. Returns the restored active
    /// version id.
    pub fn restore(&mut self, checkpoint: &Json) -> Result<u64> {
        let req = Json::obj(vec![
            ("op", Json::Str("restore".into())),
            ("checkpoint", checkpoint.clone()),
        ]);
        self.version_op(&req)
    }

    /// Ask the server to migrate this connection's live lane to another
    /// shard (`None` = the server picks the coldest), mid-stream and
    /// bit-invisibly. Returns the new home shard index.
    pub fn migrate(&mut self, shard: Option<usize>) -> Result<u64> {
        let mut fields = vec![("op", Json::Str("migrate".into()))];
        if let Some(s) = shard {
            fields.push(("shard", Json::Num(s as f64)));
        }
        let resp = self.request(&Json::obj(fields))?;
        anyhow::ensure!(
            resp.get("ok").map(|j| *j == Json::Bool(true)).unwrap_or(false),
            "server error: {resp:?}"
        );
        resp.get("shard")
            .and_then(Json::as_f64)
            .map(|s| s as u64)
            .ok_or_else(|| anyhow!("missing shard"))
    }

    /// Install a checkpoint object on this connection's lane of ANOTHER
    /// server over the same model — the receiving half of cross-server
    /// migration. Returns the restored active version id.
    pub fn migrate_in(&mut self, checkpoint: &Json) -> Result<u64> {
        let req = Json::obj(vec![
            ("op", Json::Str("migrate_in".into())),
            ("checkpoint", checkpoint.clone()),
        ]);
        self.version_op(&req)
    }

    /// Adopt a standby-pushed (parked) lane by its primary-side lane id
    /// — the promotion op after a primary failure. Returns the adopted
    /// lane's active version id.
    pub fn adopt(&mut self, lane_id: u64) -> Result<u64> {
        let req = Json::obj(vec![
            ("op", Json::Str("migrate_in".into())),
            ("lane_id", Json::Num(lane_id as f64)),
        ]);
        self.version_op(&req)
    }

    /// Mint (or idempotently re-reference) a per-tenant reservoir from
    /// a deterministic recipe. `spectral_radius`/`lambda_prior` default
    /// server-side (0.9, `"uniform"`). Returns the model id — stable
    /// across servers and restarts (it is a pure function of the
    /// recipe), so a client can reconnect anywhere and name the same
    /// model.
    pub fn create_model(
        &mut self,
        seed: u64,
        n: usize,
        spectral_radius: Option<f64>,
        lambda_prior: Option<&str>,
    ) -> Result<u64> {
        let mut fields = vec![
            ("op", Json::Str("create_model".into())),
            ("seed", Json::Num(seed as f64)),
            ("n", Json::Num(n as f64)),
        ];
        if let Some(sr) = spectral_radius {
            fields.push(("spectral_radius", Json::Num(sr)));
        }
        if let Some(p) = lambda_prior {
            fields.push(("lambda_prior", Json::Str(p.into())));
        }
        let resp = self.request(&Json::obj(fields))?;
        anyhow::ensure!(
            resp.get("ok").map(|j| *j == Json::Bool(true)).unwrap_or(false),
            "server error: {resp:?}"
        );
        resp.get("model")
            .and_then(Json::as_f64)
            .map(|m| m as u64)
            .ok_or_else(|| anyhow!("missing model"))
    }

    /// Evict a tenant model from the server's registry.
    pub fn delete_model(&mut self, model: u64) -> Result<()> {
        let req = Json::obj(vec![
            ("op", Json::Str("delete_model".into())),
            ("model", Json::Num(model as f64)),
        ]);
        let resp = self.request(&req)?;
        anyhow::ensure!(
            resp.get("ok").map(|j| *j == Json::Bool(true)).unwrap_or(false),
            "server error: {resp:?}"
        );
        Ok(())
    }

    /// Ask the server to drain gracefully: stop accepting, finish
    /// in-flight work, flush, spill live lanes (if configured), exit.
    pub fn shutdown_drain(&mut self) -> Result<()> {
        let req = Json::obj(vec![("op", Json::Str("shutdown_drain".into()))]);
        let resp = self.request(&req)?;
        anyhow::ensure!(
            resp.get("ok").map(|j| *j == Json::Bool(true)).unwrap_or(false),
            "server error: {resp:?}"
        );
        Ok(())
    }

    /// [`Self::request`] with bounded retries and decorrelated-jitter
    /// backoff on the TRANSIENT error codes only ([`RETRYABLE_CODES`]):
    /// an `overloaded` shed or an `unavailable` blip is retried up to
    /// `attempts` times; every deterministic refusal (`restore_mismatch`,
    /// `commit_singular`, …) and every success returns immediately. IO
    /// errors propagate — a dead socket can't be retried in place.
    pub fn with_retry(&mut self, req: &Json, attempts: usize) -> Result<Json> {
        const BASE_MS: f64 = 5.0;
        const CAP_MS: f64 = 500.0;
        // deterministic per-client jitter stream (no global RNG): seed
        // from the client's address, which is stable for its lifetime
        let mut rng =
            crate::rng::Pcg64::new(0x7769_7265_5f72_6574, self as *const Self as u64);
        let mut prev_ms = BASE_MS;
        let attempts = attempts.max(1);
        for attempt in 1..=attempts {
            let resp = self.request(req)?;
            let ok = resp
                .get("ok")
                .map(|j| *j == Json::Bool(true))
                .unwrap_or(false);
            let retryable = !ok
                && resp
                    .get("code")
                    .and_then(Json::as_str)
                    .map(is_retryable_code)
                    .unwrap_or(false);
            if ok || !retryable || attempt == attempts {
                return Ok(resp);
            }
            // decorrelated jitter: sleep ~U[base, 3·prev], capped
            let span = (prev_ms * 3.0 - BASE_MS).max(0.0);
            let ms = (BASE_MS + rng.next_f64() * span).min(CAP_MS);
            prev_ms = ms;
            std::thread::sleep(Duration::from_micros((ms * 1000.0) as u64));
        }
        unreachable!("the final attempt returns above")
    }

    /// Shared request → `{"ok": true, "version": v}` decode.
    fn version_op(&mut self, req: &Json) -> Result<u64> {
        let resp = self.request(req)?;
        anyhow::ensure!(
            resp.get("ok").map(|j| *j == Json::Bool(true)).unwrap_or(false),
            "server error: {resp:?}"
        );
        resp.get("version")
            .and_then(Json::as_f64)
            .map(|v| v as u64)
            .ok_or_else(|| anyhow!("missing version"))
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::{make_model, make_model_d2, make_model_f32};
    use super::*;

    use crate::tasks::mso::MsoTask;

    /// Bind port 0, spawn the server, hand back the discovered address —
    /// race-free (the listener is bound before the thread starts) and
    /// safe under parallel test runs (no hard-coded ports).
    fn spawn_server(
        model: Arc<Model>,
        max_conns: usize,
        shards: Option<usize>,
        threaded: bool,
    ) -> (String, std::thread::JoinHandle<()>) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let handle = std::thread::spawn(move || {
            serve_on(listener, model, Some(max_conns), 0, shards, threaded).unwrap();
        });
        (addr, handle)
    }

    #[test]
    fn predict_and_stream_agree() {
        let model = make_model();
        let task = MsoTask::new(1);
        let input = &task.input[..50];
        let batch = model.predict(input);
        // streaming path (local fallback arithmetic)
        let mut local = LocalStream {
            s_re: vec![0.0; model.esn.spec.slots()],
            s_im: vec![0.0; model.esn.spec.slots()],
        };
        let line_out = stream_local(&model, input, &mut local);
        for (a, b) in batch.iter().zip(&line_out) {
            assert!((a - b).abs() < 1e-10);
        }
    }

    #[test]
    fn fallback_connection_keys_cannot_alias_ipv4_keys() {
        // low IPv4 addresses key to small integers …
        let low = ip_key(&"0.0.0.7".parse().unwrap());
        assert_eq!(low, 7);
        // … so the unreadable-peer fallback must live in a disjoint
        // range: tagged, and above every possible IPv4 key
        for served in [0usize, 7, 1_000_000] {
            let k = fallback_key(served);
            assert_ne!(k & FALLBACK_KEY_TAG, 0);
            assert!(
                k > u32::MAX as u64,
                "fallback key {k} collides with the IPv4 key space"
            );
        }
        assert_ne!(fallback_key(7), low);
    }

    #[test]
    fn end_to_end_over_tcp() {
        let model = Arc::new(make_model());
        let (addr, handle) = spawn_server(Arc::clone(&model), 1, None, false);
        let mut client = Client::connect(&addr).unwrap();
        let task = MsoTask::new(1);
        let out = client.predict(&task.input[..40]).unwrap();
        assert_eq!(out.len(), 40);
        let direct = model.predict(&task.input[..40]);
        for (a, b) in out.iter().zip(&direct) {
            assert!((a - b).abs() < 1e-9);
        }
        // info op
        let resp = client
            .request(&Json::obj(vec![("op", Json::Str("info".into()))]))
            .unwrap();
        assert_eq!(resp.get("n").unwrap().as_usize(), Some(30));
        drop(client);
        handle.join().unwrap();
    }

    #[test]
    fn explicit_two_shard_server_over_tcp_is_invisible() {
        // shards must be unobservable on the wire: an explicitly 2-shard
        // server answers bit-identically to Model::predict, and `info`
        // reports the shard topology
        let model = Arc::new(make_model());
        let (addr, handle) = spawn_server(Arc::clone(&model), 2, Some(2), false);
        let task = MsoTask::new(2);
        // both connections come from the same peer IP, so they (and any
        // reconnect) must hash to the same home shard — shard placement
        // is stable across reconnects
        let mut c1 = Client::connect(&addr).unwrap();
        let mut c2 = Client::connect(&addr).unwrap();
        let shard_of = |c: &mut Client| {
            c.request(&Json::obj(vec![("op", Json::Str("info".into()))]))
                .unwrap()
                .get("shard")
                .and_then(Json::as_f64)
                .unwrap()
        };
        assert_eq!(
            shard_of(&mut c1),
            shard_of(&mut c2),
            "same peer IP must keep its home shard across connections"
        );
        for i in 0..3 {
            let input = &task.input[i * 8..i * 8 + 25];
            for c in [&mut c1, &mut c2] {
                let got = c.predict(input).unwrap();
                let want = model.predict(input);
                assert_eq!(got.len(), want.len());
                for (a, b) in got.iter().zip(&want) {
                    assert!((a - b).abs() == 0.0, "{a} vs {b}");
                }
            }
        }
        let resp = c1
            .request(&Json::obj(vec![("op", Json::Str("info".into()))]))
            .unwrap();
        assert_eq!(resp.get("shards").and_then(Json::as_f64), Some(2.0));
        let shard = resp.get("shard").and_then(Json::as_f64).unwrap();
        assert!(shard == 0.0 || shard == 1.0);
        assert_eq!(
            resp.get("shard_queue_depth").and_then(Json::as_arr).unwrap().len(),
            2
        );
        assert_eq!(
            resp.get("shard_sweeps").and_then(Json::as_arr).unwrap().len(),
            2
        );
        drop(c1);
        drop(c2);
        handle.join().unwrap();
    }

    #[test]
    fn info_reports_precision_and_sweeper_metrics() {
        let model = Arc::new(make_model_f32());
        let (addr, handle) = spawn_server(Arc::clone(&model), 1, None, false);
        let mut client = Client::connect(&addr).unwrap();
        let task = MsoTask::new(1);
        // drive at least one sweep through the front
        let out = client.predict(&task.input[..20]).unwrap();
        assert_eq!(out.len(), 20);
        let resp = client
            .request(&Json::obj(vec![("op", Json::Str("info".into()))]))
            .unwrap();
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(
            resp.get("precision").and_then(Json::as_str),
            Some("f32")
        );
        // aggregate sweeps count every shard's rounds; the predict above
        // ran on one of them
        assert!(resp.get("sweeps").and_then(Json::as_f64).unwrap() >= 1.0);
        assert!(resp.get("queue_depth").and_then(Json::as_f64).is_some());
        // default serve_on() shards one sweeper per available core
        let shards = resp.get("shards").and_then(Json::as_f64).unwrap();
        assert!(shards >= 1.0);
        assert_eq!(
            resp.get("shard_sweeps").and_then(Json::as_arr).unwrap().len(),
            shards as usize
        );
        // zero hold-off here; the window is opt-in via serve_with_holdoff
        assert_eq!(
            resp.get("holdoff_us").and_then(Json::as_f64),
            Some(0.0)
        );
        drop(client);
        handle.join().unwrap();
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn event_loop_reaps_parked_connections_after_idle_timeout() {
        // a connection that goes silent past --idle-timeout-s is closed
        // by the timer wheel; an active round-trip first proves the
        // timeout only bites SILENT connections
        use std::time::{Duration, Instant};
        let model = Arc::new(make_model());
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server_model = Arc::clone(&model);
        let handle = std::thread::spawn(move || {
            serve_on_opts(
                listener,
                server_model,
                Some(1),
                ServeOpts {
                    shards: Some(1),
                    idle_timeout: Some(Duration::from_millis(300)),
                    ..Default::default()
                },
            )
            .unwrap();
        });
        let mut c = Client::connect(&addr).unwrap();
        let task = MsoTask::new(1);
        // activity works and resets the idle clock
        let out = c.predict(&task.input[..10]).unwrap();
        assert_eq!(out.len(), 10);
        // park silently; the wheel must reap us and (max_conns = 1) the
        // server must exit — observed as EOF on the next read
        let t0 = Instant::now();
        let r = c.recv();
        let waited = t0.elapsed();
        assert!(
            r.is_err(),
            "expected the server to close the parked connection, got {r:?}"
        );
        assert!(
            waited >= Duration::from_millis(150),
            "reaped suspiciously fast ({waited:?}) — before the timeout"
        );
        handle.join().unwrap();
    }

    #[test]
    fn event_loop_matches_threaded_bitwise_at_both_precisions() {
        // the tentpole contract: the epoll transport must be invisible —
        // mixed predict/stream traffic answers bit-for-bit what the
        // thread-per-connection transport answers, at f64 and f32
        for make in [make_model as fn() -> Model, make_model_f32] {
            let model = Arc::new(make());
            let task = MsoTask::new(2);
            let mut per_transport: Vec<Vec<Vec<f64>>> = Vec::new();
            for threaded in [false, true] {
                let (addr, handle) =
                    spawn_server(Arc::clone(&model), 1, Some(2), threaded);
                let mut client = Client::connect(&addr).unwrap();
                let mut outs = Vec::new();
                for i in 0..3 {
                    let input = &task.input[i * 11..i * 11 + 30 + i];
                    outs.push(client.predict(input).unwrap());
                }
                let stream_in = &task.input[..40];
                let mut streamed = client.stream(&stream_in[..17]).unwrap();
                streamed.extend(client.stream(&stream_in[17..]).unwrap());
                outs.push(streamed);
                drop(client);
                handle.join().unwrap();
                per_transport.push(outs);
            }
            let (ev, th) = (&per_transport[0], &per_transport[1]);
            assert_eq!(ev.len(), th.len());
            for (a_vec, b_vec) in ev.iter().zip(th) {
                assert_eq!(a_vec.len(), b_vec.len());
                for (a, b) in a_vec.iter().zip(b_vec) {
                    assert!(
                        (a - b).abs() == 0.0,
                        "event loop diverged from threaded path: {a} vs {b}"
                    );
                }
            }
        }
    }

    #[test]
    fn train_commit_stream_hot_swaps_on_both_transports() {
        // the acceptance contract: a wire-driven train→commit→stream
        // must change predictions EXACTLY as a locally fitted readout
        // would — on the event loop and the threaded twin alike
        use crate::linalg::Mat;
        use crate::readout::GramAcc;
        let model = Arc::new(make_model());
        let task = MsoTask::new(1);
        let train_in = &task.input[..150];
        let target: Vec<f64> =
            train_in.iter().map(|x| 0.5 - 2.0 * x).collect();
        let stream_in = &task.input[150..190];
        let alpha = 1e-8;

        // local reference: same trajectory (hub lanes are bit-identical
        // to the sequential QBasisEsn), same accumulator, same solve
        let u = Mat::from_rows(train_in.len(), 1, train_in);
        let x = model.qesn.run(&u);
        let y = Mat::from_rows(target.len(), 1, &target);
        let mut acc = GramAcc::<f64>::new(model.esn.n(), 1);
        acc.push_rows(&x, &y);
        let want_ro = acc.solve_scaled(alpha, 1.0).unwrap();
        let all: Vec<f64> =
            train_in.iter().chain(stream_in).copied().collect();
        let u_all = Mat::from_rows(all.len(), 1, &all);
        let x_all = model.qesn.run(&u_all);
        let want: Vec<f64> = (150..190)
            .map(|t| want_ro.apply_row(x_all.row(t), 0))
            .collect();
        let model_y: Vec<f64> = {
            let y_all = model.qesn.run_readout(&u_all, &model.readout);
            (150..190).map(|t| y_all[(t, 0)]).collect()
        };

        for threaded in [false, true] {
            let (addr, handle) =
                spawn_server(Arc::clone(&model), 1, Some(2), threaded);
            let mut c = Client::connect(&addr).unwrap();
            // split the training stream: accumulation must be
            // chunking-invariant over the wire too
            assert_eq!(c.train(&train_in[..70], &target[..70]).unwrap(), 70);
            assert_eq!(c.train(&train_in[70..], &target[70..]).unwrap(), 150);
            c.commit(alpha).unwrap();
            let got = c.stream(stream_in).unwrap();
            assert_eq!(got.len(), want.len());
            for (t, (a, b)) in got.iter().zip(&want).enumerate() {
                assert!(
                    (a - b).abs() == 0.0,
                    "threaded={threaded} t={t}: hot-swapped stream \
                     diverged from the local fit: {a} vs {b}"
                );
            }
            // and the swap is observable vs the model readout
            assert!(
                got.iter().zip(&model_y).any(|(a, b)| a != b),
                "threaded={threaded}: committed readout unobservable"
            );
            drop(c);
            handle.join().unwrap();
        }
    }

    #[test]
    fn commit_without_training_is_a_clean_error_on_both_transports() {
        let model = Arc::new(make_model());
        let task = MsoTask::new(1);
        for threaded in [false, true] {
            let (addr, handle) =
                spawn_server(Arc::clone(&model), 1, Some(1), threaded);
            let mut c = Client::connect(&addr).unwrap();
            let resp = c
                .request(&Json::obj(vec![("op", Json::Str("commit".into()))]))
                .unwrap();
            assert_eq!(
                resp.get("ok"),
                Some(&Json::Bool(false)),
                "threaded={threaded}: premature commit must refuse"
            );
            // the connection survives and serves on
            let out = c.predict(&task.input[..15]).unwrap();
            assert_eq!(out.len(), 15);
            // mismatched train lengths are rejected at parse, cleanly
            let resp = c
                .request(&Json::obj(vec![
                    ("op", Json::Str("train".into())),
                    ("input", Json::Arr(vec![Json::Num(0.1), Json::Num(0.2)])),
                    ("target", Json::Arr(vec![Json::Num(0.3)])),
                ]))
                .unwrap();
            assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
            drop(c);
            handle.join().unwrap();
        }
    }

    #[test]
    fn multi_output_model_serves_all_columns_and_rejects_stream() {
        // wire end-to-end of the D_out fix: a 2-output model's predict
        // returns T×2 values (step-major), and a stream op is refused
        // with an error response instead of panicking the sweeper
        let model = Arc::new(make_model_d2());
        let (addr, handle) = spawn_server(Arc::clone(&model), 1, Some(1), false);
        let mut client = Client::connect(&addr).unwrap();
        let task = MsoTask::new(1);
        let input = &task.input[..25];
        let got = client.predict(input).unwrap();
        assert_eq!(got.len(), input.len() * 2, "truncated multi-output reply");
        let want = model.predict(input);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() == 0.0, "{a} vs {b}");
        }
        // stream on a D_out=2 model: clean error, connection stays alive
        let resp = client
            .request(&Json::obj(vec![
                ("op", Json::Str("stream".into())),
                ("input", Json::Arr(vec![Json::Num(0.1)])),
            ]))
            .unwrap();
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
        let again = client.predict(input).unwrap();
        assert_eq!(again, got);
        drop(client);
        handle.join().unwrap();
    }

    /// Bind a connection to a tenant model via a model-bearing ping.
    fn bind_model(c: &mut Client, model: u64) -> Json {
        c.request(&Json::obj(vec![
            ("op", Json::Str("ping".into())),
            ("model", Json::Num(model as f64)),
        ]))
        .unwrap()
    }

    #[test]
    fn minted_tenants_serve_bitwise_and_refuse_typed_on_both_transports() {
        // the PR-9 acceptance contract, end to end on the wire: two
        // tenants minted over `create_model` serve bit-identically to
        // models minted locally from the same recipes, interleaved with
        // each other AND base traffic through ONE sweeper — while every
        // registry misuse answers a typed error, never a wrong model
        use crate::linalg::Mat;
        use crate::readout::GramAcc;
        use super::super::registry::mint_model;

        let base = Arc::new(make_model());
        let task = MsoTask::new(1);
        let train_in = &task.input[..120];
        let target: Vec<f64> =
            train_in.iter().map(|x| 0.3 + 1.5 * x).collect();
        let stream_in = &task.input[120..160];
        let alpha = 1e-8;

        let ra = ModelRecipe::new(101, 48, 0.85, "uniform").unwrap();
        let rb = ModelRecipe::new(202, 48, 0.85, "ring").unwrap();

        // local twin of tenant A, minted from the recipe alone — the
        // determinism failover leans on: same recipe, same planes, on
        // any node, with no model transfer
        let twin = mint_model(&ra, base.esn.d_in, base.precision);
        let u = Mat::from_rows(train_in.len(), 1, train_in);
        let x = twin.qesn.run(&u);
        let y = Mat::from_rows(target.len(), 1, &target);
        let mut acc = GramAcc::<f64>::new(twin.esn.n(), 1);
        acc.push_rows(&x, &y);
        let want_ro = acc.solve_scaled(alpha, 1.0).unwrap();
        let all: Vec<f64> =
            train_in.iter().chain(stream_in).copied().collect();
        let u_all = Mat::from_rows(all.len(), 1, &all);
        let x_all = twin.qesn.run(&u_all);
        let want: Vec<f64> = (120..160)
            .map(|t| want_ro.apply_row(x_all.row(t), 0))
            .collect();

        for threaded in [false, true] {
            let (addr, handle) =
                spawn_server(Arc::clone(&base), 8, Some(1), threaded);
            let mut admin = Client::connect(&addr).unwrap();
            let a = admin.create_model(101, 48, Some(0.85), None).unwrap();
            let b = admin
                .create_model(202, 48, Some(0.85), Some("ring"))
                .unwrap();
            assert_eq!(a, ra.id(), "wire id must equal the recipe id");
            assert_eq!(b, rb.id());
            assert_ne!(a, b);
            // idempotent re-create: same id, nothing minted
            let resp = admin
                .request(&Json::obj(vec![
                    ("op", Json::Str("create_model".into())),
                    ("seed", Json::Num(101.0)),
                    ("n", Json::Num(48.0)),
                    ("spectral_radius", Json::Num(0.85)),
                ]))
                .unwrap();
            assert_eq!(resp.get("created"), Some(&Json::Bool(false)));
            assert_eq!(resp.get("model").and_then(Json::as_f64), Some(a as f64));

            // three live connections: tenant A, tenant B, base
            let mut ca = Client::connect(&addr).unwrap();
            let mut cb = Client::connect(&addr).unwrap();
            let mut cbase = Client::connect(&addr).unwrap();
            let bound = bind_model(&mut ca, a);
            assert_eq!(bound.get("ok"), Some(&Json::Bool(true)));
            assert_eq!(bind_model(&mut cb, b).get("ok"), Some(&Json::Bool(true)));

            // untrained tenant readout is all-zero by construction
            let zb = cb.stream(&task.input[..10]).unwrap();
            assert!(
                zb.iter().all(|&v| v == 0.0),
                "threaded={threaded}: untrained tenant must answer zeros"
            );
            // stateless tenant predict routes through the pooled engines
            let zp = ca.predict(&task.input[..12]).unwrap();
            assert_eq!(zp.len(), 12);
            assert!(zp.iter().all(|&v| v == 0.0));

            // A trains → commits → streams, interleaved with base
            // predicts and B streams through the same mixed sweep
            assert_eq!(ca.train(&train_in[..50], &target[..50]).unwrap(), 50);
            let base_out = cbase.predict(&task.input[..30]).unwrap();
            assert_eq!(
                base_out,
                base.predict(&task.input[..30]),
                "threaded={threaded}: base traffic lost bit-identity"
            );
            assert_eq!(ca.train(&train_in[50..], &target[50..]).unwrap(), 120);
            let _ = cb.stream(&task.input[10..20]).unwrap();
            ca.commit(alpha).unwrap();
            let got = ca.stream(stream_in).unwrap();
            assert_eq!(got.len(), want.len());
            for (t, (g, w)) in got.iter().zip(&want).enumerate() {
                assert!(
                    (g - w).abs() == 0.0,
                    "threaded={threaded} t={t}: tenant diverged from its \
                     minted twin: {g} vs {w}"
                );
            }

            // per-model accounting on `info`
            let info = ca
                .request(&Json::obj(vec![("op", Json::Str("info".into()))]))
                .unwrap();
            assert_eq!(info.get("models").and_then(Json::as_f64), Some(2.0));
            assert_eq!(info.get("model").and_then(Json::as_f64), Some(a as f64));
            let lanes = info.get("model_lanes").unwrap();
            assert!(
                lanes.get(&a.to_string()).and_then(Json::as_f64).unwrap_or(0.0)
                    >= 1.0,
                "threaded={threaded}: tenant A's lane missing from \
                 model_lanes: {lanes:?}"
            );

            // typed refusals — unknown id …
            let mut cx = Client::connect(&addr).unwrap();
            let resp = bind_model(&mut cx, 424_242);
            assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
            assert_eq!(
                resp.get("code").and_then(Json::as_str),
                Some("unknown_model")
            );
            // … cross-model conflict on a bound connection …
            let resp = bind_model(&mut ca, b);
            assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
            // … and binding after streaming state exists
            let _ = cx.stream(&task.input[..5]).unwrap();
            let resp = bind_model(&mut cx, a);
            assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));

            // delete: B's bound lane keeps serving off its cached planes;
            // NEW references to the id refuse typed
            admin.delete_model(b).unwrap();
            let still = cb.stream(&task.input[20..25]).unwrap();
            assert_eq!(still.len(), 5, "bound lane must survive delete");
            let mut cy = Client::connect(&addr).unwrap();
            let resp = bind_model(&mut cy, b);
            assert_eq!(
                resp.get("code").and_then(Json::as_str),
                Some("unknown_model")
            );
            let resp = admin
                .request(&Json::obj(vec![
                    ("op", Json::Str("delete_model".into())),
                    ("model", Json::Num(b as f64)),
                ]))
                .unwrap();
            assert_eq!(
                resp.get("code").and_then(Json::as_str),
                Some("unknown_model"),
                "double delete must answer the typed code"
            );

            drop(admin);
            drop(ca);
            drop(cb);
            drop(cbase);
            drop(cx);
            drop(cy);
            handle.join().unwrap();
        }
    }

    #[test]
    fn snapshot_json_codec_round_trips_bit_exactly() {
        // the checkpoint wire codec must lose NOTHING: every f64 —
        // including values whose decimal forms are awkward — survives
        // encode → compact string → parse → decode with identical bits
        let snap = LaneSnapshot {
            n: 3,
            precision: Precision::F64,
            state: vec![0.1, -1e-17, f64::MIN_POSITIVE, -0.0, 3.0],
            trainer: Some(GramAccRaw {
                f: 2,
                d: 1,
                g: vec![0.1 + 0.2, -2.5e-123, 1.0, 4.0],
                b: vec![1e300, -7.0],
                col_sums: vec![std::f64::consts::E, -0.0],
                y_sums: vec![std::f64::consts::PI],
                rows: 12_345_678_901_234,
                carry: Some(vec![-1.5, f64::EPSILON]),
            }),
            active_version: 2,
            next_version: 3,
            versions: vec![
                (1, vec![0.25, -0.1], 0.0),
                (2, vec![1e-300, 9.9], -2.0),
            ],
        };
        let wire = snapshot_to_json(&snap).to_string_compact();
        let back = snapshot_from_json(&parse(&wire).unwrap()).unwrap();
        assert_eq!(back, snap);
        // PartialEq treats -0.0 == 0.0, so pin the sign bits explicitly
        let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&back.state), bits(&snap.state));
        let (bt, st) = (back.trainer.unwrap(), snap.trainer.clone().unwrap());
        assert_eq!(bits(&bt.col_sums), bits(&st.col_sums));
        assert_eq!(bits(&bt.g), bits(&st.g));
        // a trainer-less snapshot (nothing accumulated yet) round-trips
        let bare = LaneSnapshot {
            trainer: None,
            ..snap.clone()
        };
        let wire = snapshot_to_json(&bare).to_string_compact();
        let back = snapshot_from_json(&parse(&wire).unwrap()).unwrap();
        assert_eq!(back, bare);
        // junk shapes are rejected at parse, not served to the sweeper
        assert!(snapshot_from_json(&Json::obj(vec![])).is_err());
    }

    #[test]
    fn commit_with_zero_rows_carries_the_commit_empty_code() {
        // the zero-rows commit must answer `code: "commit_empty"` — on
        // BOTH transports, and identically whether the connection has a
        // hub lane (sweeper-side refusal) or none at all
        let model = Arc::new(make_model());
        let task = MsoTask::new(1);
        for threaded in [false, true] {
            let (addr, handle) =
                spawn_server(Arc::clone(&model), 1, Some(1), threaded);
            let mut c = Client::connect(&addr).unwrap();
            let commit_req = Json::obj(vec![("op", Json::Str("commit".into()))]);
            // no lane yet: refused before reaching a shard queue
            let resp = c.request(&commit_req).unwrap();
            assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
            assert_eq!(
                resp.get("code"),
                Some(&Json::Str("commit_empty".into())),
                "threaded={threaded}: lane-less commit lost its code: {resp:?}"
            );
            // stream acquires a lane but trains nothing: the sweeper
            // itself must refuse with the SAME code
            let out = c.stream(&task.input[..5]).unwrap();
            assert_eq!(out.len(), 5);
            let resp = c.request(&commit_req).unwrap();
            assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
            assert_eq!(
                resp.get("code"),
                Some(&Json::Str("commit_empty".into())),
                "threaded={threaded}: zero-row commit lost its code: {resp:?}"
            );
            // the connection survives the refusals
            let out = c.stream(&task.input[5..10]).unwrap();
            assert_eq!(out.len(), 5);
            drop(c);
            handle.join().unwrap();
        }
    }

    #[test]
    fn checkpoint_restore_continues_bitwise_on_both_transports_and_precisions() {
        // the tentpole contract: a client that checkpoints mid-stream and
        // restores on a FRESH connection — even to a DIFFERENT server —
        // continues bit-identically to an uninterrupted stream
        for make in [make_model as fn() -> Model, make_model_f32] {
            let model = Arc::new(make());
            let task = MsoTask::new(1);
            let input = &task.input[..60];
            for threaded in [false, true] {
                let (addr, handle) =
                    spawn_server(Arc::clone(&model), 3, Some(2), threaded);
                // uninterrupted reference lane on its own connection
                let mut r = Client::connect(&addr).unwrap();
                let reference = r.stream(input).unwrap();
                // interrupted client: half the stream, then checkpoint
                let mut a = Client::connect(&addr).unwrap();
                let first = a.stream(&input[..30]).unwrap();
                assert_eq!(first, reference[..30], "pre-checkpoint diverged");
                let cp = a.checkpoint().unwrap();
                drop(a); // "failure": the connection (and its lane) dies
                // warm failover: fresh connection, restore, continue
                let mut b = Client::connect(&addr).unwrap();
                let active = b.restore(&cp).unwrap();
                assert_eq!(active, 0, "no commits yet: base readout active");
                let rest = b.stream(&input[30..]).unwrap();
                assert_eq!(
                    rest,
                    reference[30..],
                    "threaded={threaded}: restored stream diverged \
                     from the uninterrupted reference"
                );
                drop(b);
                drop(r);
                handle.join().unwrap();
                // lane migration: the SAME checkpoint restores onto a
                // different server over the same model, bit-identically
                let (addr2, handle2) =
                    spawn_server(Arc::clone(&model), 1, Some(1), threaded);
                let mut m = Client::connect(&addr2).unwrap();
                m.restore(&cp).unwrap();
                let rest = m.stream(&input[30..]).unwrap();
                assert_eq!(
                    rest,
                    reference[30..],
                    "threaded={threaded}: cross-server restore diverged"
                );
                drop(m);
                handle2.join().unwrap();
            }
        }
    }

    #[test]
    fn commit_versions_rollback_and_rows_survive_on_both_transports() {
        let model = Arc::new(make_model());
        let task = MsoTask::new(1);
        let train_in = &task.input[..100];
        let target: Vec<f64> = train_in.iter().map(|x| 0.5 - 2.0 * x).collect();
        let train2_in = &task.input[100..150];
        let target2: Vec<f64> = train2_in.iter().map(|x| 0.5 - 2.0 * x).collect();
        let probe = &task.input[150..180];
        for threaded in [false, true] {
            let (addr, handle) =
                spawn_server(Arc::clone(&model), 3, Some(2), threaded);
            // twin lane: identical history, but NEVER rolled back —
            // proves rollback(v1) on `a` reinstalls v1's readout
            // bit-exactly (same state ⊕ same readout ⇒ same bits)
            let mut a = Client::connect(&addr).unwrap();
            let mut twin = Client::connect(&addr).unwrap();
            for c in [&mut a, &mut twin] {
                assert_eq!(c.train(train_in, &target).unwrap(), 100);
                assert_eq!(
                    c.commit(1e-8).unwrap(),
                    1,
                    "first commit must mint version 1"
                );
                assert_eq!(c.train(train2_in, &target2).unwrap(), 150);
                assert_eq!(
                    c.commit(1e-6).unwrap(),
                    2,
                    "second commit must mint version 2"
                );
            }
            // unknown version: typed refusal, nothing changes
            let resp = a
                .request(&Json::obj(vec![
                    ("op", Json::Str("rollback".into())),
                    ("version", Json::Num(99.0)),
                ]))
                .unwrap();
            assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
            assert_eq!(
                resp.get("code"),
                Some(&Json::Str("rollback_unknown_version".into()))
            );
            // bounce through base and back — the ring retains both
            assert_eq!(a.rollback(0).unwrap(), 0);
            assert_eq!(a.rollback(1).unwrap(), 1);
            assert_eq!(twin.rollback(1).unwrap(), 1);
            let got = a.stream(probe).unwrap();
            let want = twin.stream(probe).unwrap();
            assert_eq!(
                got, want,
                "threaded={threaded}: rolled-back readout is not \
                 bit-identical to the retained version 1"
            );
            // the accumulator survived every rollback: row counts
            // continue from 150, and the next commit mints version 3
            assert_eq!(a.train(probe, &vec![0.0; probe.len()]).unwrap(), 180);
            assert_eq!(a.commit(1e-8).unwrap(), 3);
            // checkpoint carries the ring: a restore elsewhere resumes
            // at the active version with the same next-version counter
            let cp = a.checkpoint().unwrap();
            let mut b = Client::connect(&addr).unwrap();
            assert_eq!(b.restore(&cp).unwrap(), 3, "active version travels");
            assert_eq!(b.rollback(1).unwrap(), 1, "ring travels");
            let got = b.stream(probe).unwrap();
            let want = a.rollback(1).and_then(|_| a.stream(probe)).unwrap();
            assert_eq!(got, want, "restored twin diverged after rollback");
            drop(a);
            drop(twin);
            drop(b);
            handle.join().unwrap();
        }
    }

    #[test]
    fn migrate_is_bit_invisible_on_the_wire_at_both_precisions() {
        // mid-stream shard→shard migration must be unobservable: the
        // migrated lane's continuation is bit-identical to an
        // unmigrated twin's, on both transports at both precisions
        let task = MsoTask::new(1);
        let input = &task.input[..60];
        for model in [Arc::new(make_model()), Arc::new(make_model_f32())] {
            for threaded in [false, true] {
                let (addr, handle) =
                    spawn_server(Arc::clone(&model), 2, Some(2), threaded);
                let mut r = Client::connect(&addr).unwrap();
                let reference = r.stream(input).unwrap();
                let mut a = Client::connect(&addr).unwrap();
                let first = a.stream(&input[..30]).unwrap();
                assert_eq!(first, reference[..30], "pre-migration diverged");
                let info = |c: &mut Client| {
                    c.request(&Json::obj(vec![("op", Json::Str("info".into()))]))
                        .unwrap()
                };
                let before = info(&mut a);
                let cur =
                    before.get("lane_shard").and_then(Json::as_f64).unwrap();
                let target = 1 - cur as usize;
                let new_home = a.migrate(Some(target)).unwrap();
                assert_eq!(new_home, target as u64, "lane re-homed elsewhere");
                let rest = a.stream(&input[30..]).unwrap();
                assert_eq!(
                    rest,
                    reference[30..],
                    "threaded={threaded}: migrated lane diverged from the \
                     unmigrated twin"
                );
                let after = info(&mut a);
                assert_eq!(
                    after.get("lane_shard").and_then(Json::as_f64),
                    Some(target as f64),
                    "info must report the new home shard"
                );
                assert_eq!(
                    after.get("shard").and_then(Json::as_f64),
                    before.get("shard").and_then(Json::as_f64),
                    "the dispatch home (peer-IP hash) must not move"
                );
                assert!(
                    after.get("lanes_migrated").and_then(Json::as_f64).unwrap()
                        >= 1.0
                );
                assert_eq!(
                    after
                        .get("shard_occupancy_ewma")
                        .and_then(Json::as_arr)
                        .unwrap()
                        .len(),
                    2
                );
                drop(a);
                drop(r);
                handle.join().unwrap();
            }
        }
    }

    #[test]
    fn migrate_in_restores_parks_and_adopts_across_servers() {
        // the receiving half of cross-server mobility: a checkpoint
        // restores onto ANOTHER server bit-identically via migrate_in;
        // a standby delta parks without a lane and a later connection
        // adopts it; an unknown lane id is a typed refusal
        let model = Arc::new(make_model());
        let task = MsoTask::new(1);
        let input = &task.input[..60];
        for threaded in [false, true] {
            let (addr, handle) =
                spawn_server(Arc::clone(&model), 2, Some(1), threaded);
            let mut r = Client::connect(&addr).unwrap();
            let reference = r.stream(input).unwrap();
            let mut a = Client::connect(&addr).unwrap();
            assert_eq!(a.stream(&input[..30]).unwrap(), reference[..30]);
            let cp = a.checkpoint().unwrap();
            drop(a);
            drop(r);
            handle.join().unwrap();
            // successor server over the same model
            let (addr2, handle2) =
                spawn_server(Arc::clone(&model), 3, Some(2), threaded);
            let mut m = Client::connect(&addr2).unwrap();
            m.migrate_in(&cp).unwrap();
            assert_eq!(
                m.stream(&input[30..]).unwrap(),
                reference[30..],
                "threaded={threaded}: cross-server migrate_in diverged"
            );
            // park a standby delta (no lane held), then adopt it
            let mut p = Client::connect(&addr2).unwrap();
            let resp = p
                .request(&Json::obj(vec![
                    ("op", Json::Str("migrate_in".into())),
                    ("lane_id", Json::Num(42.0)),
                    ("checkpoint", cp.clone()),
                ]))
                .unwrap();
            assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
            let mut q = Client::connect(&addr2).unwrap();
            let resp = q
                .request(&Json::obj(vec![
                    ("op", Json::Str("migrate_in".into())),
                    ("lane_id", Json::Num(999.0)),
                ]))
                .unwrap();
            assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
            assert_eq!(
                resp.get("code"),
                Some(&Json::Str("unknown_lane".into())),
                "adopting an unparked lane id must be a typed refusal"
            );
            q.adopt(42).unwrap();
            assert_eq!(
                q.stream(&input[30..]).unwrap(),
                reference[30..],
                "threaded={threaded}: adopted standby lane diverged"
            );
            drop(m);
            drop(p);
            drop(q);
            handle2.join().unwrap();
        }
    }

    #[test]
    fn expired_deadlines_are_typed_refusals_that_never_advance_state() {
        // `deadline_ms: 0` is already expired at admission: the request
        // answers the typed `deadline_exceeded` code, lane state does
        // not advance, and the continuation stays bit-identical
        let model = Arc::new(make_model());
        let task = MsoTask::new(1);
        let input = &task.input[..60];
        let stream_req = |input: &[f64], deadline_ms: f64| {
            Json::obj(vec![
                ("op", Json::Str("stream".into())),
                (
                    "input",
                    Json::Arr(input.iter().map(|x| Json::Num(*x)).collect()),
                ),
                ("deadline_ms", Json::Num(deadline_ms)),
            ])
        };
        for threaded in [false, true] {
            let (addr, handle) =
                spawn_server(Arc::clone(&model), 2, Some(1), threaded);
            let mut r = Client::connect(&addr).unwrap();
            let reference = r.stream(input).unwrap();
            let mut a = Client::connect(&addr).unwrap();
            assert_eq!(a.stream(&input[..20]).unwrap(), reference[..20]);
            // expired stream: typed refusal, nothing applied
            let resp = a.request(&stream_req(&input[20..], 0.0)).unwrap();
            assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
            assert_eq!(
                resp.get("code"),
                Some(&Json::Str("deadline_exceeded".into())),
                "threaded={threaded}: expired deadline must carry its code"
            );
            // expired predict: same typed refusal on the dealt path
            let resp = a
                .request(&Json::obj(vec![
                    ("op", Json::Str("predict".into())),
                    (
                        "input",
                        Json::Arr(
                            input.iter().map(|x| Json::Num(*x)).collect(),
                        ),
                    ),
                    ("deadline_ms", Json::Num(0.0)),
                ]))
                .unwrap();
            assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
            assert_eq!(
                resp.get("code"),
                Some(&Json::Str("deadline_exceeded".into()))
            );
            // a generous deadline succeeds, and the refused stream above
            // must NOT have advanced the lane
            let resp = a.request(&stream_req(&input[20..], 30_000.0)).unwrap();
            assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
            let rest: Vec<f64> = resp
                .get("output")
                .and_then(Json::as_arr)
                .unwrap()
                .iter()
                .map(|j| j.as_f64().unwrap())
                .collect();
            assert_eq!(
                rest,
                reference[20..],
                "threaded={threaded}: a refused request advanced lane state"
            );
            let info = a
                .request(&Json::obj(vec![("op", Json::Str("info".into()))]))
                .unwrap();
            assert!(
                info.get("deadline_misses").and_then(Json::as_f64).unwrap()
                    >= 2.0,
                "both refusals must count as deadline misses"
            );
            assert!(info.get("jobs_shed").and_then(Json::as_f64).is_some());
            drop(a);
            drop(r);
            handle.join().unwrap();
        }
    }

    #[test]
    fn with_retry_backs_off_on_transient_codes_only() {
        // a scripted fake server: two `overloaded` sheds, then success,
        // then a deterministic `restore_mismatch`. with_retry must eat
        // the sheds (with backoff sleeps) and return the success, then
        // surface the deterministic refusal WITHOUT consuming a retry —
        // a retry would block on the exhausted script and hang the test
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let script = [
            r#"{"ok":false,"code":"overloaded","error":"shed"}"#,
            r#"{"ok":false,"code":"overloaded","error":"shed"}"#,
            r#"{"ok":true,"version":7}"#,
            r#"{"ok":false,"code":"restore_mismatch","error":"nope"}"#,
        ];
        let server = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            let mut writer = stream;
            for resp in script {
                let mut line = String::new();
                if reader.read_line(&mut line).unwrap() == 0 {
                    break;
                }
                writeln!(writer, "{resp}").unwrap();
                writer.flush().unwrap();
            }
        });
        let mut c = Client::connect(&addr).unwrap();
        let req = Json::obj(vec![
            ("op", Json::Str("commit".into())),
            ("alpha", Json::Num(1e-8)),
        ]);
        let t0 = Instant::now();
        let resp = c.with_retry(&req, 5).unwrap();
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(resp.get("version").and_then(Json::as_f64), Some(7.0));
        assert!(
            t0.elapsed() >= Duration::from_millis(10),
            "two retries must each back off at least the base delay"
        );
        let resp = c.with_retry(&req, 5).unwrap();
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
        assert_eq!(
            resp.get("code"),
            Some(&Json::Str("restore_mismatch".into())),
            "deterministic refusals must surface immediately, unretried"
        );
        drop(c);
        server.join().unwrap();
    }

    #[test]
    fn retryable_codes_are_pinned_to_the_error_table() {
        // the retryable subset is a subset of the one-table contract …
        for code in RETRYABLE_CODES {
            assert!(
                ERROR_CODES.contains(code),
                "retryable code {code:?} is not in the coded_error table"
            );
        }
        // … and is EXACTLY the transient set: everything else in the
        // table is deterministic and must never be retried. `moved` is
        // transient because a redirect that reaches the retry layer
        // means the ring was mid-transition — the next attempt lands
        // on the settled owner
        for code in ERROR_CODES {
            let transient =
                matches!(*code, "unavailable" | "overloaded" | "moved");
            assert_eq!(
                is_retryable_code(code),
                transient,
                "retryability of {code:?} drifted from the contract"
            );
            // every table entry resolves to a mapped (code, message)
            // pair — the debug_assert fallback means a table/constructor
            // mismatch
            let e = coded_error(code);
            let we = e.downcast_ref::<WireError>().unwrap();
            assert_eq!(we.code, *code);
            assert_ne!(
                we.message(),
                "internal serving error",
                "{code:?} is in ERROR_CODES but unmapped in coded_error"
            );
        }
        for code in ["restore_mismatch", "commit_singular", "rollback_unknown_version"]
        {
            assert!(!is_retryable_code(code));
        }
    }

    #[test]
    fn shutdown_drain_op_stops_the_server_cleanly_on_both_transports() {
        // a drain request stops the accept loop and exits the server
        // even with the connection budget unspent — the reply flushes
        // first (shutdown_drain returns Ok), and join does not hang
        let model = Arc::new(make_model());
        let task = MsoTask::new(1);
        for threaded in [false, true] {
            let (addr, handle) =
                spawn_server(Arc::clone(&model), 64, Some(1), threaded);
            let mut a = Client::connect(&addr).unwrap();
            let out = a.stream(&task.input[..10]).unwrap();
            assert_eq!(out.len(), 10);
            a.shutdown_drain().unwrap();
            drop(a);
            handle.join().unwrap();
        }
    }

    #[test]
    fn drain_checkpoint_spills_live_lanes_for_a_successor_server() {
        // --drain-checkpoint: a drained server spills every live lane to
        // dir/lane-<id>.json, and the spilled snapshot migrates into a
        // successor server bit-identically
        let model = Arc::new(make_model());
        let task = MsoTask::new(1);
        let input = &task.input[..60];
        for threaded in [false, true] {
            let dir = std::env::temp_dir().join(format!(
                "lr-pr7-spill-{}-{threaded}",
                std::process::id()
            ));
            std::fs::create_dir_all(&dir).unwrap();
            let listener = TcpListener::bind("127.0.0.1:0").unwrap();
            let addr = listener.local_addr().unwrap().to_string();
            let server_model = Arc::clone(&model);
            let spill = dir.clone();
            let handle = std::thread::spawn(move || {
                serve_on_opts(
                    listener,
                    server_model,
                    Some(64),
                    ServeOpts {
                        shards: Some(1),
                        threaded,
                        drain_checkpoint: Some(spill),
                        ..Default::default()
                    },
                )
                .unwrap();
            });
            let mut r = Client::connect(&addr).unwrap();
            let reference = r.stream(input).unwrap();
            drop(r); // released before the drain: must NOT be spilled
            let mut a = Client::connect(&addr).unwrap();
            assert_eq!(a.stream(&input[..20]).unwrap(), reference[..20]);
            let info = a
                .request(&Json::obj(vec![("op", Json::Str("info".into()))]))
                .unwrap();
            let lane_id =
                info.get("lane_id").and_then(Json::as_f64).unwrap() as u64;
            a.shutdown_drain().unwrap();
            drop(a);
            handle.join().unwrap();
            let spilled = std::fs::read_dir(&dir)
                .unwrap()
                .map(|e| e.unwrap().file_name().into_string().unwrap())
                .collect::<Vec<_>>();
            assert_eq!(
                spilled,
                vec![format!("lane-{lane_id}.json")],
                "threaded={threaded}: exactly the live lane spills"
            );
            // spills carry a trailing fnv1a checksum line; the loader
            // verifies it and hands back the snapshot json
            let json = super::super::ShardedFront::read_spilled_lane(
                &dir.join(format!("lane-{lane_id}.json")),
            )
            .unwrap();
            let cp = parse(&json).unwrap();
            // successor: the spilled lane migrates in and continues
            let (addr2, handle2) =
                spawn_server(Arc::clone(&model), 1, Some(1), threaded);
            let mut b = Client::connect(&addr2).unwrap();
            b.migrate_in(&cp).unwrap();
            assert_eq!(
                b.stream(&input[20..]).unwrap(),
                reference[20..],
                "threaded={threaded}: spilled lane diverged in the successor"
            );
            drop(b);
            handle2.join().unwrap();
            let _ = std::fs::remove_dir_all(&dir);
        }
    }

    #[test]
    fn standby_pusher_replicates_lanes_for_bitwise_promotion() {
        // --standby: the primary pushes dirty-lane checkpoint deltas to
        // the replica; once `standby_lag_lanes` drains to 0, adopting
        // the lane on the standby continues the stream bit-identically
        let model = Arc::new(make_model());
        let task = MsoTask::new(1);
        let input = &task.input[..60];
        let (standby_addr, standby_handle) =
            spawn_server(Arc::clone(&model), 64, Some(1), true);
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let primary_addr = listener.local_addr().unwrap().to_string();
        let server_model = Arc::clone(&model);
        let standby_for_primary = standby_addr.clone();
        let primary_handle = std::thread::spawn(move || {
            serve_on_opts(
                listener,
                server_model,
                Some(64),
                ServeOpts {
                    shards: Some(1),
                    threaded: true,
                    standby: Some(standby_for_primary),
                    standby_interval_ms: 20,
                    ..Default::default()
                },
            )
            .unwrap();
        });
        let mut r = Client::connect(&primary_addr).unwrap();
        let reference = r.stream(input).unwrap();
        let mut a = Client::connect(&primary_addr).unwrap();
        assert_eq!(a.stream(&input[..30]).unwrap(), reference[..30]);
        let info_req = Json::obj(vec![("op", Json::Str("info".into()))]);
        let lane_id = a
            .request(&info_req)
            .unwrap()
            .get("lane_id")
            .and_then(Json::as_f64)
            .unwrap() as u64;
        // wait (bounded) for the pusher to drain every dirty lane
        let t0 = Instant::now();
        loop {
            let lag = a
                .request(&info_req)
                .unwrap()
                .get("standby_lag_lanes")
                .and_then(Json::as_f64)
                .unwrap();
            if lag == 0.0 {
                break;
            }
            assert!(
                t0.elapsed() < Duration::from_secs(10),
                "standby lag never drained (still {lag} lanes behind)"
            );
            std::thread::sleep(Duration::from_millis(25));
        }
        // "promotion": a fresh client adopts the replicated lane on the
        // standby and continues as if the primary never existed
        let mut s = Client::connect(&standby_addr).unwrap();
        s.adopt(lane_id).unwrap();
        assert_eq!(
            s.stream(&input[30..]).unwrap(),
            reference[30..],
            "promoted standby lane diverged from the primary's twin"
        );
        // orderly teardown: drain the primary first (stops the pusher),
        // then the standby
        a.shutdown_drain().unwrap();
        drop(a);
        drop(r);
        primary_handle.join().unwrap();
        s.shutdown_drain().unwrap();
        drop(s);
        standby_handle.join().unwrap();
    }

    #[test]
    fn ping_op_answers_pong_on_both_transports() {
        let model = Arc::new(make_model());
        for threaded in [false, true] {
            let (addr, handle) =
                spawn_server(Arc::clone(&model), 2, Some(1), threaded);
            let mut c = Client::connect(&addr).unwrap();
            let resp = c
                .request(&Json::obj(vec![("op", Json::Str("ping".into()))]))
                .unwrap();
            assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
            assert_eq!(
                resp.get("pong"),
                Some(&Json::Bool(true)),
                "threaded={threaded}: ping must answer pong"
            );
            c.shutdown_drain().unwrap();
            drop(c);
            handle.join().unwrap();
        }
    }

    #[test]
    fn corrupt_snapshots_are_rejected_typed_on_both_transports() {
        // a tampered or truncated checkpoint surfaces as the typed
        // `restore_corrupt` refusal — never a parse panic — and leaves
        // the lane untouched, on restore AND on migrate_in
        let model = Arc::new(make_model());
        let task = MsoTask::new(1);
        let input = &task.input[..40];
        for threaded in [false, true] {
            let (addr, handle) =
                spawn_server(Arc::clone(&model), 2, Some(1), threaded);
            let mut r = Client::connect(&addr).unwrap();
            let reference = r.stream(input).unwrap();
            let mut a = Client::connect(&addr).unwrap();
            assert_eq!(a.stream(&input[..20]).unwrap(), reference[..20]);
            let cp = a.checkpoint().unwrap();
            // tamper: flip the precision tag to an unknown value
            let text = cp.to_string_compact();
            assert!(text.contains("f64"), "checkpoint lost its precision tag");
            let corrupt = parse(&text.replace("f64", "f16")).unwrap();
            for op in ["restore", "migrate_in"] {
                let resp = a
                    .request(&Json::obj(vec![
                        ("op", Json::Str(op.into())),
                        ("checkpoint", corrupt.clone()),
                    ]))
                    .unwrap();
                assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
                assert_eq!(
                    resp.get("code"),
                    Some(&Json::Str("restore_corrupt".into())),
                    "threaded={threaded}: corrupt {op} must carry its code"
                );
            }
            // a truncated snapshot (missing required fields) is the
            // same typed refusal
            let resp = a
                .request(&Json::obj(vec![
                    ("op", Json::Str("restore".into())),
                    (
                        "checkpoint",
                        Json::obj(vec![("precision", Json::Str("f64".into()))]),
                    ),
                ]))
                .unwrap();
            assert_eq!(
                resp.get("code"),
                Some(&Json::Str("restore_corrupt".into())),
                "threaded={threaded}: truncated snapshot must be typed"
            );
            // nothing was applied: the lane continues bit-identically
            assert_eq!(
                a.stream(&input[20..]).unwrap(),
                reference[20..],
                "threaded={threaded}: a refused restore touched the lane"
            );
            drop(a);
            drop(r);
            handle.join().unwrap();
        }
    }

    #[test]
    fn spilled_lane_checksum_rejects_corruption_and_truncation() {
        // the spill loader verifies the fnv1a trailer: intact files
        // round-trip, flipped bytes and truncations are refused
        let dir = std::env::temp_dir()
            .join(format!("lr-pr8-spillck-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let json = r#"{"precision":"f64","versions":[]}"#;
        let sum = super::super::cluster::fnv1a(json.as_bytes());
        let good = dir.join("good.json");
        std::fs::write(&good, format!("{json}\nfnv1a:{sum:016x}\n")).unwrap();
        assert_eq!(
            super::super::ShardedFront::read_spilled_lane(&good).unwrap(),
            json
        );
        // one flipped byte in the payload: checksum mismatch
        let bad = dir.join("bad.json");
        let tampered = json.replace("f64", "f65");
        std::fs::write(&bad, format!("{tampered}\nfnv1a:{sum:016x}\n"))
            .unwrap();
        assert!(super::super::ShardedFront::read_spilled_lane(&bad).is_err());
        // truncated: payload with no checksum line
        let cut = dir.join("cut.json");
        std::fs::write(&cut, json).unwrap();
        assert!(super::super::ShardedFront::read_spilled_lane(&cut).is_err());
        // empty file
        let empty = dir.join("empty.json");
        std::fs::write(&empty, "").unwrap();
        assert!(
            super::super::ShardedFront::read_spilled_lane(&empty).is_err()
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn client_surfaces_redirect_loop_after_bounded_moved_hops() {
        // a scripted server that always answers `moved` pointing at
        // itself: the client must follow a bounded number of hops and
        // then surface the typed `redirect_loop` error — never spin
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let resp_addr = addr.clone();
        let server = std::thread::spawn(move || {
            // initial connection + one reconnect per followed hop
            for _ in 0..=MAX_REDIRECT_HOPS {
                let (stream, _) = listener.accept().unwrap();
                let mut reader = BufReader::new(stream.try_clone().unwrap());
                let mut writer = stream;
                let mut line = String::new();
                if reader.read_line(&mut line).unwrap() == 0 {
                    continue;
                }
                writeln!(
                    writer,
                    r#"{{"ok":false,"code":"moved","addr":"{resp_addr}","error":"not here"}}"#
                )
                .unwrap();
                writer.flush().unwrap();
            }
        });
        let mut c = Client::connect(&addr).unwrap();
        let err = c
            .request(&Json::obj(vec![("op", Json::Str("info".into()))]))
            .unwrap_err();
        let we = err
            .downcast_ref::<WireError>()
            .expect("redirect loop must be a typed wire error");
        assert_eq!(we.code, "redirect_loop");
        drop(c);
        server.join().unwrap();
    }

    #[test]
    fn cluster_peers_redirect_nonowned_keys_and_clients_follow() {
        // two live nodes split the ring; every loopback client shares
        // one connection key, so exactly one node owns it. The other
        // node refuses key-homed ops with `moved {addr}`, and
        // Client::request follows the redirect transparently
        let model = Arc::new(make_model());
        let task = MsoTask::new(1);
        let input = &task.input[..40];
        let l_a = TcpListener::bind("127.0.0.1:0").unwrap();
        let l_b = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr_a = l_a.local_addr().unwrap().to_string();
        let addr_b = l_b.local_addr().unwrap().to_string();
        let mut handles = Vec::new();
        for (listener, advertise, peer) in [
            (l_a, addr_a.clone(), addr_b.clone()),
            (l_b, addr_b.clone(), addr_a.clone()),
        ] {
            let m = Arc::clone(&model);
            handles.push(std::thread::spawn(move || {
                serve_on_opts(
                    listener,
                    m,
                    Some(64),
                    ServeOpts {
                        shards: Some(1),
                        threaded: true,
                        peers: Some(peer),
                        advertise: Some(advertise),
                        ping_interval_ms: 25,
                        ..Default::default()
                    },
                )
                .unwrap();
            }));
        }
        // discover the owner of the loopback key from either node
        let info_req = Json::obj(vec![("op", Json::Str("info".into()))]);
        let mut probe = Client::connect(&addr_a).unwrap();
        let info = probe.request(&info_req).unwrap();
        assert_eq!(info.get("cluster_nodes").and_then(Json::as_f64), Some(2.0));
        assert_eq!(info.get("cluster_live").and_then(Json::as_f64), Some(2.0));
        let owner = info
            .get("cluster_owner")
            .and_then(Json::as_str)
            .unwrap()
            .to_string();
        assert!(owner == addr_a || owner == addr_b);
        let other = if owner == addr_a {
            addr_b.clone()
        } else {
            addr_a.clone()
        };
        drop(probe);
        // reference lane lives on the owner
        let mut r = Client::connect(&owner).unwrap();
        let reference = r.stream(input).unwrap();
        // raw protocol view from the non-owner: key-homed ops answer
        // `moved` carrying the owner's address …
        let mut raw = Client::connect(&other).unwrap();
        raw.send(&Json::obj(vec![
            ("op", Json::Str("stream".into())),
            ("input", Json::Arr(vec![Json::Num(task.input[0])])),
        ]))
        .unwrap();
        let resp = raw.recv().unwrap();
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
        assert_eq!(resp.get("code"), Some(&Json::Str("moved".into())));
        assert_eq!(
            resp.get("addr").and_then(Json::as_str),
            Some(owner.as_str()),
            "moved must name the owning node"
        );
        // … while exempt ops (info, ping) answer locally
        let local = raw.request(&info_req).unwrap();
        assert_eq!(local.get("ok"), Some(&Json::Bool(true)));
        drop(raw);
        // a redirect-following client connected to the WRONG node lands
        // on the owner and streams bit-identically
        let mut c = Client::connect(&other).unwrap();
        assert_eq!(
            c.stream(input).unwrap(),
            reference,
            "redirected stream diverged from the owner-local twin"
        );
        // teardown: drain both nodes (drain is exempt from the guard)
        drop(r);
        c.shutdown_drain().unwrap();
        drop(c);
        let mut d = Client::connect(&other).unwrap();
        d.shutdown_drain().unwrap();
        drop(d);
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn holdoff_autotune_idles_to_zero_and_fixed_mode_stays_pinned() {
        let model = Arc::new(make_model());
        let task = MsoTask::new(1);
        let cap_us = 120_000u64;
        let info_req = Json::obj(vec![("op", Json::Str("info".into()))]);
        // fixed mode: the effective window IS the configured window
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let m = Arc::clone(&model);
        let fixed = std::thread::spawn(move || {
            serve_on_opts(
                listener,
                m,
                Some(4),
                ServeOpts {
                    shards: Some(1),
                    threaded: true,
                    holdoff_us: cap_us,
                    ..Default::default()
                },
            )
            .unwrap();
        });
        let mut c = Client::connect(&addr).unwrap();
        let info = c.request(&info_req).unwrap();
        assert_eq!(
            info.get("holdoff_effective_us").and_then(Json::as_f64),
            Some(cap_us as f64),
            "fixed mode must report the configured window"
        );
        c.shutdown_drain().unwrap();
        drop(c);
        fixed.join().unwrap();
        // autotuned mode: zero before any traffic, bounded by the cap
        // under traffic, and back to zero once the shard goes idle
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let m = Arc::clone(&model);
        let auto = std::thread::spawn(move || {
            serve_on_opts(
                listener,
                m,
                Some(4),
                ServeOpts {
                    shards: Some(1),
                    threaded: true,
                    holdoff_us: cap_us,
                    holdoff_auto: true,
                    ..Default::default()
                },
            )
            .unwrap();
        });
        let mut c = Client::connect(&addr).unwrap();
        let info = c.request(&info_req).unwrap();
        assert_eq!(
            info.get("holdoff_us").and_then(Json::as_f64),
            Some(cap_us as f64)
        );
        assert_eq!(
            info.get("holdoff_effective_us").and_then(Json::as_f64),
            Some(0.0),
            "an untouched shard must add zero latency"
        );
        let out = c.stream(&task.input[..20]).unwrap();
        assert_eq!(out.len(), 20, "autotuned stream must still answer");
        let eff = c
            .request(&info_req)
            .unwrap()
            .get("holdoff_effective_us")
            .and_then(Json::as_f64)
            .unwrap();
        assert!(
            eff <= cap_us as f64,
            "effective window {eff} exceeded the --holdoff-us cap"
        );
        // idle longer than the cap: the window must collapse to zero
        std::thread::sleep(Duration::from_micros(cap_us + 40_000));
        let info = c.request(&info_req).unwrap();
        assert_eq!(
            info.get("holdoff_effective_us").and_then(Json::as_f64),
            Some(0.0),
            "an idle shard must converge back to zero added latency"
        );
        c.shutdown_drain().unwrap();
        drop(c);
        auto.join().unwrap();
    }

    // -----------------------------------------------------------------
    // PR 10: wire-path A/B. The binary frame protocol must be
    // BIT-identical to JSON on every op, on both transports, at both
    // precisions. One fresh server per client (same deterministic
    // model), the same op sequence, transcripts compared as compact
    // JSON text — shortest-round-trip float formatting means equal
    // text ⇔ equal bits.
    // -----------------------------------------------------------------

    /// The op sequence both clients drive: every serving op, version
    /// control, a tunnelled structured op, deadline-tagged requests and
    /// typed errors — plus float values (−0.0, the smallest subnormal)
    /// that would expose any formatting shortcut on either side.
    fn ab_ops() -> Vec<Json> {
        let task = MsoTask::new(1);
        let arr = |v: &[f64]| Json::Arr(v.iter().map(|&x| Json::Num(x)).collect());
        let op = |name: &str| ("op", Json::Str(name.into()));
        vec![
            Json::obj(vec![op("ping")]),
            Json::obj(vec![op("predict"), ("input", arr(&task.input[..25]))]),
            Json::obj(vec![
                op("predict"),
                ("input", arr(&[0.0, -0.0, 5e-324, 1.0e-300, -7.25e-12, 0.5])),
            ]),
            Json::obj(vec![op("stream"), ("input", arr(&task.input[..5]))]),
            Json::obj(vec![op("stream"), ("input", arr(&task.input[5..10]))]),
            Json::obj(vec![
                op("train"),
                ("input", arr(&task.input[10..50])),
                ("target", arr(&task.input[11..51])),
            ]),
            Json::obj(vec![op("commit"), ("alpha", Json::Num(1e-8))]),
            Json::obj(vec![op("stream"), ("input", arr(&task.input[10..15]))]),
            Json::obj(vec![op("rollback"), ("version", Json::Num(0.0))]),
            Json::obj(vec![op("stream"), ("input", arr(&task.input[15..20]))]),
            // tunnelled op with a structured reply
            Json::obj(vec![op("checkpoint")]),
            Json::obj(vec![op("ping"), ("deadline_ms", Json::Num(30_000.0))]),
            Json::obj(vec![op("reset")]),
            Json::obj(vec![op("stream"), ("input", arr(&task.input[..5]))]),
            // typed errors must match byte for byte too
            Json::obj(vec![op("no_such_op")]),
            Json::obj(vec![
                op("train"),
                ("input", arr(&[1.0])),
                ("target", arr(&[1.0, 2.0])),
            ]),
            Json::obj(vec![op("rollback"), ("version", Json::Num(99.0))]),
        ]
    }

    /// `steps_per_sec` is wall-clock timing — the only legitimately
    /// nondeterministic response field. Everything else must match.
    fn strip_timing(mut j: Json) -> Json {
        if let Json::Obj(ref mut m) = j {
            m.remove("steps_per_sec");
        }
        j
    }

    fn run_wire_ab(threaded: bool, model_fn: fn() -> Model) {
        let seq = ab_ops();
        let mut transcripts: Vec<Vec<String>> = Vec::new();
        for binary in [false, true] {
            let model = Arc::new(model_fn());
            let (addr, handle) = spawn_server(model, 1, Some(1), threaded);
            let mut c = Client::connect(&addr).unwrap();
            if binary {
                c.upgrade_binary().unwrap();
            }
            let mut out = Vec::with_capacity(seq.len());
            for req in &seq {
                let resp = c.request(req).unwrap();
                out.push(strip_timing(resp).to_string_compact());
            }
            drop(c);
            handle.join().unwrap();
            transcripts.push(out);
        }
        let (json_t, bin_t) = (&transcripts[0], &transcripts[1]);
        assert_eq!(json_t.len(), bin_t.len());
        for (i, (a, b)) in json_t.iter().zip(bin_t.iter()).enumerate() {
            assert_eq!(
                a,
                b,
                "response to op #{i} ({}) diverged between JSON and binary",
                seq[i].to_string_compact()
            );
        }
    }

    #[test]
    fn binary_transcript_is_bit_identical_to_json_threaded_f64() {
        run_wire_ab(true, make_model);
    }

    #[test]
    fn binary_transcript_is_bit_identical_to_json_threaded_f32() {
        run_wire_ab(true, make_model_f32);
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn binary_transcript_is_bit_identical_to_json_event_loop_f64() {
        run_wire_ab(false, make_model);
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn binary_transcript_is_bit_identical_to_json_event_loop_f32() {
        run_wire_ab(false, make_model_f32);
    }

    /// Drive a poisoned binary connection end to end: hello + ack, then
    /// `poison` bytes, then write-shutdown. The server must answer ONE
    /// typed `bad_frame` refusal frame and close the connection.
    fn assert_bad_frame_then_eof(addr: &str, poison: &[u8]) {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(&binframe::client_hello()).unwrap();
        let mut ack = [0u8; binframe::HELLO_LEN];
        s.read_exact(&mut ack).unwrap();
        assert_eq!(ack, binframe::server_hello(), "upgrade ack mismatch");
        s.write_all(poison).unwrap();
        s.shutdown(std::net::Shutdown::Write).unwrap();
        let mut reader = BufReader::new(s);
        match binframe::read_frame(&mut reader).unwrap() {
            binframe::ReadFrame::Frame(body) => {
                let resp = binframe::decode_response(&body).unwrap();
                assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
                assert_eq!(
                    resp.get("code").and_then(Json::as_str),
                    Some("bad_frame"),
                    "refusal must carry the typed bad_frame code: {resp:?}"
                );
            }
            _ => panic!("expected a typed bad_frame reply frame"),
        }
        match binframe::read_frame(&mut reader).unwrap() {
            binframe::ReadFrame::Eof => {}
            _ => panic!("expected EOF after the bad_frame refusal"),
        }
    }

    fn run_framing_refusals(threaded: bool) {
        let model = Arc::new(make_model());
        let (addr, handle) = spawn_server(model, 3, Some(1), threaded);
        // oversized length prefix: framing is lost from the first field
        let over = ((binframe::MAX_FRAME_BYTES + 1) as u32).to_le_bytes();
        assert_bad_frame_then_eof(&addr, &over);
        // torn frame: the prefix promises 100 bytes, EOF after 10
        let mut torn = 100u32.to_le_bytes().to_vec();
        torn.extend_from_slice(&[0u8; 10]);
        assert_bad_frame_then_eof(&addr, &torn);
        // wrong-version hello: magic matches, version does not — the
        // typed refusal comes back before any frame is exchanged
        let mut s = TcpStream::connect(&addr).unwrap();
        let mut bad_hello = binframe::client_hello();
        bad_hello[4] = binframe::VERSION + 1;
        s.write_all(&bad_hello).unwrap();
        s.shutdown(std::net::Shutdown::Write).unwrap();
        let mut reader = BufReader::new(s);
        match binframe::read_frame(&mut reader).unwrap() {
            binframe::ReadFrame::Frame(body) => {
                let resp = binframe::decode_response(&body).unwrap();
                assert_eq!(
                    resp.get("code").and_then(Json::as_str),
                    Some("bad_frame"),
                    "wrong-version hello must be refused typed: {resp:?}"
                );
            }
            _ => panic!("expected a typed refusal of the wrong-version hello"),
        }
        handle.join().unwrap();
    }

    #[test]
    fn torn_and_oversized_frames_refused_on_the_wire_threaded() {
        run_framing_refusals(true);
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn torn_and_oversized_frames_refused_on_the_wire_event_loop() {
        run_framing_refusals(false);
    }

    /// A binary upgrade on one connection must not disturb JSON
    /// connections on the same server — and both answer bit-identically.
    fn run_upgrade_negotiation(threaded: bool) {
        let model = Arc::new(make_model());
        let (addr, handle) = spawn_server(Arc::clone(&model), 2, Some(1), threaded);
        let task = MsoTask::new(1);
        let mut bin = Client::connect(&addr).unwrap();
        bin.upgrade_binary().unwrap();
        assert!(bin.is_binary());
        let mut json = Client::connect(&addr).unwrap();
        assert!(!json.is_binary());
        let want = model.predict(&task.input[..20]);
        for c in [&mut bin, &mut json] {
            let got = c.predict(&task.input[..20]).unwrap();
            assert_eq!(got.len(), want.len());
            for (a, b) in got.iter().zip(&want) {
                assert_eq!(a.to_bits(), b.to_bits(), "{a} vs {b}");
            }
        }
        drop(bin);
        drop(json);
        handle.join().unwrap();
    }

    #[test]
    fn binary_upgrade_coexists_with_json_threaded() {
        run_upgrade_negotiation(true);
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn binary_upgrade_coexists_with_json_event_loop() {
        run_upgrade_negotiation(false);
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn poll_threads_deal_connections_and_publish_stats() {
        // P = 2 poll threads: connections are dealt round-robin, every
        // connection serves bit-identically wherever it lands, and
        // `info` publishes the new wire-path observability fields
        let model = Arc::new(make_model());
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server_model = Arc::clone(&model);
        let handle = std::thread::spawn(move || {
            serve_on_opts(
                listener,
                server_model,
                Some(4),
                ServeOpts {
                    shards: Some(1),
                    poll_threads: 2,
                    ..Default::default()
                },
            )
            .unwrap();
        });
        let task = MsoTask::new(1);
        let info_req = Json::obj(vec![("op", Json::Str("info".into()))]);
        let mut conns: Vec<Client> = (0..4)
            .map(|i| {
                let mut c = Client::connect(&addr).unwrap();
                if i == 3 {
                    c.upgrade_binary().unwrap();
                }
                c
            })
            .collect();
        let want = model.predict(&task.input[..15]);
        let mut homes = Vec::new();
        for c in conns.iter_mut() {
            let info = c.request(&info_req).unwrap();
            assert_eq!(
                info.get("poll_threads").and_then(Json::as_f64),
                Some(2.0)
            );
            homes.push(info.get("poll_thread").and_then(Json::as_f64).unwrap());
            assert_eq!(
                info.get("poll_rounds").and_then(Json::as_arr).map(|a| a.len()),
                Some(2),
                "one readiness-round counter per poll thread"
            );
            let got = c.predict(&task.input[..15]).unwrap();
            for (a, b) in got.iter().zip(&want) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
        assert!(
            homes.contains(&0.0) && homes.contains(&1.0),
            "round-robin dealing must spread connections across both \
             poll threads, got homes {homes:?}"
        );
        let binary_conns = conns[3]
            .request(&info_req)
            .unwrap()
            .get("binary_conns")
            .and_then(Json::as_f64)
            .unwrap();
        assert!(binary_conns >= 1.0, "binary_conns = {binary_conns}");
        drop(conns);
        handle.join().unwrap();
    }
}
