//! Cluster membership: consistent hashing, liveness gossip, and the
//! failure detector — the layer that turns N independent `repro serve`
//! processes into one self-healing group (DESIGN.md §12).
//!
//! The design is deliberately minimal and crate-free:
//!
//! * **Static membership** — the full node set is the `--peers` list
//!   plus this node's own `--advertise` address. Nodes never join or
//!   leave the SET at runtime; they only transition between *alive* and
//!   *dead*, which is what reassigns ring ranges. Addresses are compared
//!   as byte strings, so every node of a group must be configured with
//!   the IDENTICAL address spelling for each member.
//! * **Consistent hashing** — connection keys (the wire layer's peer-IP
//!   key) map to owning nodes through a hash ring with
//!   [`VNODES_PER_NODE`] virtual nodes per member, hashed with the same
//!   SplitMix64 finalizer as the intra-process shard map. Ownership is a
//!   pure function of `(key, live node set)`: every live node computes
//!   the same ring, so any node can answer `moved {addr}` for a key it
//!   does not own and the redirect converges. When a node dies, only the
//!   ranges it owned move (~1/n of the key space — tested below);
//!   everyone else's clients are untouched.
//! * **Liveness gossip** — each node pings every peer once per interval
//!   over the ordinary wire protocol (`{"op": "ping"}` — one line, no
//!   lane state touched) with IO-timeout-bounded reads, smoothing the
//!   observed RTT with an EWMA and counting consecutive misses. A peer
//!   at [`MISS_THRESHOLD`] consecutive misses is declared dead and the
//!   ring is rebuilt without it; a later successful ping resurrects it
//!   (and rebuilds again) — a restarted node re-enters the group with no
//!   operator action.
//!
//! Failover then needs no coordinator: the primary's standby fan-out
//! already parked its lane deltas on the surviving replicas, the
//! detector reassigns its ring range to a survivor, every survivor's
//! `moved` responses point clients at that new owner, and the client's
//! `migrate_in` adopt promotes the parked lane there — chaos-proven
//! bit-identical against a SIGKILLed real process in
//! `rust/tests/chaos.rs`.
//!
//! **Tenant models need no transfer at all.** A registry entry
//! (`server/registry.rs`) is a pure function of its recipe —
//! `(seed, n, spectral_radius, lambda_prior)` drive a dedicated PCG
//! stream, so `create_model` mints bit-identical `(Λ, [W_in]_Q)` planes
//! on every node, and the model id is itself a hash of the recipe. A
//! client redirected by `moved` (or failing over after a node death)
//! simply re-issues the same `create_model` at the new owner: the
//! idempotent create re-mints the identical model in microseconds, and
//! the lane STATE — the only per-tenant bytes the recipe cannot
//! regenerate — rides the existing checkpoint/standby machinery
//! unchanged.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::rng::splitmix64_mix;
use crate::util::json::Json;

/// Virtual nodes per member: enough points that each member's share of
/// the key space concentrates near 1/n (balance bound tested below)
/// while keeping ring rebuilds trivially cheap (n·64 hashes + a sort).
pub(crate) const VNODES_PER_NODE: usize = 64;

/// Consecutive ping misses before a peer is declared dead. With the
/// default interval this bounds detection at ~`MISS_THRESHOLD ×
/// interval` plus one IO timeout.
pub(crate) const MISS_THRESHOLD: u32 = 5;

/// Default gossip ping interval (ms) when `--ping-interval-ms` is 0.
pub(crate) const DEFAULT_PING_INTERVAL_MS: u64 = 50;

/// EWMA smoothing factor for the per-peer RTT signal.
const RTT_EWMA_ALPHA: f64 = 0.2;

/// FNV-1a 64-bit over raw bytes — the crate's string/content hash
/// (node addresses here; drain-spill checksums in `shard.rs`). One copy
/// of the magic constants.
pub(crate) fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Ring point of virtual node `replica` of member `addr`: FNV-1a folds
/// the address bytes, SplitMix64 decorrelates the replica index, and a
/// final mix spreads the points uniformly over the u64 circle.
fn vnode_point(addr: &str, replica: usize) -> u64 {
    splitmix64_mix(fnv1a(addr.as_bytes()) ^ splitmix64_mix(replica as u64 | 1 << 62))
}

/// A consistent-hash ring over the LIVE members: sorted virtual-node
/// points, each naming its owner. Ownership of a key is the first point
/// clockwise of the key's hash (wrapping).
pub(crate) struct HashRing {
    /// `(point, node index)` sorted by point.
    points: Vec<(u64, usize)>,
    nodes: Vec<String>,
}

impl HashRing {
    /// Build the ring over `nodes` (order-independent: placement is a
    /// pure function of each address string).
    pub(crate) fn build(nodes: &[String]) -> Self {
        let nodes: Vec<String> = nodes.to_vec();
        let mut points = Vec::with_capacity(nodes.len() * VNODES_PER_NODE);
        for (i, addr) in nodes.iter().enumerate() {
            for r in 0..VNODES_PER_NODE {
                points.push((vnode_point(addr, r), i));
            }
        }
        points.sort_unstable();
        Self { points, nodes }
    }

    /// The owning member for a connection key (`None` on an empty ring).
    pub(crate) fn owner(&self, key: u64) -> Option<&str> {
        if self.points.is_empty() {
            return None;
        }
        let h = splitmix64_mix(key);
        let idx = match self.points.binary_search_by(|p| p.0.cmp(&h)) {
            Ok(i) => i,
            Err(i) => i,
        };
        let (_, node) = self.points[idx % self.points.len()];
        Some(&self.nodes[node])
    }

    /// Member count.
    pub(crate) fn len(&self) -> usize {
        self.nodes.len()
    }
}

/// Health record of one peer, updated only by the gossip thread (the
/// mutex is uncontended; readers are `info` and ring rebuilds).
struct PeerHealth {
    rtt_ewma_us: f64,
    misses: u32,
    alive: bool,
}

struct PeerSlot {
    addr: String,
    health: Mutex<PeerHealth>,
}

/// One node's view of the group: the static member set, per-peer health,
/// and the current ring over the live members. Shared between the gossip
/// thread (writes) and both transports' ownership guards (reads).
pub struct ClusterState {
    /// This node's own address as the group knows it (`--advertise`).
    advertise: String,
    peers: Vec<PeerSlot>,
    /// Ring over the LIVE members; swapped wholesale on a liveness
    /// transition so readers always see a consistent ring.
    ring: Mutex<Arc<HashRing>>,
    /// Monotonic rebuild counter (starts at 1) — `ring_epoch` in `info`,
    /// so an operator can see failovers happen.
    epoch: AtomicU64,
}

impl ClusterState {
    /// Build the group view: everyone starts ALIVE (optimistic boot —
    /// a cold group must not bounce redirects off nodes that merely
    /// haven't pinged yet; a genuinely absent peer is declared dead
    /// within `MISS_THRESHOLD` intervals). `advertise` is removed from
    /// `peers` if listed, so self-pings never happen.
    pub fn new(advertise: String, peers: Vec<String>) -> Arc<Self> {
        let peers: Vec<PeerSlot> = peers
            .into_iter()
            .filter(|p| !p.is_empty() && *p != advertise)
            .map(|addr| PeerSlot {
                addr,
                health: Mutex::new(PeerHealth {
                    rtt_ewma_us: 0.0,
                    misses: 0,
                    alive: true,
                }),
            })
            .collect();
        let state = Self {
            advertise,
            ring: Mutex::new(Arc::new(HashRing::build(&[]))),
            peers,
            epoch: AtomicU64::new(0),
        };
        state.rebuild_ring();
        Arc::new(state)
    }

    /// This node's advertised address.
    pub fn advertise(&self) -> &str {
        &self.advertise
    }

    /// Total member count (self + peers, dead or alive).
    pub fn members(&self) -> usize {
        self.peers.len() + 1
    }

    /// Currently-live member count (self counts).
    pub fn live_members(&self) -> usize {
        1 + self
            .peers
            .iter()
            .filter(|p| p.health.lock().unwrap().alive)
            .count()
    }

    /// Ring rebuild count so far.
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Relaxed)
    }

    /// Rebuild the ring over self + the live peers (called on every
    /// liveness transition; cheap enough that calling it spuriously is
    /// harmless).
    fn rebuild_ring(&self) {
        let mut nodes = vec![self.advertise.clone()];
        nodes.extend(
            self.peers
                .iter()
                .filter(|p| p.health.lock().unwrap().alive)
                .map(|p| p.addr.clone()),
        );
        *self.ring.lock().unwrap() = Arc::new(HashRing::build(&nodes));
        self.epoch.fetch_add(1, Ordering::Relaxed);
    }

    /// The live member owning `key` under the current ring.
    pub fn owner_for_key(&self, key: u64) -> String {
        let ring = Arc::clone(&self.ring.lock().unwrap());
        ring.owner(key).unwrap_or(&self.advertise).to_string()
    }

    /// `Some(owner)` when `key` is owned by ANOTHER live member — the
    /// ownership guard both transports answer `moved {addr}` from.
    pub fn owned_elsewhere(&self, key: u64) -> Option<String> {
        let owner = self.owner_for_key(key);
        (owner != self.advertise).then_some(owner)
    }

    /// Number of peers (gossip targets).
    pub(crate) fn peer_count(&self) -> usize {
        self.peers.len()
    }

    /// Peer `idx`'s address.
    pub(crate) fn peer_addr(&self, idx: usize) -> &str {
        &self.peers[idx].addr
    }

    /// Record a successful ping of peer `idx`: reset the miss counter,
    /// fold the RTT into the EWMA, and resurrect (ring rebuild) if the
    /// peer was dead.
    pub(crate) fn record_pong(&self, idx: usize, rtt: Duration) {
        let resurrected = {
            let mut h = self.peers[idx].health.lock().unwrap();
            h.misses = 0;
            let rtt_us = rtt.as_micros() as f64;
            h.rtt_ewma_us = if h.rtt_ewma_us == 0.0 {
                rtt_us
            } else {
                RTT_EWMA_ALPHA * rtt_us + (1.0 - RTT_EWMA_ALPHA) * h.rtt_ewma_us
            };
            !std::mem::replace(&mut h.alive, true)
        };
        if resurrected {
            self.rebuild_ring();
        }
    }

    /// Record a missed ping of peer `idx`; at [`MISS_THRESHOLD`]
    /// consecutive misses the peer is declared dead and its ring range
    /// reassigned. Returns `true` on the alive→dead transition.
    pub(crate) fn record_miss(&self, idx: usize) -> bool {
        let died = {
            let mut h = self.peers[idx].health.lock().unwrap();
            h.misses = h.misses.saturating_add(1);
            h.alive && h.misses >= MISS_THRESHOLD && {
                h.alive = false;
                true
            }
        };
        if died {
            self.rebuild_ring();
        }
        died
    }

    /// Per-peer `(addr, alive, rtt_ewma_us)` snapshot for `info`.
    pub fn peer_status(&self) -> Vec<(String, bool, f64)> {
        self.peers
            .iter()
            .map(|p| {
                let h = p.health.lock().unwrap();
                (p.addr.clone(), h.alive, h.rtt_ewma_us)
            })
            .collect()
    }
}

/// The gossip sidecar (one thread per clustered node, spawned by
/// `serve_on_opts` next to the rebalancer/pusher): every `interval`,
/// ping each peer over a lazily-(re)connected wire client with
/// IO-timeout-bounded reads, and feed the detector. Connection attempts
/// are timeout-bounded too — a black-holed peer costs one bounded miss
/// per round, never a hang.
pub(crate) fn gossip_loop(
    cluster: Arc<ClusterState>,
    stop: Arc<AtomicBool>,
    interval: Duration,
) {
    let mut clients: Vec<Option<super::wire::Client>> =
        (0..cluster.peer_count()).map(|_| None).collect();
    let ping = Json::obj(vec![("op", Json::Str("ping".into()))]);
    // every ping (connect, write, read) is bounded by this, so one round
    // can't stall past peers × timeout even with every peer black-holed
    let io_timeout = (interval * 2).max(Duration::from_millis(50));
    'gossip: loop {
        // sleep in short slices so serve_on_opts joins promptly
        let mut slept = Duration::ZERO;
        while slept < interval {
            if stop.load(Ordering::SeqCst) {
                break 'gossip;
            }
            let slice = Duration::from_millis(10).min(interval - slept);
            std::thread::sleep(slice);
            slept += slice;
        }
        for idx in 0..cluster.peer_count() {
            if stop.load(Ordering::SeqCst) {
                break 'gossip;
            }
            let slot = &mut clients[idx];
            if slot.is_none() {
                match super::wire::Client::connect_timeout(
                    cluster.peer_addr(idx),
                    io_timeout,
                ) {
                    Ok(mut c) => {
                        let _ = c.set_io_timeout(Some(io_timeout));
                        *slot = Some(c);
                    }
                    Err(_) => {
                        cluster.record_miss(idx);
                        continue;
                    }
                }
            }
            let c = slot.as_mut().expect("connected above");
            let t = Instant::now();
            match c.request(&ping) {
                Ok(resp)
                    if resp.get("ok") == Some(&Json::Bool(true)) =>
                {
                    cluster.record_pong(idx, t.elapsed());
                }
                _ => {
                    *slot = None; // reconnect next round
                    cluster.record_miss(idx);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;

    fn nodes(n: usize) -> Vec<String> {
        (0..n).map(|i| format!("10.0.0.{i}:7878")).collect()
    }

    #[test]
    fn ring_ownership_is_deterministic_and_total() {
        let ring = HashRing::build(&nodes(5));
        let twin = HashRing::build(&nodes(5));
        for key in 0..512u64 {
            let a = ring.owner(key).expect("non-empty ring owns every key");
            // pure function of (key, node set): a rebuilt ring agrees
            assert_eq!(Some(a), twin.owner(key));
            assert_eq!(Some(a), ring.owner(key));
        }
        assert!(HashRing::build(&[]).owner(7).is_none());
    }

    #[test]
    fn ring_virtual_nodes_balance_within_bound() {
        // with 64 vnodes each, no member of a 4-node ring should own
        // more than ~2× its fair share of a large key population
        let ring = HashRing::build(&nodes(4));
        let mut counts: HashMap<String, usize> = HashMap::new();
        const KEYS: u64 = 20_000;
        for key in 0..KEYS {
            *counts
                .entry(ring.owner(key).unwrap().to_string())
                .or_default() += 1;
        }
        assert_eq!(counts.len(), 4, "every member owns some keys");
        let fair = KEYS as usize / 4;
        for (addr, c) in &counts {
            assert!(
                *c > fair / 2 && *c < fair * 2,
                "vnode balance bound violated: {addr} owns {c} of {KEYS} \
                 (fair share {fair})"
            );
        }
    }

    #[test]
    fn ring_node_leave_moves_only_its_own_keys() {
        // consistent hashing's defining property: removing one of n
        // members re-homes ONLY the keys that member owned (~1/n); every
        // other key keeps its owner — so a node death never reshuffles
        // the survivors' clients
        let full = HashRing::build(&nodes(5));
        let mut reduced_nodes = nodes(5);
        let dead = reduced_nodes.remove(2);
        let reduced = HashRing::build(&reduced_nodes);
        const KEYS: u64 = 10_000;
        let mut moved = 0usize;
        for key in 0..KEYS {
            let before = full.owner(key).unwrap();
            let after = reduced.owner(key).unwrap();
            if before == dead {
                assert_ne!(after, dead, "dead node's keys must re-home");
                moved += 1;
            } else {
                assert_eq!(
                    before, after,
                    "key {key} moved although its owner survived"
                );
            }
        }
        // the departed member owned roughly 1/5 of the space
        let fair = KEYS as usize / 5;
        assert!(
            moved > fair / 2 && moved < fair * 2,
            "expected ~{fair} keys to move, got {moved}"
        );
        // join is the same statement in reverse: re-adding the member
        // restores the original assignment exactly
        let rejoined = HashRing::build(&nodes(5));
        for key in 0..KEYS {
            assert_eq!(rejoined.owner(key), full.owner(key));
        }
    }

    #[test]
    fn detector_declares_death_at_threshold_and_resurrects() {
        let c = ClusterState::new(
            "10.0.0.0:7878".into(),
            vec!["10.0.0.1:7878".into(), "10.0.0.2:7878".into()],
        );
        assert_eq!(c.members(), 3);
        assert_eq!(c.live_members(), 3, "optimistic boot: all alive");
        let epoch0 = c.epoch();
        // misses below the threshold change nothing
        for _ in 0..MISS_THRESHOLD - 1 {
            assert!(!c.record_miss(0));
        }
        assert_eq!(c.live_members(), 3);
        assert_eq!(c.epoch(), epoch0);
        // the threshold-th consecutive miss kills it and rebuilds
        assert!(c.record_miss(0));
        assert_eq!(c.live_members(), 2);
        assert_eq!(c.epoch(), epoch0 + 1);
        // dead peers own nothing: every key resolves to a live member
        for key in 0..256u64 {
            assert_ne!(c.owner_for_key(key), "10.0.0.1:7878");
        }
        // a successful ping resurrects it (restarted node re-enters)
        c.record_pong(0, Duration::from_micros(250));
        assert_eq!(c.live_members(), 3);
        assert_eq!(c.epoch(), epoch0 + 2);
        let status = c.peer_status();
        assert!(status[0].1 && status[0].2 > 0.0, "RTT EWMA recorded");
    }

    #[test]
    fn owned_elsewhere_is_none_for_own_range() {
        let c = ClusterState::new(
            "10.0.0.0:7878".into(),
            vec!["10.0.0.1:7878".into()],
        );
        let mut own = 0usize;
        let mut other = 0usize;
        for key in 0..512u64 {
            match c.owned_elsewhere(key) {
                None => own += 1,
                Some(addr) => {
                    assert_eq!(addr, "10.0.0.1:7878");
                    other += 1;
                }
            }
        }
        assert!(own > 0 && other > 0, "a 2-node ring splits the space");
        // a single-node "cluster" owns everything
        let solo = ClusterState::new("10.0.0.0:7878".into(), vec![]);
        for key in 0..256u64 {
            assert!(solo.owned_elsewhere(key).is_none());
        }
    }
}
